"""Execute the fenced Python blocks in README.md and docs/*.md.

Documentation snippets rot silently: an API rename passes every test while
the README still shows the old spelling. This checker makes the docs part
of CI — every ```python fence is executed, per file, top to bottom, in one
shared namespace (so a later block may use names an earlier block in the
same file defined, exactly as a reader would run them).

    python tools/docs_check.py README.md docs/*.md

Conventions:
* Only ``python`` fences run; ``bash``/``json``/``text`` fences are
  documentation-only.
* A fence whose info string contains ``no-run`` (e.g. ```` ```python
  no-run ````) is skipped — for snippets that need hardware or external
  services. Use sparingly: a skipped snippet is an unchecked snippet.
* Blocks run from the repo root (snippets may open checked-in files by
  relative path).
* A forced 4-device host platform is set up before jax loads, so
  mesh-serving snippets work on CPU-only hosts.

Exit status: nonzero on the first failing block, with the file, block
index, and traceback. No failure output means every snippet ran green.
"""
from __future__ import annotations

import os
import re
import sys
import time
import traceback

# before any snippet (or transitively jax) is imported: mesh snippets need
# devices, CPU-only CI hosts need them forced
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

FENCE_RE = re.compile(r"^```(\S*)[ \t]*(.*)$")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def python_blocks(path: str) -> list[tuple[int, str, str]]:
    """(start line, info string, source) for each fenced code block."""
    blocks, info, buf, start = [], None, [], 0
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            m = FENCE_RE.match(line.rstrip())
            if m and info is None:
                info, buf, start = (m.group(1) + " " + m.group(2)).strip(), \
                    [], ln
            elif line.rstrip() == "```" and info is not None:
                blocks.append((start, info, "".join(buf)))
                info = None
            elif info is not None:
                buf.append(line)
    if info is not None:
        raise SystemExit(f"{path}:{start}: unterminated code fence")
    return blocks


def run_file(path: str) -> tuple[int, int]:
    """Execute a file's python fences in one namespace; (ran, skipped)."""
    namespace: dict = {"__name__": f"docscheck:{os.path.basename(path)}"}
    ran = skipped = 0
    for idx, (ln, info, src) in enumerate(python_blocks(path)):
        words = info.split()
        if not words or words[0] not in ("python", "py"):
            continue
        if "no-run" in words[1:]:
            skipped += 1
            print(f"  SKIP  {path}:{ln} (no-run)")
            continue
        t0 = time.monotonic()
        try:
            exec(compile(src, f"{path}:block{idx}(line {ln})", "exec"),
                 namespace)
        except Exception:
            print(f"  FAIL  {path}:{ln} (block {idx})", flush=True)
            traceback.print_exc()
            raise SystemExit(1) from None
        ran += 1
        print(f"  ok    {path}:{ln} ({time.monotonic() - t0:.1f}s)",
              flush=True)
    return ran, skipped


def main(argv: list[str]) -> int:
    paths = argv or ["README.md",
                     *sorted(os.path.join("docs", p)
                             for p in os.listdir(os.path.join(REPO_ROOT,
                                                              "docs"))
                             if p.endswith(".md"))]
    os.chdir(REPO_ROOT)   # snippets open checked-in files by relative path
    total = skipped = 0
    for path in paths:
        print(f"docs-check: {path}", flush=True)
        r, s = run_file(path)
        total += r
        skipped += s
    print(f"docs-check: {total} blocks ran green, {skipped} skipped "
          f"across {len(paths)} files")
    if total == 0:
        print("docs-check: no runnable blocks found — check the fences",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
