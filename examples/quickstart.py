"""Quickstart: build an index, run every diverse-search method, compare.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.api import diverse_search
from repro.core.baselines import div_astar_oracle
from repro.index.flat import build_knn_graph

rng = np.random.default_rng(0)
centers = rng.normal(size=(20, 32)) * 2.0
X = (centers[rng.integers(0, 20, 5000)]
     + rng.normal(size=(5000, 32)) * 0.4).astype(np.float32)

print("building proximity graph over N=5000 ...")
graph = build_knn_graph(X, metric="l2", M=8)

q = X[123] + 0.05 * rng.normal(size=32).astype(np.float32)
k, eps = 5, 0.0
for method in ("greedy", "pgs", "pds", "pss"):
    res = diverse_search(graph, q, k=k, eps=eps, method=method, ef=15)
    print(f"{method:8s} ids={res.ids} total={res.total:.4f} "
          f"K={res.stats.K_final} certified={res.stats.certified}")
oracle = div_astar_oracle(X, "l2", q, k, eps)
print(f"oracle   ids={oracle.ids} total={oracle.total:.4f}")
