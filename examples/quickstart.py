"""Quickstart: build an index, run every diverse-search method, then serve
a mixed-(k, eps) request stream — plus live upserts and deletes — through
the ``DiverseVectorDB`` facade.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.api import diverse_search
from repro.core.baselines import div_astar_oracle
from repro.db import DiverseVectorDB, Query
from repro.index.flat import build_knn_graph

rng = np.random.default_rng(0)
centers = rng.normal(size=(20, 32)) * 2.0
X = (centers[rng.integers(0, 20, 5000)]
     + rng.normal(size=(5000, 32)) * 0.4).astype(np.float32)

print("building proximity graph over N=5000 ...")
graph = build_knn_graph(X, metric="l2", M=8)

q = X[123] + 0.05 * rng.normal(size=32).astype(np.float32)
k, eps = 5, 0.0
for method in ("greedy", "pgs", "pds", "pss"):
    res = diverse_search(graph, q, k=k, eps=eps, method=method, ef=15)
    print(f"{method:8s} ids={res.ids} total={res.total:.4f} "
          f"K={res.stats.K_final} certified={res.stats.certified}")
oracle = div_astar_oracle(X, "l2", q, k, eps)
print(f"oracle   ids={oracle.ids} total={oracle.total:.4f}")

# --- serving: the DiverseVectorDB facade ------------------------------------
# One constructor assembles index -> engine -> scheduler (-> cache). Each
# request is a frozen Query carrying its own (k, eps) — the paper's
# Definition 1, end to end: no index rebuild between diversification
# levels. Certified lanes are recycled for queued requests; results are
# bit-identical to the per-query drivers above.
print("\nserving 8 mixed-(k, eps) requests over 3 lanes ...")
db = DiverseVectorDB(index=graph, num_lanes=3, max_k=8, default_ef=15,
                     prewarm=False)
queries = X[rng.integers(0, 5000, 8)] \
    + 0.05 * rng.normal(size=(8, 32)).astype(np.float32)
reqs = [Query(queries[i], k=(5, 3)[i % 2], eps=(0.0, -0.5)[i % 2], ef=15)
        for i in range(8)]
results = db.search_batch(reqs)
for i, (req, r) in enumerate(zip(reqs, results)):
    print(f"req {i}: k={req.k} eps={req.eps:+.1f} ids={r.ids} "
          f"certified={r.stats.certified}")
stats = db.stats()
print(f"scheduler: p50={stats['p50_latency'] * 1e3:.0f}ms "
      f"p99={stats['p99_latency'] * 1e3:.0f}ms "
      f"fairness={stats['fairness']:.3f} "
      f"throughput={stats['throughput']:.1f} req/s")

# --- writes: upsert / delete at serve time ----------------------------------
# Fresh vectors land in a flat-scored delta segment and join results at
# harvest; deletes flip a bitmap the diversifier and certificates respect;
# a full delta triggers a background rebuild + epoch swap (contract 15).
new_ids = db.upsert(q[None] + 0.01)
r = db.search(Query(q, k=5, eps=0.0, ef=15))
print(f"\nupserted id {int(new_ids[0])}; now served: "
      f"{int(new_ids[0]) in r.ids.tolist()}")
db.delete(new_ids)
r = db.search(Query(q, k=5, eps=0.0, ef=15))
print(f"deleted id {int(new_ids[0])}; still served: "
      f"{int(new_ids[0]) in r.ids.tolist()}")
print(f"index: {db.stats()['index']}")
