"""Train a reduced-config LM with the fault-tolerant loop (checkpoints,
straggler monitor, resume).

    PYTHONPATH=src python examples/train_lm.py
"""
from repro.compat import make_mesh

from repro.configs import get_config
from repro.train.loop import train
from repro.train.optimizer import AdamW, cosine_schedule

cfg = get_config("qwen2-1.5b").reduced()
mesh = make_mesh((1, 1), ("data", "model"))
report = train(cfg, mesh, steps=60, global_batch=16, seq_len=32,
               ckpt_dir="/tmp/repro_train_demo", ckpt_every=20,
               optimizer=AdamW(lr=cosine_schedule(3e-3, 10, 60)))
print(f"ran {report.steps_run} steps; loss {report.losses[0]:.3f} -> "
      f"{report.final_loss:.3f}; restarts={report.restarts}; "
      f"stragglers={report.straggler_events}")
