"""The paper's Fig. 1 scenario: diverse retrieval over CLIP-like embeddings.

Synthetic 'image embeddings' live on the unit sphere in tight near-duplicate
clusters (re-crops / re-uploads of the same artwork). A plain top-k returns
near-duplicates; the paper's PSS with user-chosen eps removes them and
stays optimal.

    PYTHONPATH=src python examples/diverse_image_search.py
"""
import numpy as np

from repro.core.api import diverse_search
from repro.core.beam_search import beam_search
from repro.core.similarity import pairwise_sim
from repro.index.flat import build_knn_graph

import jax.numpy as jnp

rng = np.random.default_rng(1)
n_works, dups, d = 800, 6, 64
works = rng.normal(size=(n_works, d))
X = np.repeat(works, dups, 0) + rng.normal(size=(n_works * dups, d)) * 0.02
X /= np.linalg.norm(X, axis=1, keepdims=True)
X = X.astype(np.float32)

graph = build_knn_graph(X, metric="cos", M=8)
q = (works[17] / np.linalg.norm(works[17])).astype(np.float32)

ids, scores = beam_search(graph, jnp.asarray(q), k=5, L=100)
print("plain top-5 (near-duplicates, work id = index//dups):",
      np.asarray(ids) // dups)

for eps in (0.99, 0.8):
    res = diverse_search(graph, q, k=5, eps=eps, method="pss", ef=20)
    works_found = res.ids // dups
    sims = np.asarray(pairwise_sim(jnp.asarray(X[res.ids]),
                                   jnp.asarray(X[res.ids]), "cos"))
    off = sims[~np.eye(5, dtype=bool)]
    print(f"pss eps={eps}: works={works_found} max_pair_sim={off.max():.3f} "
          f"total={res.total:.3f}")
