"""Elastic scaling demo: checkpoint on one mesh, resume on a smaller one.

Runs itself twice under different XLA device counts (the controller role).

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import subprocess
import sys

PHASE = os.environ.get("ELASTIC_PHASE")

if PHASE is None:
    env = dict(os.environ)
    for phase, devs in (("big", "8"), ("small", "4")):
        env["ELASTIC_PHASE"] = phase
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devs}"
        out = subprocess.run([sys.executable, __file__], env=env)
        assert out.returncode == 0
    print("elastic 8-device -> 4-device restart OK")
    raise SystemExit(0)

import jax  # noqa: E402
from repro.compat import make_mesh
from repro.configs import get_config  # noqa: E402
from repro.distributed import sharding as sh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.train import checkpoint as ckpt  # noqa: E402

cfg = get_config("qwen2-1.5b").reduced()
CKPT = "/tmp/repro_elastic_demo"
if PHASE == "big":
    mesh = make_mesh((4, 2), ("data", "model"))
    params = M.init_params(cfg, jax.random.key(0))
    specs = sh.to_named(sh.param_spec_tree(cfg, params, mesh), mesh)
    params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, specs)
    ckpt.save(CKPT, 1, params)
    print("phase=big: saved on", mesh.shape)
else:
    mesh = make_mesh((2, 2), ("data", "model"))
    like = M.init_params(cfg, jax.random.key(0))
    specs = sh.to_named(sh.param_spec_tree(cfg, like, mesh), mesh)
    params = ckpt.restore(CKPT, 1, like, shardings=specs)
    batch = M.make_batch(cfg, batch=4, seq=8, rng=jax.random.key(1))
    print("phase=small: restored on", mesh.shape, "loss=",
          float(M.loss_fn(cfg, params, batch)))
