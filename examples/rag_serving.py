"""End-to-end RAG: diverse retrieval (the paper) feeding LM decode.

Retrieval is served by a ``DiverseVectorDB`` — index, engine, scheduler
assembled behind one constructor — passed to the pipeline as ``db=``. Each
request is submitted with its own (k, eps), lanes freed by certified
queries are recycled, and per-request latency stats come back with the
answer. The same db accepts upserts/deletes between generate calls.

    PYTHONPATH=src python examples/rag_serving.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.db import DiverseVectorDB
from repro.models import model as M
from repro.serve.rag import RagPipeline

rng = np.random.default_rng(0)
docs = rng.normal(size=(4000, 48)).astype(np.float32)
db = DiverseVectorDB(docs, "ip", M=8, num_lanes=3, max_k=16,
                     prewarm=False)

cfg = get_config("qwen2-1.5b").reduced()
params = M.init_params(cfg, jax.random.key(0))
pipe = RagPipeline(cfg, params, k=4, eps=3.0, ef=4,
                   engine="scheduler", num_lanes=3, db=db)

queries = docs[rng.integers(0, 4000, 3)]
tokens, ids, certified = pipe.generate(queries, np.ones((3, 4), np.int32),
                                       steps=8)
print("retrieved diverse doc ids per query:\n", ids)
print("theorem-2 certified lanes:", certified)
print("generated tokens:\n", tokens)

# live corpus update: the next generate() sees the new document
new_ids = db.upsert(queries[:1] + 0.01)
_, ids2, _ = pipe.generate(queries[:1], np.ones((1, 4), np.int32), steps=4)
print(f"upserted doc {int(new_ids[0])}; retrieved now:", ids2[0])

stats = pipe.scheduler.latency_stats()
print(f"scheduler: completed={stats['completed']} "
      f"p99={stats['p99_latency'] * 1e3:.0f}ms "
      f"signatures={stats['signatures']} writes={stats['writes']}")
