"""Fault-tolerant training loop.

Single entry ``train(cfg, ...)``: builds the sharded train step, resumes
from the newest complete checkpoint, prefetches data, checkpoints every N
steps (async), and runs a straggler/fault monitor:

  * per-step wall times feed an EWMA; a step slower than
    ``straggler_factor`` x EWMA is logged as a straggler event (at fleet
    scale this hook is where the controller would re-slice or evict);
  * any exception inside the step triggers restore-from-checkpoint and
    replay (``max_restarts`` bound), exercised by tests via
    ``fault_hook`` (injects a crash at a chosen step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding as sh
from repro.launch.steps import build_train_step
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.optimizer import AdamW


@dataclasses.dataclass
class TrainReport:
    steps_run: int
    final_loss: float
    restarts: int
    straggler_events: list
    losses: list


def train(cfg: ModelConfig, mesh, *, steps: int, global_batch: int,
          seq_len: int, ckpt_dir: str, ckpt_every: int = 50,
          optimizer: AdamW | None = None, seed: int = 0,
          fault_hook: Callable[[int], None] | None = None,
          straggler_factor: float = 3.0, max_restarts: int = 3,
          log_every: int = 10) -> TrainReport:
    opt = optimizer or AdamW(lr=1e-3)
    step_fn, _ = build_train_step(cfg, mesh, optimizer=opt)
    pspec = sh.param_spec_tree(cfg, M.abstract_params(cfg), mesh)
    pshard = sh.to_named(pspec, mesh)

    def fresh_state():
        with mesh:
            params = jax.jit(
                lambda k: M.init_params(cfg, k),
                out_shardings=pshard)(jax.random.key(seed))
            opt_state = jax.jit(opt.init)(params)
        return params, opt_state

    params, opt_state = fresh_state()
    start = 0
    last = ckpt.latest_step(ckpt_dir)
    if last is not None:
        params = ckpt.restore(ckpt_dir, last, params,
                              shardings=pshard)
        opt_state = ckpt.restore(ckpt_dir + "/opt", last, opt_state)
        start = last

    saver = ckpt.AsyncCheckpointer(ckpt_dir)
    opt_saver = ckpt.AsyncCheckpointer(ckpt_dir + "/opt")
    data = SyntheticLM(cfg.vocab_size, seq_len, global_batch, seed=seed)
    pf = Prefetcher(data, start_step=start)

    losses: list[float] = []
    stragglers: list[tuple[int, float]] = []
    restarts = 0
    ewma = None
    step = start
    try:
        while step < steps:
            try:
                t0 = time.time()
                dstep, batch = pf.next()
                if fault_hook is not None:
                    fault_hook(dstep)
                fb = dict(batch)
                if M.needs_frontend(cfg):
                    fb["frontend_embeds"] = np.zeros(
                        (batch["tokens"].shape[0], cfg.num_frontend_tokens,
                         cfg.d_model), np.float32)
                with mesh:
                    params, opt_state, loss = step_fn(params, opt_state, fb)
                loss = float(loss)
                dt = time.time() - t0
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
                if dt > straggler_factor * ewma and step > start + 3:
                    stragglers.append((step, dt))
                losses.append(loss)
                if log_every and step % log_every == 0:
                    print(f"step {step:6d} loss {loss:.4f} {dt*1e3:.0f}ms",
                          flush=True)
                step += 1
                if ckpt_every and step % ckpt_every == 0:
                    saver.save(step, params)
                    opt_saver.save(step, opt_state)
            except Exception as e:  # noqa: BLE001 — restart-from-checkpoint
                restarts += 1
                print(f"step {step} failed ({type(e).__name__}: {e}); "
                      f"restart {restarts}/{max_restarts}", flush=True)
                if restarts > max_restarts:
                    raise
                saver.wait()
                opt_saver.wait()
                last = ckpt.latest_step(ckpt_dir)
                if last is None:
                    params, opt_state = fresh_state()
                    step = 0
                else:
                    params, opt_state = fresh_state()
                    params = ckpt.restore(ckpt_dir, last, params,
                                          shardings=pshard)
                    opt_state = ckpt.restore(ckpt_dir + "/opt", last,
                                             opt_state)
                    step = last
                pf.close()
                pf = Prefetcher(data, start_step=step)
    finally:
        pf.close()
        saver.wait()
        opt_saver.wait()
    return TrainReport(steps_run=step - start, final_loss=losses[-1] if losses
                       else float("nan"), restarts=restarts,
                       straggler_events=stragglers, losses=losses)
