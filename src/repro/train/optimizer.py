"""AdamW + schedules, pure-pytree (no optax dependency in the container).

State layout mirrors the param tree (so the param sharding specs apply
verbatim to both moments), plus a scalar step. ``adamw`` returns an
(init, update) pair in the optax style the rest of the framework consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        # global-norm clip
        if self.grad_clip > 0:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32)))
                              for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(F32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(F32)),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(F32)
        bc2 = 1 - b2 ** step.astype(F32)
        lr = self._lr(step)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(F32)
            return (p.astype(F32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        s = step.astype(F32)
        warm = peak * s / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 \
            * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(s < warmup, warm, cos)
    return lr
