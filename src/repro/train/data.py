"""Training data pipeline: deterministic sharded token streams + prefetch.

Two sources:
  * ``SyntheticLM`` — deterministic PRNG token stream (structured so loss can
    actually go down: a noisy copy/induction pattern), seeded per (step,
    host) so every data-parallel worker reads a disjoint slice without
    coordination — the property the 1000-node deployment needs.
  * ``MemmapLM``   — flat uint16/uint32 token file, strided per host.

``Prefetcher`` overlaps host batch assembly with device compute (one
background thread, bounded queue) — compute/comm/input overlap at the
pipeline level.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class SyntheticLM:
    """Induction-pattern synthetic LM data: predictable continuation."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, num_hosts: int = 1, host_id: int = 0):
        assert global_batch % num_hosts == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // num_hosts
        self.seed = seed
        self.host_id = host_id

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.host_id, step]))
        b, s = self.local_batch, self.seq
        period = 8
        motif = rng.integers(0, self.vocab, (b, period))
        reps = -(-(s + 1) // period)
        toks = np.tile(motif, (1, reps))[:, : s + 1]
        noise = rng.random((b, s + 1)) < 0.05
        toks = np.where(noise, rng.integers(0, self.vocab, (b, s + 1)), toks)
        return dict(tokens=toks[:, :-1].astype(np.int32),
                    labels=toks[:, 1:].astype(np.int32))

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MemmapLM:
    """Flat token-file reader; hosts stride disjointly."""

    def __init__(self, path: str, seq_len: int, global_batch: int,
                 dtype=np.uint16, num_hosts: int = 1, host_id: int = 0):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.seq = seq_len
        self.local_batch = global_batch // num_hosts
        self.num_hosts = num_hosts
        self.host_id = host_id
        self.tokens_per_step = global_batch * (seq_len + 1)

    def batch_at(self, step: int) -> dict:
        n = self.data.shape[0]
        start = (step * self.tokens_per_step
                 + self.host_id * self.local_batch * (self.seq + 1)) % max(
                     n - self.local_batch * (self.seq + 1), 1)
        flat = np.asarray(self.data[start: start + self.local_batch
                                    * (self.seq + 1)]).astype(np.int32)
        toks = flat.reshape(self.local_batch, self.seq + 1)
        return dict(tokens=toks[:, :-1], labels=toks[:, 1:])

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Bounded background prefetch; .close() joins the worker."""

    _STOP = object()

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self.q.get()

    def close(self):
        self._stop.set()
        self.thread.join(timeout=2)
