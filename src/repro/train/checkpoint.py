"""Sharded checkpointing: atomic manifests, async save, elastic restore.

Layout (one directory per step):

    <dir>/step_000123/
        arrays.npz          flattened param+opt tree ("/"-joined key paths)
        MANIFEST.json       step, mesh shape, tree digest, status=complete

Writes go to ``step_xxx.tmp`` then os.replace — a crashed writer never
leaves a manifest behind, so ``latest_step`` only ever resumes from a
complete checkpoint (the fault-tolerance contract). ``AsyncCheckpointer``
snapshots to host then writes on a worker thread so the train loop never
blocks on disk. Restore is *elastic*: arrays are laid out by logical key,
so they restore onto any mesh — ``device_put`` with the new sharding
re-partitions (tested 8 -> 4 devices in tests/test_train.py).
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Any

import jax
import numpy as np


_WIDTH_VIEW = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}
_SAVEZ_SAFE = {"bool", "int8", "int16", "int32", "int64", "uint8", "uint16",
               "uint32", "uint64", "float16", "float32", "float64",
               "complex64", "complex128"}


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    """Flatten to numpy; ml_dtypes (bf16, fp8, ...) are stored as unsigned
    views since np.savez cannot round-trip them natively. ``restore`` views
    them back using the target tree's dtypes."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name not in _SAVEZ_SAFE:
            arr = arr.view(_WIDTH_VIEW[arr.dtype.itemsize])
        flat[key] = arr
    return flat


def tree_digest(tree: Any) -> str:
    keys = sorted(
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        + f":{leaf.shape}:{leaf.dtype}"
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0])
    return hashlib.sha256("|".join(keys).encode()).hexdigest()[:16]


def save(dir_: str, step: int, tree: Any, extra: dict | None = None) -> str:
    os.makedirs(dir_, exist_ok=True)
    final = os.path.join(dir_, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = dict(step=step, digest=tree_digest(tree),
                    num_arrays=len(flat), status="complete",
                    **(extra or {}))
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        import shutil
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(dir_: str) -> int | None:
    if not os.path.isdir(dir_):
        return None
    steps = []
    for name in os.listdir(dir_):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(dir_, name, "MANIFEST.json")):
            steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore(dir_: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (shapes+dtypes must match).

    ``shardings``: optional pytree of NamedSharding for elastic placement on
    a (possibly different) mesh.
    """
    path = os.path.join(dir_, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    assert manifest["status"] == "complete"
    want = tree_digest(like)
    if manifest["digest"] != want:
        raise ValueError(
            f"checkpoint tree digest {manifest['digest']} != expected {want}")
    arrays = np.load(os.path.join(path, "arrays.npz"))
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else None)
    out = []
    for i, (p, leaf) in enumerate(leaves_with_path[0]):
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = arrays[key]
        want = np.dtype(leaf.dtype)
        if arr.dtype != want and want.name not in _SAVEZ_SAFE:
            arr = arr.view(want)  # stored as a uint view (bf16, fp8, ...)
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(leaves_with_path[1], out)


class AsyncCheckpointer:
    """Snapshot to host immediately; persist on a background thread."""

    def __init__(self, dir_: str):
        self.dir = dir_
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None):
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot (blocks on xfer)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, extra), daemon=True)
        self._thread.start()

    def _write(self, step, tree, extra):
        self.last_path = save(self.dir, step, tree, extra)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
