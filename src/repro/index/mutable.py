"""Online mutable index: delta segment + deletion bitmap + epoch swap.

The paper's framework assumes a static corpus; real serving takes writes
concurrently with reads. This module adds the incremental path (ROADMAP
direction 3) as a *decorator layer* over the existing offline builders and
engines, so the progressive search machinery stays untouched:

* **Delta segment** — upserted vectors land in a fixed-capacity tail of the
  (append-only) corpus buffer. They are not in any graph yet; instead every
  harvested lane's candidate frontier is merged with a flat brute-force
  scan of the live delta via the ``kernels/ops.py`` batch-similarity ladder
  (``quantized="int8"`` corpora also run the int8 rung —
  ``quantized_similarity_many`` over the delta codes — but the merged
  frontier always carries exact float scores: contract 13).
* **Deletion bitmap** — ``delete`` tombstones ids in place. Vectors are
  never moved or reused (ids are positional and append-only), so every id
  means the same vector in every epoch; the bitmap is applied at harvest,
  *before* diversification and the Theorem-2 audit, and the semantic cache
  revalidates against it (``MutableIndex`` is the cache's live-corpus
  hook).
* **Background rebuild and epoch swap** — when the delta fills,
  ``request_rebuild`` builds a fresh structure (``index/flat.py`` /
  ``index/hnsw.py`` single-host, ``sharded_search`` on a mesh) over a
  snapshot of the rows, optionally on a background thread. The swap is
  installed **between rounds**: ``MutableBackend.free_lanes`` stops
  admitting while a built structure is pending, lets in-flight lanes drain,
  and installs the new epoch on an idle engine (``swap_graph`` /
  ``swap_index``). Per-lane search state is shaped by the corpus size
  (``beam_search.SearchState.visited`` is ``bool[N]``), so a mid-flight
  swap is structurally unsafe — the drain barrier is what makes the swap
  atomic.

Contract 15 (``docs/ARCHITECTURE.md``): a search straddling an epoch swap
returns results valid against one epoch or the other, never a mix — every
search runs all its rounds against a single epoch's structure, and its
harvest-time merge (bitmap filter + delta merge + Theorem-2 re-audit) reads
one consistent snapshot of the live corpus, against which the certificate
is sound. Because ids are append-only and per-id vectors immutable, a
pre-swap frontier is still meaningful post-swap: the audit simply runs
against the live view.

Certificate soundness under the merge: the engine's frontier bounds every
*unexplored graph point* by its K-th candidate score (``s_K``; ``-inf``
when the frontier carries padding, i.e. the graph was exhausted). The
merged frontier adds every live delta point (so none is "unexplored") and
drops tombstones (which only shrinks the feasible set). The re-audit
certifies with ``min_value > max(s_K_merged, s_K_engine)`` — the engine's
bound still covers unexplored graph points even when delta points extend
the frontier below it.
"""
from __future__ import annotations

import dataclasses
import threading

import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.core import theorems
from repro.core.graph import FlatGraph, make_flat_graph
from repro.core.pgs import DiverseResult
from repro.kernels import ops as kops


class DeltaFull(RuntimeError):
    """The delta segment overflowed its hard limit while a rebuild was
    still pending — writes are arriving faster than rebuilds retire them.
    Back off, or raise ``delta_capacity``."""


def _compact_served(ids, scores, live):
    """Keep the served set's order, drop dead rows, pad with -1 at the end."""
    k = ids.shape[0]
    keep = np.flatnonzero(live)
    out_ids = np.full(k, -1, np.int32)
    out_sc = np.zeros(k, np.float32)
    out_ids[: keep.size] = ids[keep]
    out_sc[: keep.size] = scores[keep]
    return out_ids, out_sc


class MutableIndex:
    """Append-only corpus + delta segment + deletion bitmap + epoch'd
    search structure (``FlatGraph`` or ``ShardedIndex``).

    Ids are **positional and stable**: row ``i`` of the float buffer is id
    ``i`` forever (upserts append, deletes tombstone, rebuilds keep dead
    rows in place). ``shards`` corpora are padded with tombstoned zero rows
    so every epoch splits evenly across the mesh.
    """

    def __init__(self, vectors=None, metric: str = "l2", *,
                 graph: FlatGraph | None = None,
                 delta_capacity: int = 256, M: int = 16,
                 builder: str = "knng", shards: int | None = None,
                 shard_align: int | None = None,
                 quantized: str | None = None, scale_rows: int = 8,
                 background: bool = True, seed: int = 0):
        if builder not in ("knng", "hnsw"):
            raise ValueError(f"unknown builder {builder!r}")
        if quantized is not None and builder == "hnsw" and not shards:
            raise ValueError(
                "quantized single-host graphs are level-0 only "
                "(make_flat_graph) — use builder='knng'")
        if delta_capacity < 1:
            raise ValueError(f"delta_capacity={delta_capacity} must be >= 1")
        if graph is not None:
            if vectors is not None:
                raise ValueError("pass either vectors or graph=, not both")
            if shards:
                raise ValueError("a sharded index is built from vectors — "
                                 "pass vectors=, not a single-host graph")
            if quant.is_quantized(graph.vectors):
                raise ValueError(
                    "the mutable layer needs the exact float corpus "
                    "(certificates and rebuilds rescore it; contract 13) — "
                    "pass quantized= and the float vectors instead")
            base = np.asarray(graph.vectors, np.float32)
            metric = graph.metric
        else:
            if vectors is None:
                raise ValueError("MutableIndex needs vectors or graph=")
            base = np.asarray(vectors, np.float32)
        if base.ndim != 2:
            raise ValueError("vectors must be a float [n, d] corpus")
        self.metric = str(metric)
        self.d = int(base.shape[1])
        self.delta_capacity = int(delta_capacity)
        self.M = int(M)
        self.builder = builder
        self.shards = int(shards) if shards else None
        #: elastic alignment: pad epochs to divisibility by the LARGEST
        #: shard count the serving layer may rescale to, so every prepared
        #: target splits the same rows evenly (defaults to ``shards``)
        self.shard_align = int(shard_align) if shard_align else None
        if self.shard_align is not None:
            if not self.shards:
                raise ValueError("shard_align only applies to sharded "
                                 "corpora (pass shards=)")
            if self.shard_align % self.shards:
                raise ValueError(
                    f"shard_align={self.shard_align} must be a multiple of "
                    f"shards={self.shards}")
        self.quantized = quantized
        self.scale_rows = int(scale_rows)
        self.background = bool(background)
        self.seed = int(seed)
        # append-only storage (amortized-doubling buffer); row index == id
        n = int(base.shape[0])
        cap = max(64, 1 << int(np.ceil(np.log2(max(n + delta_capacity, 1)))))
        self._vecs = np.zeros((cap, self.d), np.float32)
        self._vecs[:n] = base
        self._del = np.zeros(cap, bool)
        self._n = n
        self.epoch = 0
        #: bumps on every write and on every swap — the one-token snapshot
        #: tag results/benchmarks key corpus state by
        self.version = 0
        self.rebuilds = 0
        #: set on the first write and never cleared (tombstones persist
        #: across swaps); while False, harvests take the bit-exact fast path
        self.mutated = False
        self.num_deleted = 0
        if self.shards is not None:
            self._pad_for_shards()
        #: first id NOT covered by the current epoch's structure — rows at
        #: ``[delta_start, n)`` are the delta segment
        self.delta_start = self._n
        self._pending: tuple[int, object] | None = None
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        self._delta_codes: tuple[int, object] | None = None
        if self.shards is not None:
            self.graph = None
            self.sharded = self._build(self._vecs[:self._n].copy())
        else:
            self.sharded = None
            self.graph = (self._wrap_quantized(base, graph)
                          if graph is not None
                          else self._build(base))

    # -- views ---------------------------------------------------------------
    @property
    def n_total(self) -> int:
        return self._n

    @property
    def deleted(self) -> np.ndarray:
        """Live deletion bitmap (bool[n_total] view)."""
        return self._del[:self._n]

    @property
    def delta_count(self) -> int:
        return self._n - self.delta_start

    @property
    def live_count(self) -> int:
        return self._n - self.num_deleted

    def float_view(self) -> np.ndarray:
        """The exact float corpus, all epochs + delta ([n_total, d] view)."""
        return self._vecs[:self._n]

    def delta_ids(self) -> np.ndarray:
        """Live (non-tombstoned) ids in the delta segment."""
        tail = np.arange(self.delta_start, self._n, dtype=np.int64)
        return tail[~self._del[self.delta_start:self._n]]

    def stats(self) -> dict:
        return dict(n_total=self._n, live=self.live_count,
                    deleted=self.num_deleted, delta=self.delta_count,
                    delta_capacity=self.delta_capacity, epoch=self.epoch,
                    version=self.version, rebuilds=self.rebuilds,
                    rebuild_pending=self.swap_ready()
                    or (self._thread is not None and self._thread.is_alive()))

    # -- writes --------------------------------------------------------------
    def _grow(self, extra: int) -> None:
        need = self._n + extra
        if need <= self._vecs.shape[0]:
            return
        cap = self._vecs.shape[0]
        while cap < need:
            cap *= 2
        vecs = np.zeros((cap, self.d), np.float32)
        vecs[:self._n] = self._vecs[:self._n]
        dele = np.zeros(cap, bool)
        dele[:self._n] = self._del[:self._n]
        self._vecs, self._del = vecs, dele

    def upsert(self, vectors) -> np.ndarray:
        """Append fresh vectors; returns their assigned ids (int64[m]).

        Ids are always fresh — replacing an existing id is
        ``delete([id])`` + ``upsert(new_vector)``. Filling the delta past
        ``delta_capacity`` auto-requests a rebuild; past four capacities
        with a rebuild still pending it raises ``DeltaFull``.
        """
        vecs = np.asarray(vectors, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None]
        if vecs.ndim != 2 or vecs.shape[1] != self.d:
            raise ValueError(f"upsert expects [m, {self.d}] vectors")
        m = int(vecs.shape[0])
        if self.delta_count + m > 4 * self.delta_capacity:
            raise DeltaFull(
                f"delta {self.delta_count}+{m} past 4x capacity "
                f"{self.delta_capacity} with a rebuild still pending")
        self._grow(m)
        ids = np.arange(self._n, self._n + m, dtype=np.int64)
        self._vecs[self._n:self._n + m] = vecs
        self._del[self._n:self._n + m] = False
        self._n += m
        self.version += 1
        self.mutated = True
        self._delta_codes = None
        if self.delta_count >= self.delta_capacity:
            self.request_rebuild()
        return ids

    def delete(self, ids) -> int:
        """Tombstone ids in the live bitmap; returns how many were newly
        deleted. Unknown ids raise (a delete must never silently no-op)."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        if ids.size == 0:
            return 0
        if (ids < 0).any() or (ids >= self._n).any():
            raise KeyError(f"delete of unknown id(s) outside [0, {self._n})")
        newly = int((~self._del[ids]).sum())
        self._del[ids] = True
        self.num_deleted += newly
        self.version += 1
        self.mutated = True
        self._delta_codes = None
        return newly

    # -- delta scoring (kernels/ops ladder) ----------------------------------
    def _delta_int8(self, ids: np.ndarray):
        """Int8 codes for the live delta rows (rebuilt lazily per write)."""
        key = self.version
        if self._delta_codes is not None and self._delta_codes[0] == key:
            return self._delta_codes[1]
        corp = quant.quantize_corpus(self._vecs[ids], "int8",
                                     scale_rows=self.scale_rows)
        self._delta_codes = (key, corp)
        return corp

    def score_delta(self, q, *, impl: str | None = None):
        """Flat-score the live delta segment: ``(ids, float_scores)``.

        Always one batched dispatch through the ``kernels/ops`` ladder.
        ``quantized="int8"`` corpora also run the int8 rung
        (``quantized_similarity_many`` over the delta codes — the
        bandwidth-realistic path a capped prefilter would rank by), but the
        returned scores are the exact float rerank of every live delta row:
        certificates never see a quantized score (contract 13), and the
        fixed capacity keeps "all rows" cheap by construction.
        """
        ids = self.delta_ids()
        if ids.size == 0:
            return ids, np.zeros(0, np.float32)
        q32 = np.asarray(q, np.float32).reshape(-1)
        if self.quantized == "int8":
            kops.quantized_similarity_many(
                jnp.asarray(q32)[None], self._delta_int8(ids), self.metric,
                impl=impl)
        sc = np.asarray(kops.batch_similarity(
            jnp.asarray(q32), jnp.asarray(self._vecs[ids]), self.metric,
            impl=impl), np.float32)
        return ids, sc

    # -- harvest-time merge + audit ------------------------------------------
    def audit_frontier(self, q, k: int, eps: float, cand_ids,
                       cand_scores=None, *, max_expansions: int = 100_000,
                       impl: str | None = None):
        """Merge a recorded frontier with the live delta, apply the bitmap,
        and re-run the Theorem-2 audit against the live corpus.

        ``cand_scores=None`` rescores the frontier rows against ``q`` (the
        semantic cache's revalidation path, where the query drifted);
        otherwise the scores are trusted as ``q``'s exact float scores.
        Returns ``(certified, sel_ids[k], sel_scores[k], merged_ids,
        merged_scores, slack)`` — certification uses
        ``max(s_K_merged, s_K_frontier)`` so the engine's bound on
        unexplored graph points survives delta points extending the
        frontier below it.
        """
        q32 = np.asarray(q, np.float32).reshape(-1)
        cand_ids = np.asarray(cand_ids, np.int64).reshape(-1)
        valid = (cand_ids >= 0) & (cand_ids < self._n)
        # padding in the recorded frontier == the graph was exhausted, so
        # there are no unexplored graph points to bound (s_K = -inf)
        exhausted = cand_ids.size == 0 or bool((cand_ids < 0).any())
        g_ids = cand_ids[valid]
        if cand_scores is None:
            g_sc = (np.asarray(kops.batch_similarity(
                jnp.asarray(q32), jnp.asarray(self._vecs[g_ids]),
                self.metric, impl=impl), np.float32)
                if g_ids.size else np.zeros(0, np.float32))
        else:
            g_sc = np.asarray(cand_scores, np.float32).reshape(-1)[valid]
        s_K_bound = (-np.inf if exhausted or g_ids.size == 0
                     else float(g_sc.min()))
        live = ~self._del[g_ids] if g_ids.size else np.zeros(0, bool)
        g_ids, g_sc = g_ids[live], g_sc[live]
        d_ids, d_sc = self.score_delta(q32, impl=impl)
        if d_ids.size and g_ids.size:
            fresh = ~np.isin(d_ids, g_ids)  # post-write frontiers may
            d_ids, d_sc = d_ids[fresh], d_sc[fresh]  # already hold delta ids
        ids = np.concatenate([g_ids, d_ids])
        sc = np.concatenate([g_sc, d_sc]).astype(np.float32)
        if ids.size == 0:
            return (False, np.full(k, -1, np.int32),
                    np.zeros(k, np.float32), ids.astype(np.int32), sc,
                    -np.inf)
        order = np.lexsort((ids, -sc))   # score desc, id asc (repo-wide tie)
        ids, sc = ids[order], sc[order]
        cert_a, sel_ids, min_value, s_K_a = theorems.theorem2_audit(
            self.float_view(), self.metric, ids, sc, eps, k,
            max_expansions=max_expansions)
        if (sel_ids < 0).all():
            # deletions can leave fewer than k live candidates (or no
            # feasible size-k diverse set): serve the largest feasible
            # diverse set instead of nothing — never certified at k
            k_eff = min(k - 1, int(ids.size))
            while k_eff >= 1:
                _, sel_small, _, _ = theorems.theorem2_audit(
                    self.float_view(), self.metric, ids, sc, eps, k_eff,
                    max_expansions=max_expansions)
                if not (sel_small < 0).all():
                    sel_ids = np.concatenate(
                        [sel_small,
                         np.full(k - k_eff, -1, sel_small.dtype)])
                    break
                k_eff -= 1
            cert_a, min_value = False, -np.inf
        s_K_eff = max(s_K_a, s_K_bound)
        certified = bool(cert_a and min_value > s_K_eff)
        slack = float(min_value - s_K_eff)
        score_of = dict(zip(ids.tolist(), sc.tolist()))
        sel_sc = np.asarray([score_of.get(int(i), 0.0) if i >= 0 else 0.0
                             for i in sel_ids], np.float32)
        return (certified, sel_ids.astype(np.int32), sel_sc,
                ids.astype(np.int32), sc, slack)

    def finalize(self, q, k: int, eps: float, result: DiverseResult,
                 frontier, *, max_expansions: int = 100_000,
                 impl: str | None = None):
        """Post-process one harvested lane against the live corpus view.

        Returns ``(result, (merged_ids, merged_scores, slack_or_None),
        meta)`` where ``meta = dict(epoch=..., version=...)`` tags the
        snapshot the result is valid against. With no writes ever applied
        the engine's output passes through bit-exactly.
        """
        meta = dict(epoch=self.epoch, version=self.version)
        if not self.mutated and frontier is not None:
            rec = (np.asarray(frontier[0]), np.asarray(frontier[1]),
                   frontier[2] if len(frontier) > 2 else None)
            return result, rec, meta
        if frontier is None:
            # no recorded certificate frontier (e.g. a pgs lane finishing
            # in-round): bitmap-filter the served set; the delta cannot be
            # merged without a frontier, so any mutation voids the
            # certificate rather than over-claiming
            ids = np.asarray(result.ids)
            live = (ids >= 0) & ~self._del[np.maximum(ids, 0)]
            if not self.mutated or (live == (ids >= 0)).all():
                certified = result.stats.certified and self.delta_count == 0
                if certified == result.stats.certified:
                    return result, None, meta
                stats = dataclasses.replace(result.stats, certified=False)
                return (DiverseResult(result.ids, result.scores,
                                      result.total, stats), None, meta)
            out_ids, out_sc = _compact_served(
                ids, np.asarray(result.scores, np.float32), live)
            stats = dataclasses.replace(result.stats, certified=False)
            return (DiverseResult(out_ids, out_sc, float(out_sc.sum()),
                                  stats), None, meta)
        certified, sel_ids, sel_sc, m_ids, m_sc, slack = self.audit_frontier(
            q, k, eps, frontier[0], frontier[1],
            max_expansions=max_expansions, impl=impl)
        stats = dataclasses.replace(result.stats, certified=certified,
                                    div_calls=result.stats.div_calls + 1)
        res = DiverseResult(sel_ids, sel_sc, float(sel_sc.sum()), stats)
        return res, (m_ids, m_sc, slack if certified else None), meta

    # -- rebuild + epoch swap ------------------------------------------------
    def _pad_for_shards(self) -> None:
        pad = (-self._n) % (self.shard_align or self.shards)
        if pad:
            self._grow(pad)
            self._del[self._n:self._n + pad] = True  # permanent tombstones
            self.num_deleted += pad
            self._n += pad

    def _wrap_quantized(self, snap: np.ndarray, g: FlatGraph) -> FlatGraph:
        if self.quantized is None:
            return g
        corp = quant.quantize_corpus(snap, self.quantized,
                                     scale_rows=self.scale_rows,
                                     seed=self.seed)
        return make_flat_graph(corp, np.asarray(g.neighbors), None,
                               int(g.entry), self.metric)

    def _build(self, snap: np.ndarray):
        """Build the epoch structure over a row snapshot (thread-safe: pure
        function of ``snap``; tombstoned rows stay in place so ids remain
        positional)."""
        if self.shards is not None:
            from repro.sharded_search import build_sharded_index
            return build_sharded_index(
                snap, self.shards, self.metric, M=self.M,
                builder=self.builder, quantized=self.quantized,
                scale_rows=self.scale_rows, seed=self.seed)
        if self.builder == "hnsw":
            from repro.index.hnsw import build_hnsw
            g = build_hnsw(snap, self.metric, M=self.M, seed=self.seed)
        else:
            from repro.index.flat import build_knn_graph
            g = build_knn_graph(snap, self.metric, M=self.M, seed=self.seed)
        return self._wrap_quantized(snap, g)

    def request_rebuild(self, *, background: bool | None = None) -> bool:
        """Kick off a rebuild over the current rows; returns True if one was
        started (False: one is already running or awaiting its swap).

        ``background=True`` builds on a thread (numpy's BLAS releases the
        GIL, so serving keeps pumping); the built structure is *installed*
        only by ``install_swap`` — the serving layer's between-rounds
        barrier — never here.
        """
        with self._lock:
            if self._pending is not None:
                return False
            if self._thread is not None and self._thread.is_alive():
                return False
        if self.shards is not None:
            self._pad_for_shards()
        n_snap = self._n
        snap = self._vecs[:n_snap].copy()

        def work():
            art = self._build(snap)
            with self._lock:
                self._pending = (n_snap, art)

        if self.background if background is None else background:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
        return True

    def wait_rebuild(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def swap_ready(self) -> bool:
        with self._lock:
            return self._pending is not None

    def install_swap(self):
        """Adopt the pending structure as the new epoch; returns it.

        Callers (``MutableBackend.maybe_swap``) must hold the engine idle —
        this only flips the index's own pointers.
        """
        with self._lock:
            if self._pending is None:
                raise RuntimeError("no rebuilt structure pending")
            n_snap, art = self._pending
            self._pending = None
        if self.shards is not None:
            self.sharded = art
        else:
            self.graph = art
        self.delta_start = n_snap
        self.epoch += 1
        self.version += 1
        self.rebuilds += 1
        self._delta_codes = None
        return art


class MutableBackend:
    """``LaneBackend`` decorator adding the write path to any engine.

    Delegates the protocol to the wrapped engine and adds, at harvest, the
    live merge (``MutableIndex.finalize``: bitmap filter + delta merge +
    Theorem-2 re-audit), publishing the *merged* frontier in its own
    ``last_candidates`` so cache admission sees live-valid certificates.
    ``free_lanes`` is the epoch-swap barrier: while a rebuilt structure is
    pending it admits nothing, lets in-flight lanes drain, and installs the
    swap on the idle engine between rounds (contract 15).
    """

    def __init__(self, inner, index: MutableIndex):
        self.inner = inner
        self.mutable_index = index
        inner.record_candidates = True
        self.last_candidates: list = [None] * int(inner.num_lanes)
        #: per-lane ``dict(epoch=..., version=...)`` snapshot tag of the
        #: last finalized harvest (audits key corpus state by it)
        self.last_meta: list = [None] * int(inner.num_lanes)
        self.swaps = 0
        self._reqs: dict[int, object] = {}

    # -- protocol delegation -------------------------------------------------
    @property
    def num_lanes(self) -> int:
        return self.inner.num_lanes

    @property
    def max_k(self) -> int:
        return self.inner.max_k

    @property
    def default_ef(self) -> int:
        return self.inner.default_ef

    @property
    def methods(self):
        return self.inner.methods

    @property
    def compressed(self) -> bool:
        return self.inner.compressed

    @property
    def bytes_per_vector(self) -> float:
        return self.inner.bytes_per_vector

    @property
    def signature_log(self):
        return self.inner.signature_log

    @property
    def record_candidates(self) -> bool:
        return True

    @record_candidates.setter
    def record_candidates(self, value) -> None:
        pass   # the merge *requires* frontiers; the inner flag stays True

    def active_count(self) -> int:
        return self.inner.active_count()

    def step(self):
        return self.inner.step()

    def prewarm(self, **kw) -> None:
        self.inner.prewarm(**kw)

    # -- elastic delegation (only when the inner engine is rescalable) -------
    def __getattr__(self, name):
        # defined dynamically so a MutableBackend over a non-rescalable
        # engine does NOT satisfy core.backend.RescalableBackend — the
        # runtime_checkable isinstance probes these attributes
        if name in ("num_shards", "prepare_rescale", "rescale_options"):
            return getattr(self.inner, name)
        if name == "rescale":
            inner_rescale = self.inner.rescale

            def rescale(shards: int) -> bool:
                ok = inner_rescale(shards)
                if ok and self.mutable_index.shards is not None:
                    # future rebuilds must target the mesh now serving
                    self.mutable_index.shards = int(shards)
                    self.mutable_index.sharded = self.inner.index
                if ok:
                    # lane count may follow the mesh: mirror the merged
                    # frontier bookkeeping onto the new width
                    B = int(self.inner.num_lanes)
                    for lst in (self.last_candidates, self.last_meta):
                        del lst[B:]
                        lst.extend([None] * (B - len(lst)))
                return ok

            return rescale
        raise AttributeError(name)

    # -- the write-aware surface ---------------------------------------------
    def maybe_swap(self) -> bool:
        """Install a pending epoch swap if the engine is idle (between
        rounds, no occupied lanes); returns True when a swap landed."""
        if not self.mutable_index.swap_ready():
            return False
        if self.inner.active_count():
            return False
        art = self.mutable_index.install_swap()
        if self.mutable_index.shards is not None:
            # the engine's rerank corpus is the epoch snapshot — rows the
            # new index covers, not newer delta rows appended since
            n_epoch = art.num_shards * art.shard_size
            if art.num_shards != getattr(self.inner, "num_shards",
                                         art.num_shards):
                # a rescale landed while the background rebuild ran: the
                # rebuilt epoch targets the old mesh — repartition it onto
                # the serving shard count (same rows, exact re-blocking)
                from repro.sharded_search.search import reshard_index
                art = reshard_index(
                    art, int(self.inner.num_shards),
                    self.mutable_index.float_view()[:n_epoch],
                    M=self.mutable_index.M,
                    builder=self.mutable_index.builder)
                self.mutable_index.sharded = art
                self.mutable_index.shards = int(self.inner.num_shards)
            self.inner.swap_index(
                art, self.mutable_index.float_view()[:n_epoch])
        else:
            self.inner.swap_graph(art)
        self.swaps += 1
        return True

    def free_lanes(self):
        if self.mutable_index.swap_ready() and not self.maybe_swap():
            return np.zeros(0, np.int64)   # drain: swap barrier is pending
        return self.inner.free_lanes()

    def admit(self, lane: int, request) -> None:
        self._reqs[int(lane)] = request
        self.inner.admit(lane, request)

    def harvest(self):
        out = []
        for lane, result in self.inner.harvest():
            req = self._reqs.get(int(lane))
            frontier = self.inner.last_candidates[lane]
            res, merged, meta = self.mutable_index.finalize(
                req.q, int(req.k), float(req.eps), result, frontier)
            self.last_candidates[int(lane)] = merged
            self.last_meta[int(lane)] = meta
            out.append((lane, res))
        return out

    def recycle(self, lane: int) -> None:
        self._reqs.pop(int(lane), None)
        self.inner.recycle(lane)
