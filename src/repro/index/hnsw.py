"""HNSW proximity-graph builder (offline stage, numpy — see DESIGN.md §2).

Faithful to Malkov & Yashunin (the paper's index of choice, §II-A): geometric
level assignment with mL = 1/ln(M), ef_construction beam search per insert,
heuristic neighbor selection (Alg. 4 of the HNSW paper), bidirectional links
with degree-capped pruning, M0 = 2M at level 0.

Output is the flat, fixed-shape representation ``repro.core.graph.FlatGraph``
consumed by the JAX searchers. Construction is deterministic given the seed.

Similarity convention matches the paper (higher = more similar) for all
three metric spaces.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import FlatGraph, make_flat_graph


def _pairwise(x: np.ndarray, metric: str) -> np.ndarray:
    dots = x @ x.T
    if metric == "ip":
        return dots
    if metric == "cos":
        n = np.maximum(np.sqrt(np.einsum("nd,nd->n", x, x)), 1e-12)
        return dots / (n[:, None] * n[None, :])
    if metric == "l2":
        sq = np.einsum("nd,nd->n", x, x)
        d2 = np.maximum(sq[:, None] + sq[None, :] - 2.0 * dots, 0.0)
        return 1.0 - np.sqrt(d2)
    raise ValueError(metric)


def _sims(q: np.ndarray, x: np.ndarray, metric: str) -> np.ndarray:
    dots = x @ q
    if metric == "ip":
        return dots
    if metric == "cos":
        qn = max(float(np.sqrt(q @ q)), 1e-12)
        xn = np.maximum(np.sqrt(np.einsum("nd,nd->n", x, x)), 1e-12)
        return dots / (qn * xn)
    if metric == "l2":
        d2 = np.maximum(q @ q + np.einsum("nd,nd->n", x, x) - 2.0 * dots, 0.0)
        return 1.0 - np.sqrt(d2)
    raise ValueError(metric)


@dataclasses.dataclass
class HNSWBuilder:
    vectors: np.ndarray
    metric: str = "l2"
    M: int = 16
    ef_construction: int = 200
    seed: int = 0

    def __post_init__(self):
        self.vectors = np.asarray(self.vectors, np.float32)
        self.N, self.d = self.vectors.shape
        self.M0 = 2 * self.M
        self.mL = 1.0 / np.log(self.M)
        rng = np.random.default_rng(self.seed)
        self.levels = np.minimum(
            (-np.log(rng.uniform(size=self.N, low=1e-12, high=1.0))
             * self.mL).astype(np.int64), 12)
        # adjacency per level: dict level -> {node: list[int]}
        self.adj: list[dict[int, list[int]]] = [
            {} for _ in range(int(self.levels.max()) + 1)]
        self.entry = -1
        self.max_level = -1

    # -- search-layer (HNSW Alg. 2), numpy + heapq --------------------------
    def _search_layer(self, q: np.ndarray, entry: int, ef: int,
                      level: int) -> tuple[np.ndarray, np.ndarray]:
        import heapq

        adj = self.adj[level]
        visited = {entry}
        e_sim = float(_sims(q, self.vectors[entry][None, :], self.metric)[0])
        cand = [(-e_sim, entry)]       # max-heap on sim
        result = [(e_sim, entry)]      # min-heap on sim, size <= ef
        while cand:
            neg_sim, node = heapq.heappop(cand)
            if -neg_sim < result[0][0] and len(result) >= ef:
                break
            nbrs = [x for x in adj.get(node, []) if x not in visited]
            if not nbrs:
                continue
            visited.update(nbrs)
            sims = _sims(q, self.vectors[nbrs], self.metric)
            worst = result[0][0]
            for x, s in zip(nbrs, sims):
                s = float(s)
                if len(result) < ef or s > worst:
                    heapq.heappush(cand, (-s, x))
                    heapq.heappush(result, (s, x))
                    if len(result) > ef:
                        heapq.heappop(result)
                    worst = result[0][0]
        result.sort(key=lambda t: (-t[0], t[1]))
        ids = np.array([r[1] for r in result], np.int64)
        ss = np.array([r[0] for r in result], np.float64)
        return ids, ss

    # -- heuristic neighbor selection (HNSW Alg. 4) -------------------------
    def _select_neighbors(self, cand_ids: np.ndarray, cand_sims: np.ndarray,
                          m: int) -> list[int]:
        cand_ids = np.asarray(cand_ids, np.int64)
        # one batched Gram among candidates instead of per-candidate calls
        pair = None
        chosen: list[int] = []
        chosen_pos: list[int] = []
        for pos, (cid, csim) in enumerate(zip(cand_ids, cand_sims)):
            if len(chosen) >= m:
                break
            if not chosen:
                chosen.append(int(cid))
                chosen_pos.append(pos)
                continue
            if pair is None:
                pair = _pairwise(self.vectors[cand_ids], self.metric)
            # keep if closer to q than to any already-chosen neighbor
            if np.all(pair[pos, chosen_pos] < csim):
                chosen.append(int(cid))
                chosen_pos.append(pos)
        # backfill with remaining best if heuristic under-selects
        if len(chosen) < m:
            for cid in cand_ids:
                if int(cid) not in chosen:
                    chosen.append(int(cid))
                    if len(chosen) >= m:
                        break
        return chosen

    def _link(self, node: int, nbrs: list[int], level: int):
        adj = self.adj[level]
        cap = self.M0 if level == 0 else self.M
        adj[node] = list(nbrs[:cap])
        for nb in nbrs:
            lst = adj.setdefault(nb, [])
            lst.append(node)
            if len(lst) > cap:
                sims = _sims(self.vectors[nb], self.vectors[lst], self.metric)
                order = np.argsort(-sims, kind="stable")
                sel = self._select_neighbors(
                    np.array(lst)[order], sims[order], cap)
                adj[nb] = sel

    def insert(self, i: int):
        lvl = int(self.levels[i])
        if self.entry < 0:
            self.entry = i
            self.max_level = lvl
            for l in range(lvl + 1):
                self.adj[l][i] = []
            return
        cur = self.entry
        # greedy descent above the node's level
        for l in range(self.max_level, lvl, -1):
            changed = True
            cur_sim = float(_sims(self.vectors[i],
                                  self.vectors[cur][None, :], self.metric)[0])
            while changed:
                changed = False
                nbrs = self.adj[l].get(cur, [])
                if nbrs:
                    sims = _sims(self.vectors[i], self.vectors[nbrs],
                                 self.metric)
                    j = int(np.argmax(sims))
                    if sims[j] > cur_sim:
                        cur, cur_sim, changed = nbrs[j], float(sims[j]), True
        # beam-search insert at each level from min(lvl, max_level) down
        for l in range(min(lvl, self.max_level), -1, -1):
            ids, sims = self._search_layer(self.vectors[i], cur,
                                           self.ef_construction, l)
            m = self.M0 if l == 0 else self.M
            nbrs = self._select_neighbors(ids, sims, m)
            self._link(i, nbrs, l)
            cur = int(ids[0])
        if lvl > self.max_level:
            for l in range(self.max_level + 1, lvl + 1):
                self.adj[l][i] = []
            self.max_level = lvl
            self.entry = i

    def build(self, order: np.ndarray | None = None) -> FlatGraph:
        if order is None:
            order = np.arange(self.N)
        for i in order:
            self.insert(int(i))
        return self.export()

    def export(self) -> FlatGraph:
        nbr0 = np.full((self.N, self.M0), -1, np.int32)
        for node, lst in self.adj[0].items():
            lst = lst[: self.M0]
            nbr0[node, : len(lst)] = lst
        n_up = self.max_level  # levels 1..max_level
        if n_up > 0:
            upper = np.full((n_up, self.N, self.M), -1, np.int32)
            for l in range(1, self.max_level + 1):
                # upper[0] must be the TOP level for FlatGraph.descend
                row = self.max_level - l
                for node, lst in self.adj[l].items():
                    lst = lst[: self.M]
                    upper[row, node, : len(lst)] = lst
        else:
            upper = np.zeros((0, self.N, 1), np.int32)
        return make_flat_graph(self.vectors, nbr0, upper, self.entry,
                               self.metric)


def build_hnsw(vectors: np.ndarray, metric: str = "l2", M: int = 16,
               ef_construction: int = 200, seed: int = 0) -> FlatGraph:
    return HNSWBuilder(vectors, metric, M, ef_construction, seed).build()
