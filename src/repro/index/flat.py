"""Exact (brute-force) index — the recall/ground-truth oracle.

Also provides the fast KNN-graph proximity index (``build_knn_graph``): exact
top-(M+1) neighbors via blocked matmul + Vamana-style alpha pruning + reverse
edges. Functionally comparable to HNSW level-0 but built in O(N^2 d / block)
vectorized work, which is what the 1-core container can afford at N >= 50k
(DESIGN.md §2). Both emit ``FlatGraph`` so every searcher runs on either.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import FlatGraph, make_flat_graph


def _sims_block(q_block: np.ndarray, x: np.ndarray, metric: str) -> np.ndarray:
    dots = q_block @ x.T
    if metric == "ip":
        return dots
    if metric == "cos":
        qn = np.maximum(np.linalg.norm(q_block, axis=1, keepdims=True), 1e-12)
        xn = np.maximum(np.linalg.norm(x, axis=1), 1e-12)
        return dots / (qn * xn[None, :])
    if metric == "l2":
        q2 = np.einsum("nd,nd->n", q_block, q_block)[:, None]
        x2 = np.einsum("nd,nd->n", x, x)[None, :]
        return 1.0 - np.sqrt(np.maximum(q2 + x2 - 2.0 * dots, 0.0))
    raise ValueError(metric)


def exact_topk(queries: np.ndarray, x: np.ndarray, k: int, metric: str,
               block: int = 256) -> tuple[np.ndarray, np.ndarray]:
    """Exact top-k (ids, scores) per query; deterministic id tie-break."""
    queries = np.atleast_2d(np.asarray(queries, np.float32))
    out_ids = np.empty((queries.shape[0], k), np.int32)
    out_scores = np.empty((queries.shape[0], k), np.float32)
    for s in range(0, queries.shape[0], block):
        sims = _sims_block(queries[s:s + block], x, metric)
        # lexicographic: score desc, id asc
        order = np.lexsort((np.arange(x.shape[0])[None, :].repeat(
            sims.shape[0], 0), -sims), axis=1)[:, :k]
        out_ids[s:s + block] = order
        out_scores[s:s + block] = np.take_along_axis(sims, order, axis=1)
    return out_ids, out_scores


def exact_rerank(queries: np.ndarray, cand_ids: np.ndarray, x: np.ndarray,
                 metric: str) -> tuple[np.ndarray, np.ndarray]:
    """Exact float rerank of candidate frontiers (the quantized path's
    score-then-verify stage).

    ``queries`` f32[B, d], ``cand_ids`` int[B, K] (-1 padded), ``x``
    f32[N, d] the float corpus. Each row's valid candidates are re-scored
    with exact float similarity and re-sorted descending by score with
    ascending-id tie-break (the same order ``exact_topk`` and the
    tournament merge use); -1 entries keep score -inf and sink to the
    tail. Returns ``(ids int32[B, K], scores f32[B, K])``.
    """
    queries = np.atleast_2d(np.asarray(queries, np.float32))
    cand_ids = np.asarray(cand_ids, np.int64)
    b, k = cand_ids.shape
    out_ids = np.full((b, k), -1, np.int32)
    out_scores = np.full((b, k), -np.inf, np.float32)
    for r in range(b):
        valid = cand_ids[r] >= 0
        ids = cand_ids[r][valid]
        if ids.size == 0:
            continue
        sims = _sims_block(queries[r][None], x[ids], metric)[0]
        order = np.lexsort((ids, -sims))
        out_ids[r, : ids.size] = ids[order]
        out_scores[r, : ids.size] = sims[order]
    return out_ids, out_scores


def build_knn_graph(vectors: np.ndarray, metric: str = "l2", M: int = 16,
                    alpha_sim: float = 1.0, block: int = 512,
                    seed: int = 0) -> FlatGraph:
    """Exact-KNN proximity graph with alpha pruning + reverse edges."""
    x = np.asarray(vectors, np.float32)
    n = x.shape[0]
    M0 = 2 * M
    overfetch = min(n - 1, 3 * M0)
    knn = np.empty((n, overfetch), np.int32)
    for s in range(0, n, block):
        sims = _sims_block(x[s:s + block], x, metric)
        rows = np.arange(s, min(s + block, n))
        sims[np.arange(rows.size), rows] = -np.inf  # drop self
        part = np.argpartition(-sims, overfetch, axis=1)[:, :overfetch]
        ps = np.take_along_axis(sims, part, axis=1)
        order = np.argsort(-ps, axis=1, kind="stable")
        knn[s:s + block] = np.take_along_axis(part, order, axis=1)

    neighbors = np.full((n, M0), -1, np.int32)
    for i in range(n):
        cands = knn[i]
        sims_q = _sims_block(x[i][None], x[cands], metric)[0]
        chosen: list[int] = []
        for cid, csim in zip(cands, sims_q):
            if len(chosen) >= M0:
                break
            if chosen:
                s_to = _sims_block(x[int(cid)][None], x[chosen], metric)[0]
                if np.any(s_to * alpha_sim >= csim):
                    continue
            chosen.append(int(cid))
        if len(chosen) < M0:
            for cid in cands:
                if int(cid) not in chosen:
                    chosen.append(int(cid))
                if len(chosen) >= M0:
                    break
        neighbors[i, : len(chosen)] = chosen

    # reverse edges into free slots (connectivity)
    free = (neighbors < 0).sum(axis=1)
    for i in range(n):
        for j in neighbors[i]:
            if j < 0:
                break
            if free[j] > 0 and i not in neighbors[j]:
                neighbors[j, M0 - free[j]] = i
                free[j] -= 1

    # medoid entry point
    mean = x.mean(axis=0)
    entry = int(np.argmax(_sims_block(mean[None], x, metric)[0]))

    # --- connectivity repair -------------------------------------------
    # Pure nearest-neighbor edges fragment clustered data into islands
    # (every top-M neighbor is a cluster-mate). Stitch components together
    # through their closest cross-component pairs, bidirectionally, until
    # the graph is connected from the entry point.
    neighbors = _stitch_components(x, neighbors, entry, metric)
    neighbors = _directed_repair(x, neighbors, entry, knn, metric)
    return make_flat_graph(x, neighbors, None, entry, metric)


def _directed_reachable(neighbors: np.ndarray, entry: int) -> np.ndarray:
    n = neighbors.shape[0]
    reached = np.zeros(n, bool)
    reached[entry] = True
    frontier = np.array([entry])
    while frontier.size:
        nxt = neighbors[frontier].ravel()
        nxt = nxt[nxt >= 0]
        nxt = np.unique(nxt)
        nxt = nxt[~reached[nxt]]
        if nxt.size == 0:
            break
        reached[nxt] = True
        frontier = nxt
    return reached


def _directed_repair(x: np.ndarray, neighbors: np.ndarray, entry: int,
                     knn: np.ndarray, metric: str,
                     max_rounds: int = 32) -> np.ndarray:
    """Beam search follows directed edges; make every node entry-reachable.

    For each unreached node, add one in-edge from its nearest already
    reached KNN candidate (slot rotation spreads evictions); repeat until
    the directed BFS covers the graph.
    """
    n, m0 = neighbors.shape
    for _ in range(max_rounds):
        reached = _directed_reachable(neighbors, entry)
        missing = np.flatnonzero(~reached)
        if missing.size == 0:
            return neighbors
        reached_ids = np.flatnonzero(reached)
        for u in missing:
            cands = knn[u]
            rc = cands[reached[cands]]
            if rc.size:
                v = int(rc[0])
            else:
                sims = _sims_block(x[u][None], x[reached_ids], metric)[0]
                v = int(reached_ids[int(np.argmax(sims))])
            row = neighbors[v]
            if u in row:
                continue
            slot = np.flatnonzero(row < 0)
            idx = slot[0] if slot.size else (int(u) % m0)
            neighbors[v, idx] = u
    return neighbors


def _components(neighbors: np.ndarray) -> np.ndarray:
    """Undirected connected components over the adjacency (union-find)."""
    n = neighbors.shape[0]
    parent = np.arange(n)

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for i in range(n):
        for j in neighbors[i]:
            if j >= 0:
                ra, rb = find(i), find(int(j))
                if ra != rb:
                    parent[ra] = rb
    return np.array([find(i) for i in range(n)])


def _stitch_components(x: np.ndarray, neighbors: np.ndarray, entry: int,
                       metric: str, max_rounds: int = 64) -> np.ndarray:
    n, m0 = neighbors.shape
    for _ in range(max_rounds):
        comp = _components(neighbors)
        main = comp[entry]
        others = np.unique(comp[comp != main])
        if others.size == 0:
            return neighbors
        in_main = np.flatnonzero(comp == main)
        for c in others:
            members = np.flatnonzero(comp == c)
            # closest (member, main) pair via blocked sims
            best = (-np.inf, -1, -1)
            for s in range(0, members.size, 128):
                blk = members[s:s + 128]
                sims = _sims_block(x[blk], x[in_main], metric)
                flat = int(np.argmax(sims))
                bi, bj = divmod(flat, in_main.size)
                val = float(sims[bi, bj])
                if val > best[0]:
                    best = (val, int(blk[bi]), int(in_main[bj]))
            _, a, b = best
            for (u, v) in ((a, b), (b, a)):
                row = neighbors[u]
                slot = np.flatnonzero(row < 0)
                if slot.size:
                    neighbors[u, slot[0]] = v
                else:
                    neighbors[u, m0 - 1] = v  # overwrite weakest slot
    return neighbors
