"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import glob
import json
import os
import sys


def load_all(out_dir="results/dryrun", tag=""):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(path))
        if (r.get("tag") or "") != tag:
            continue
        rows.append(r)
    return rows


def fmt_table(rows, mesh="single"):
    hdr = ("| arch | shape | status | compute_s | memory_s | coll_s | "
           "bottleneck | MODEL_FLOPS | useful | roofline_frac | fits |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | skipped | - | - |"
                         f" - | - | - | - | - | - |")
            continue
        if r["status"] == "error":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | -"
                         f" | - | - | - | - | - |")
            continue
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {ro['compute_s']:.4f} | "
            f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
            f"{ro['bottleneck']} | {ro['model_flops']:.2e} | "
            f"{ro['useful_ratio']:.3f} | {ro['roofline_fraction']:.3f} | "
            f"{'Y' if r.get('fits_16gb_hbm') else 'N'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    tag = sys.argv[2] if len(sys.argv) > 2 else ""
    rows = load_all(tag=tag)
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(fmt_table(rows, mesh))
