"""Static analyzer for optimized (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified in this
container — a scanned 8-layer stack reports 1/8 of the unrolled FLOPs), so
layer-scanned models would be wildly under-counted. This analyzer walks the
HLO call graph instead and multiplies while bodies by their
``known_trip_count`` backend config, giving:

  * flops              — dot/convolution FLOPs (2*out*contraction)
  * collective_bytes   — per-device operand bytes of all-reduce/all-gather/
                         reduce-scatter/all-to-all/collective-permute
  * collective_breakdown — bytes per collective opcode
  * hbm_bytes          — fusion-boundary operand+output bytes (intra-fusion
                         traffic excluded): a standard HBM-traffic proxy

All numbers are per-device (the module is already partitioned).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CALL_RE = re.compile(
    r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_WINDOW_RE = re.compile(r"window=\{[^}]*size=([0-9x]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "copy-start", "copy-done", "after-all",
                   "partition-id", "replica-id", "iota"}


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str           # operand list + attrs (raw tail of the line)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    collective_bytes: float = 0.0
    hbm_bytes: float = 0.0
    collective_breakdown: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.collective_bytes += other.collective_bytes * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.collective_breakdown.items():
            self.collective_breakdown[k] += v * mult


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._parse(text)
        self._types: dict[str, str] = {}
        for comp in self.computations.values():
            for ins in comp:
                self._types[ins.name] = ins.type_str
        self._memo: dict[str, Cost] = {}

    def _parse(self, text: str):
        cur: list[Instr] | None = None
        comment = re.compile(r"/\*.*?\*/")
        for line in text.splitlines():
            stripped = comment.sub("", line).rstrip()
            if not stripped:
                continue
            hdr = _COMP_HDR_RE.match(stripped)
            if hdr and stripped.endswith("{"):
                name = hdr.group(2)
                cur = []
                self.computations[name] = cur
                if hdr.group(1):
                    self.entry = name
                continue
            if stripped.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(stripped)
            if m:
                cur.append(Instr(m.group(1), m.group(2), m.group(3),
                                 m.group(4)))

    # ------------------------------------------------------------- costs --
    def _operand_names(self, ins: Instr) -> list[str]:
        # operands come before the first "), " attr boundary; conservative:
        head = ins.rest.split("),", 1)[0]
        return [n for n in _OPERAND_RE.findall(head)
                if n in self._types]

    def _dot_flops(self, ins: Instr) -> float:
        out_elems = shape_elems(ins.type_str)
        ops = self._operand_names(ins)
        if not ops:
            return 0.0
        lhs_dims = shape_dims(self._types[ops[0]])
        m = _LHS_CDIMS_RE.search(ins.rest)
        contraction = 1
        if m and m.group(1):
            for d in m.group(1).split(","):
                contraction *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
        return 2.0 * out_elems * contraction

    def _conv_flops(self, ins: Instr) -> float:
        out = shape_dims(ins.type_str)
        if not out:
            return 0.0
        out_elems = shape_elems(ins.type_str)
        ops = self._operand_names(ins)
        kshape = shape_dims(self._types[ops[1]]) if len(ops) > 1 else []
        wm = _WINDOW_RE.search(ins.rest)
        window = 1
        if wm:
            for s in wm.group(1).split("x"):
                window *= int(s)
        out_features = out[-1] if out else 1
        kelems = 1
        for d in kshape:
            kelems *= d
        per_out = kelems / max(out_features, 1)
        return 2.0 * out_elems * max(per_out, window)

    _SLICY = {"dynamic-slice", "dynamic-update-slice", "gather", "scatter"}

    def _fusion_traffic(self, ins: Instr, callee_m) -> float:
        """HBM traffic of a fusion: boundary bytes, except for fusions whose
        body slices big loop-invariant tensors (stacked weights / remat
        stacks) — those read/write only the slice, so count the inner
        slice-level traffic instead of the full operand tensors."""
        boundary = shape_bytes(ins.type_str) + sum(
            shape_bytes(self._types[o]) for o in self._operand_names(ins))
        if not callee_m:
            return boundary
        body = self.computations.get(callee_m.group(1), [])
        if not any(i.opcode in self._SLICY for i in body):
            return boundary
        inner = 0.0
        for i in body:
            if i.opcode in ("dynamic-slice", "gather"):
                inner += 2 * shape_bytes(i.type_str)
            elif i.opcode == "dynamic-update-slice":
                ops_ = self._operand_names(i)
                upd = shape_bytes(self._types[ops_[1]]) if len(ops_) > 1 \
                    else 0
                inner += 2 * upd
            elif i.opcode == "scatter":
                ops_ = self._operand_names(i)
                if len(ops_) > 2:
                    inner += 2 * shape_bytes(self._types[ops_[2]])
        # plus the fusion's own root output if it is not a pure update alias
        root = body[-1] if body else None
        if root is not None and root.opcode not in self._SLICY:
            inner += shape_bytes(ins.type_str)
        return min(boundary, inner)

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        cost = Cost()
        self._memo[name] = cost  # guard cycles
        for ins in self.computations.get(name, []):
            op = ins.opcode
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                b = sum(shape_bytes(self._types[o])
                        for o in self._operand_names(ins))
                cost.collective_bytes += b
                cost.collective_breakdown[base] += b
                cost.hbm_bytes += b + shape_bytes(ins.type_str)
                continue
            if op == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                body = _CALL_RE.search(ins.rest)
                condm = _COND_RE.search(ins.rest)
                if body:
                    cost.add(self.comp_cost(body.group(1)), trip)
                if condm:
                    cost.add(self.comp_cost(condm.group(1)), trip)
                continue
            if op == "conditional":
                # attribute all branches once (upper bound: max would need
                # branch probabilities; branches here are tiny)
                for cname in _CALL_RE.findall(ins.rest):
                    cost.add(self.comp_cost(cname))
                continue
            if op in ("fusion", "call", "async-start"):
                callee = _CALL_RE.search(ins.rest)
                if callee:
                    sub = self.comp_cost(callee.group(1))
                    cost.flops += sub.flops
                    cost.collective_bytes += sub.collective_bytes
                    for k, v in sub.collective_breakdown.items():
                        cost.collective_breakdown[k] += v
                cost.hbm_bytes += self._fusion_traffic(ins, callee)
                continue
            if op in ("dynamic-slice", "gather"):
                cost.hbm_bytes += 2 * shape_bytes(ins.type_str)
                continue
            if op == "dynamic-update-slice":
                ops_ = self._operand_names(ins)
                upd = shape_bytes(self._types[ops_[1]]) if len(ops_) > 1 \
                    else shape_bytes(ins.type_str)
                cost.hbm_bytes += 2 * upd
                continue
            if op == "dot":
                cost.flops += self._dot_flops(ins)
            elif op == "convolution":
                cost.flops += self._conv_flops(ins)
            elif op in ("reduce", "reduce-window", "sort", "scatter",
                        "gather", "select-and-scatter"):
                cost.flops += shape_elems(ins.type_str)
            if op not in _SKIP_BYTES_OPS:
                cost.hbm_bytes += shape_bytes(ins.type_str) + sum(
                    shape_bytes(self._types[o])
                    for o in self._operand_names(ins))
        return cost

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    c = mod.entry_cost()
    return dict(
        flops=c.flops,
        collective_bytes=c.collective_bytes,
        hbm_bytes=c.hbm_bytes,
        collective_breakdown=dict(c.collective_breakdown),
    )
