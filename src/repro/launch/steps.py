"""Jitted train/serve step builders with mesh shardings (dry-run + runtime).

``build_train_step(cfg, mesh)``: full AdamW training step — loss, grads,
update — with params/opt-state donated and sharded per
``distributed.sharding``. ``build_serve_step(cfg, mesh)``: one-token decode
with donated KV cache. Both return (jitted_fn, abstract_inputs) so the
dry-run can ``.lower(**abstract).compile()`` without allocating anything.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCard
from repro.distributed import sharding as sh
from repro.models import model as M
from repro.train.optimizer import AdamW, AdamWState


def build_train_step(cfg: ModelConfig, mesh, *, optimizer: AdamW | None = None,
                     skip_future: bool = False, remat: bool = True,
                     opts: dict | None = None):
    opt = optimizer or AdamW()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch, remat=remat,
                                skip_future=skip_future, opts=opts))(params)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, loss

    aparams = M.abstract_params(cfg)
    aopt = jax.eval_shape(opt.init, aparams)
    pspec = sh.param_spec_tree(cfg, aparams, mesh,
                               fsdp=bool((opts or {}).get("fsdp")))
    ospec = AdamWState(step=P(), mu=pspec, nu=pspec)
    jitted = jax.jit(
        train_step,
        in_shardings=(sh.to_named(pspec, mesh),
                      sh.to_named(ospec, mesh),
                      None),
        out_shardings=(sh.to_named(pspec, mesh),
                       sh.to_named(ospec, mesh),
                       NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
    )
    return jitted, dict(params=aparams, opt_state=aopt)


def build_prefill_step(cfg: ModelConfig, mesh, *, skip_future: bool = False,
                       opts: dict | None = None):
    """Inference prefill: forward logits only (no grads/optimizer)."""

    def prefill_step(params, batch):
        logits, _ = M.forward(cfg, params, batch, remat=False,
                              skip_future=skip_future, opts=opts)
        return logits

    aparams = M.abstract_params(cfg)
    pspec = sh.param_spec_tree(cfg, aparams, mesh)
    jitted = jax.jit(prefill_step,
                     in_shardings=(sh.to_named(pspec, mesh), None))
    return jitted, dict(params=aparams)


def build_serve_step(cfg: ModelConfig, mesh, *, opts: dict | None = None):
    def serve_step(params, cache, token):
        return M.decode_step(cfg, params, cache, token, opts)

    aparams = M.abstract_params(cfg)
    pspec = sh.param_spec_tree(cfg, aparams, mesh)
    jitted = jax.jit(
        serve_step,
        in_shardings=(sh.to_named(pspec, mesh), None, None),
        donate_argnums=(1,),
    )
    return jitted, dict(params=aparams)


def abstract_train_inputs(cfg: ModelConfig, shape: ShapeCard, mesh,
                          opts: dict | None = None):
    """ShapeDtypeStructs (with shardings attached) for lower()."""
    aparams = M.abstract_params(cfg)
    opt = AdamW()
    aopt = jax.eval_shape(opt.init, aparams)
    batch = M.make_batch(cfg, shape.global_batch, shape.seq_len,
                         abstract=True)
    pspec = sh.param_spec_tree(cfg, aparams, mesh,
                               fsdp=bool((opts or {}).get("fsdp")))
    bspec = sh.batch_spec_tree(cfg, batch, mesh)

    def attach(tree, spec):
        return jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
            tree, spec, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    ospec = AdamWState(step=P(), mu=pspec, nu=pspec)
    return (attach(aparams, pspec), attach(aopt, ospec),
            attach(batch, bspec))


def abstract_serve_inputs(cfg: ModelConfig, shape: ShapeCard, mesh):
    aparams = M.abstract_params(cfg)
    pspec = sh.param_spec_tree(cfg, aparams, mesh)
    acache = jax.eval_shape(
        functools.partial(M.init_cache, cfg, shape.global_batch,
                          shape.seq_len))
    cspec = sh.cache_spec_tree(cfg, acache, mesh)
    bat = sh.batch_axes_for(shape.global_batch, mesh)
    token = jax.ShapeDtypeStruct(
        (shape.global_batch, 1), jnp.int32,
        sharding=NamedSharding(mesh, P(bat, None)))

    def attach(tree, spec):
        return jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
            tree, spec, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    return (attach(aparams, pspec), attach(acache, cspec), token)


def input_specs(cfg: ModelConfig, shape: ShapeCard, mesh,
                opts: dict | None = None) -> dict[str, Any]:
    """The dry-run contract: abstract, sharded stand-ins for every input."""
    if shape.kind == "train":
        params, opt_state, batch = abstract_train_inputs(cfg, shape, mesh,
                                                         opts)
        return dict(kind="train", params=params, opt_state=opt_state,
                    batch=batch)
    if shape.kind == "prefill":
        params, _, batch = abstract_train_inputs(cfg, shape, mesh, opts)
        batch = dict(batch)
        batch.pop("labels", None)
        return dict(kind="prefill", params=params, batch=batch)
    params, cache, token = abstract_serve_inputs(cfg, shape, mesh)
    return dict(kind="serve", params=params, cache=cache, token=token)
