"""Serving launcher: diverse-retrieval RAG over a synthetic corpus.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --requests 4 --k 5 --eps 3.0

Requests flow through the continuous-batching lane scheduler
(``serve.scheduler.LaneScheduler``): per-request (k, eps), lane recycling on
certification, pre-warmed compile ladder; per-request latency and fairness
stats are printed after the run. ``--tenants N`` labels requests round-robin
across N tenants and ``--policy {fifo,drr,slo_cost}`` picks the cost-aware
admission policy scheduling across them (``serve.policies``); per-tenant
p50/p99 and the cross-tenant Jain index are printed when N > 1.

``--mesh-shards P`` serves retrieval off a P-way sharded device mesh
instead of the single-host engine: the corpus is partitioned across the
mesh's data axis and the *same* scheduler drives a
``sharded_search.engine.ShardedEngine`` backend (shard-local beams,
tournament merge, per-lane progressive budgets). On CPU, force host
devices first, e.g. ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
``--elastic`` instead starts on half the available power-of-two devices and
lets the scheduler grow/shrink the shard count under sustained queue depth,
migrating in-flight lanes between rounds (contract 16).

``--cache-size N`` enables the semantic result cache (``serve.cache``):
repeated or near-duplicate queries are answered from a certified cached
result set after a fresh Theorem-2 recheck, without occupying a lane.
``--cost-model-path f.json`` warm-starts the admission policies' expansion
cost model from a previous run and persists the learned state afterwards.

Serving is assembled through ``repro.db.DiverseVectorDB`` (one constructor:
index → backend → scheduler → cache), which also provides the write path:
``--upserts N`` interleaves N upserts and N deletes with the request batch
to exercise the delta segment, deletion bitmap, and epoch swap, and prints
the mutable-index stats afterwards.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.db import DiverseVectorDB
from repro.models import model as M
from repro.serve.policies import ExpansionCostModel
from repro.serve.rag import RagPipeline


def _build_db(docs: np.ndarray, args, cost_model) -> DiverseVectorDB:
    shards = args.mesh_shards or None
    if args.elastic:
        if args.mesh_shards:
            raise SystemExit("--elastic picks its own shard counts "
                             "(shards='auto'); drop --mesh-shards")
        if jax.device_count() < 2:
            raise SystemExit("--elastic needs >= 2 devices (set XLA_FLAGS="
                             "--xla_force_host_platform_device_count=4)")
        shards = "auto"
    if shards and shards != "auto":
        if shards & (shards - 1):
            raise SystemExit(f"--mesh-shards {shards} must be a power of "
                             "two (tournament merge)")
        if shards > jax.device_count():
            raise SystemExit(f"--mesh-shards {shards} > "
                             f"{jax.device_count()} devices (set XLA_FLAGS "
                             "to force host devices)")
    return DiverseVectorDB(docs, "ip", shards=shards, num_lanes=args.lanes,
                           max_k=max(args.k, 16), M=8, policy=args.policy,
                           cache_size=args.cache_size, cost_model=cost_model,
                           prewarm=args.prewarm, elastic=args.elastic or None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--corpus", type=int, default=4000)
    ap.add_argument("--dim", type=int, default=48)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--eps", type=float, default=3.0)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--engine", default="scheduler",
                    choices=["scheduler", "lockstep", "fixed_k"])
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "drr", "slo_cost"],
                    help="admission policy for the lane scheduler")
    ap.add_argument("--tenants", type=int, default=1,
                    help="label requests round-robin across N tenants "
                         "(per-tenant stats printed when N > 1)")
    ap.add_argument("--mesh-shards", type=int, default=0,
                    help="serve retrieval from a P-way sharded mesh backend "
                         "(0 = single-host engine)")
    ap.add_argument("--elastic", action="store_true",
                    help="elastic mesh serving (shards='auto'): start on "
                         "half the available power-of-two devices and let "
                         "the scheduler grow/shrink the shard count under "
                         "sustained queue depth (requires --engine "
                         "scheduler; in-flight lanes migrate between "
                         "rounds, contract 16)")
    ap.add_argument("--cache-size", type=int, default=0,
                    help="semantic result cache capacity: repeated/near-"
                         "duplicate queries are served from certified "
                         "cached result sets after a Theorem-2 recheck "
                         "(0 = off; requires --engine scheduler)")
    ap.add_argument("--cost-model-path", default=None,
                    help="JSON file to warm-start the admission policies' "
                         "expansion cost model from (loaded if it exists) "
                         "and to persist the learned state back to after "
                         "the run")
    ap.add_argument("--upserts", type=int, default=0,
                    help="exercise the write path: N upserts before the "
                         "batch and N deletes after (requires --engine "
                         "scheduler); mutable-index stats are printed")
    ap.add_argument("--prewarm", action="store_true",
                    help="pre-compile the scheduler's capacity ladder")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    docs = rng.normal(size=(args.corpus, args.dim)).astype(np.float32)
    if (args.mesh_shards or args.elastic) and args.engine != "scheduler":
        raise SystemExit("--mesh-shards/--elastic require --engine "
                         "scheduler")
    if args.upserts and args.engine != "scheduler":
        raise SystemExit("--upserts requires --engine scheduler")
    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    cost_model = None
    if args.cost_model_path and os.path.exists(args.cost_model_path):
        cost_model = ExpansionCostModel.load(args.cost_model_path)
        print(f"# cost model warm-started from {args.cost_model_path} "
              f"({cost_model.stats()['observations']} observations)")
    db = _build_db(docs, args, cost_model)
    pipe = RagPipeline(cfg, params, k=args.k, eps=args.eps,
                       engine=args.engine, num_lanes=args.lanes,
                       prewarm=args.prewarm, policy=args.policy,
                       cache_size=args.cache_size, cost_model=cost_model,
                       db=db)
    qs = docs[rng.integers(0, len(docs), args.requests)]
    if args.upserts:
        new_ids = db.upsert(rng.normal(size=(args.upserts, args.dim))
                            .astype(np.float32))
        print(f"# upserted {len(new_ids)} vectors "
              f"(ids {int(new_ids[0])}..{int(new_ids[-1])})")
    tenants = ([f"t{i % args.tenants}" for i in range(args.requests)]
               if args.tenants > 1 else None)
    if args.engine != "scheduler" and (tenants is not None
                                       or args.policy != "fifo"
                                       or args.cache_size
                                       or args.cost_model_path):
        # the lockstep/fixed_k paths never build a LaneScheduler, so these
        # flags would be silently ignored — refuse instead
        raise SystemExit("--tenants/--policy/--cache-size/--cost-model-path "
                         "require --engine scheduler")
    t0 = time.time()
    tokens, ids, cert = pipe.generate(qs, np.ones((args.requests, 2),
                                                  np.int32),
                                      steps=args.steps, tenants=tenants)
    dt = time.time() - t0
    print(f"{args.requests} requests in {dt:.2f}s; "
          f"certified={cert.tolist()}")
    print("retrieved ids:\n", ids)
    if args.upserts:
        victims = rng.integers(0, args.corpus, args.upserts)
        removed = db.delete(np.unique(victims))
        post = db.search(qs[0], k=args.k, eps=args.eps)
        idx = db.stats()["index"]
        print(f"# deleted {removed} ids; post-write search certified="
              f"{post.stats.certified} ids={post.ids.tolist()}")
        print(f"# index: n={idx['n_total']} live={idx['live']} "
              f"delta={idx['delta']} epoch={idx['epoch']} "
              f"rebuilds={idx['rebuilds']}")
    if args.engine == "scheduler":
        stats = pipe.scheduler.latency_stats()
        if args.elastic:
            where = (f"elastic-mesh[{stats['shards']}] "
                     f"scale_events={stats['scale_events']}")
        elif args.mesh_shards:
            where = f"mesh[{args.mesh_shards}]"
        else:
            where = "single-host"
        print(f"scheduler[{where}|{stats['policy']}]: "
              f"p50={stats['p50_latency'] * 1e3:.1f}ms "
              f"p99={stats['p99_latency'] * 1e3:.1f}ms "
              f"fairness={stats['fairness']:.3f} "
              f"throughput={stats['throughput']:.1f} req/s "
              f"signatures={stats['signatures']}")
        if tenants is not None:
            for name, t in stats["tenants"].items():
                print(f"  tenant[{name}]: completed={t['completed']} "
                      f"shed={t['shed']} deferred={t['deferred']} "
                      f"p50={t['p50_latency'] * 1e3:.1f}ms "
                      f"p99={t['p99_latency'] * 1e3:.1f}ms")
            print(f"  tenant_fairness={stats['tenant_fairness']:.3f} "
                  f"calibration_error={stats['cost_calibration_error']:.3f}")
        if args.cache_size:
            cs = stats["cache"]
            print(f"  cache[{args.cache_size}]: hits={stats['cache_hits']} "
                  f"hit_rate={stats['cache_hit_rate']:.3f} "
                  f"admitted={cs['admitted']} evicted={cs['evicted']} "
                  f"revalidation_failures={cs['revalidation_failures']}")
        if args.cost_model_path:
            pipe.scheduler.cost_model.save(args.cost_model_path)
            print(f"# cost model saved to {args.cost_model_path}")


if __name__ == "__main__":
    main()
