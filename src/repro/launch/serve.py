"""Serving launcher: diverse-retrieval RAG over a synthetic corpus.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --requests 4 --k 5 --eps 3.0

Requests flow through the continuous-batching lane scheduler
(``serve.scheduler.LaneScheduler``): per-request (k, eps), lane recycling on
certification, pre-warmed compile ladder; per-request latency and fairness
stats are printed after the run.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.index.flat import build_knn_graph
from repro.models import model as M
from repro.serve.rag import RagPipeline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--corpus", type=int, default=4000)
    ap.add_argument("--dim", type=int, default=48)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--eps", type=float, default=3.0)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--engine", default="scheduler",
                    choices=["scheduler", "lockstep", "fixed_k"])
    ap.add_argument("--prewarm", action="store_true",
                    help="pre-compile the scheduler's capacity ladder")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    docs = rng.normal(size=(args.corpus, args.dim)).astype(np.float32)
    graph = build_knn_graph(docs, metric="ip", M=8)
    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    pipe = RagPipeline(cfg, params, graph, k=args.k, eps=args.eps,
                       engine=args.engine, num_lanes=args.lanes,
                       prewarm=args.prewarm)
    qs = docs[rng.integers(0, args.corpus, args.requests)]
    t0 = time.time()
    tokens, ids, cert = pipe.generate(qs, np.ones((args.requests, 2),
                                                  np.int32),
                                      steps=args.steps)
    dt = time.time() - t0
    print(f"{args.requests} requests in {dt:.2f}s; "
          f"certified={cert.tolist()}")
    print("retrieved ids:\n", ids)
    if args.engine == "scheduler":
        stats = pipe.scheduler.latency_stats()
        print("scheduler: "
              f"p50={stats['p50_latency'] * 1e3:.1f}ms "
              f"p99={stats['p99_latency'] * 1e3:.1f}ms "
              f"fairness={stats['fairness']:.3f} "
              f"throughput={stats['throughput']:.1f} req/s "
              f"signatures={stats['signatures']}")


if __name__ == "__main__":
    main()
