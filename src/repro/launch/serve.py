"""Serving launcher: diverse-retrieval RAG over a synthetic corpus.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --requests 4 --k 5 --eps 3.0
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.index.flat import build_knn_graph
from repro.models import model as M
from repro.serve.rag import RagPipeline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--corpus", type=int, default=4000)
    ap.add_argument("--dim", type=int, default=48)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--eps", type=float, default=3.0)
    ap.add_argument("--steps", type=int, default=8)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    docs = rng.normal(size=(args.corpus, args.dim)).astype(np.float32)
    graph = build_knn_graph(docs, metric="ip", M=8)
    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    pipe = RagPipeline(cfg, params, graph, k=args.k, eps=args.eps)
    qs = docs[rng.integers(0, args.corpus, args.requests)]
    t0 = time.time()
    tokens, ids, cert = pipe.generate(qs, np.ones((args.requests, 2),
                                                  np.int32),
                                      steps=args.steps)
    dt = time.time() - t0
    print(f"{args.requests} requests in {dt:.2f}s; "
          f"certified={cert.tolist()}")
    print("retrieved ids:\n", ids)


if __name__ == "__main__":
    main()
