"""Roofline terms from a compiled dry-run cell (TPU v5e constants).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / bytes come from the trip-count-aware HLO analyzer
(launch/hlo_analysis.py) and are PER-DEVICE (the module is SPMD-partitioned),
so the "/ chips" in the formulas is already applied — each term is simply
per_device_quantity / per_chip_rate. MODEL_FLOPS = 6·N·D (dense) or
6·N_active·D (MoE) gives the useful-compute ratio.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.configs.base import ModelConfig, ShapeCard
from repro.launch.mesh import HW


def param_counts(cfg: ModelConfig, params_tree: Any) -> tuple[int, int]:
    """(total, active) parameter counts from the abstract param tree."""
    import jax

    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_tree)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        names = [str(getattr(p, "key", "")) for p in path]
        if "moe" in names and names[-1] in ("wg", "wu", "wd"):
            expert += n
    active = total
    if cfg.num_experts and expert:
        active = total - expert + expert * cfg.experts_per_token \
            / cfg.num_experts
    return int(total), int(active)


def model_flops(cfg: ModelConfig, shape: ShapeCard, n_active: int) -> float:
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    hlo_flops_per_dev: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)
    roofline_fraction: float     # max-term time / sum-term time proxy

    def as_dict(self):
        return dataclasses.asdict(self)


def compute_roofline(cfg: ModelConfig, shape: ShapeCard, chips: int,
                     hlo: dict, n_active: int,
                     arg_bytes_per_dev: float = 0.0) -> Roofline:
    compute_s = hlo["flops"] / HW["peak_flops_bf16"]
    memory_s = hlo["hbm_bytes"] / HW["hbm_bw"]
    collective_s = hlo["collective_bytes"] / HW["ici_bw"]
    terms = dict(compute=compute_s, memory=memory_s,
                 collective=collective_s)
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, n_active)
    total_hlo = hlo["flops"] * chips
    useful = mf / total_hlo if total_hlo else 0.0
    # Roofline fraction: ideal step time is bounded below BOTH by useful
    # model compute at peak AND by reading every input (params, optimizer
    # state, KV cache) once from HBM — the latter is what makes decode
    # fundamentally memory-bound. frac = ideal / dominant-term time.
    ideal_compute_s = mf / (chips * HW["peak_flops_bf16"])
    ideal_mem_s = arg_bytes_per_dev / HW["hbm_bw"]
    ideal_s = max(ideal_compute_s, ideal_mem_s)
    frac = ideal_s / max(terms[bottleneck], 1e-30)
    return Roofline(compute_s, memory_s, collective_s, bottleneck, mf,
                    hlo["flops"], useful, frac)
