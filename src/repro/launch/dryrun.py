import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init, and only the dry-run wants 512 placeholder devices.

    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun

Each cell writes results/dryrun/<arch>__<shape>__<mesh>[__tag].json with
memory_analysis, cost_analysis, the trip-count-aware HLO totals, the
collective schedule breakdown, and the roofline terms.
"""
import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from repro.configs import ARCH_NAMES, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.launch import hlo_analysis, roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh     # noqa: E402
from repro.launch.steps import (  # noqa: E402
    build_prefill_step, build_serve_step, build_train_step, input_specs)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             tag: str = "", opts: dict | None = None) -> dict:
    opts = opts or {}
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape_name)
    rec: dict = dict(arch=arch, shape=shape_name, mesh=mesh_kind, tag=tag,
                     opts=opts)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return _write(rec, out_dir)

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.size
    t0 = time.time()
    try:
        specs = input_specs(cfg, shape, mesh, opts)
        if specs["kind"] == "train":
            step, _ = build_train_step(
                cfg, mesh, skip_future=opts.get("skip_future", False),
                remat=opts.get("remat", True), opts=opts)
            args = (specs["params"], specs["opt_state"], specs["batch"])
        elif specs["kind"] == "prefill":
            step, _ = build_prefill_step(
                cfg, mesh, skip_future=opts.get("skip_future", False),
                opts=opts)
            args = (specs["params"], specs["batch"])
        else:
            step, _ = build_serve_step(cfg, mesh, opts=opts)
            args = (specs["params"], specs["cache"], specs["token"])

        with mesh:
            lowered = step.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        mem = dict(
            argument_size_in_bytes=ma.argument_size_in_bytes,
            output_size_in_bytes=ma.output_size_in_bytes,
            temp_size_in_bytes=ma.temp_size_in_bytes,
            alias_size_in_bytes=ma.alias_size_in_bytes,
        )
        print(f"[{arch} {shape_name} {mesh_kind}] memory_analysis:", mem,
              flush=True)
        ca = compiled.cost_analysis() or {}
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "utilization operand")}
        print(f"[{arch} {shape_name} {mesh_kind}] cost_analysis(flops):",
              cost.get("flops"), flush=True)

        hlo_text = compiled.as_text()
        hlo = hlo_analysis.analyze(hlo_text)
        t_analyze = time.time() - t0 - t_lower - t_compile

        import repro.models.model as M
        aparams = jax.eval_shape(
            lambda: M.abstract_params(cfg))  # cheap, cached by jax anyway
        n_total, n_active = rl.param_counts(cfg, aparams)
        roof = rl.compute_roofline(cfg, shape, chips, hlo, n_active,
                                   mem["argument_size_in_bytes"])

        rec.update(
            status="ok", chips=chips,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            analyze_s=round(t_analyze, 2),
            memory_analysis=mem, cost_analysis=cost,
            hlo=dict(flops=hlo["flops"],
                     collective_bytes=hlo["collective_bytes"],
                     hbm_bytes=hlo["hbm_bytes"],
                     collective_breakdown=hlo["collective_breakdown"]),
            params_total=n_total, params_active=n_active,
            roofline=roof.as_dict(),
        )
        # per-device memory sanity vs 16 GB HBM
        per_dev = mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]
        rec["per_device_bytes"] = per_dev
        rec["fits_16gb_hbm"] = bool(per_dev < 16e9)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[{arch} {shape_name} {mesh_kind}] FAILED: {e}", flush=True)
    return _write(rec, out_dir)


def _write(rec: dict, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    status = rec["status"]
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (f" bottleneck={r['bottleneck']}"
                 f" frac={r['roofline_fraction']:.3f}"
                 f" fits={rec['fits_16gb_hbm']}")
    elif status == "skipped":
        extra = f" ({rec['reason'][:60]})"
    print(f"DRYRUN {rec['arch']:26s} {rec['shape']:12s} {rec['mesh']:6s}"
          f" -> {status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--opt", action="append", default=[],
                    help="key=value step options (e.g. skip_future=false)")
    args = ap.parse_args()

    opts = {}
    for kv in args.opt:
        k, v = kv.split("=", 1)
        if v.isdigit():
            opts[k] = int(v)
        elif v.lower() in ("true", "false", "yes", "no", "1", "0"):
            opts[k] = v.lower() in ("1", "true", "yes")
        else:
            opts[k] = v

    archs = list(ARCH_NAMES) if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, args.out, args.tag,
                               opts)
                failures += rec["status"] == "error"
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
