"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --steps 100 --batch 16 --seq 64 [--reduced] [--ckpt DIR]

Uses the fault-tolerant loop (checkpoint/restart, straggler monitor,
prefetching data pipeline). Full configs need the production mesh; the
default host run uses --reduced.
"""
from __future__ import annotations

import argparse

import jax

from repro.compat import make_mesh
from repro.configs import get_config
from repro.train.loop import train
from repro.train.optimizer import AdamW, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n = len(jax.devices())
    model_ax = 1
    mesh = make_mesh((n // model_ax, model_ax), ("data", "model"))
    rep = train(cfg, mesh, steps=args.steps, global_batch=args.batch,
                seq_len=args.seq, ckpt_dir=args.ckpt,
                ckpt_every=args.ckpt_every,
                optimizer=AdamW(lr=cosine_schedule(
                    args.lr, args.steps // 10, args.steps)))
    print(f"done: {rep.steps_run} steps, final loss {rep.final_loss:.4f}, "
          f"restarts={rep.restarts}")


if __name__ == "__main__":
    main()
