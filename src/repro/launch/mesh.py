"""Production mesh construction (multi-pod dry-run spec).

Defined as functions so importing this module never touches jax device
state — the 512-placeholder-device XLA flag is set only by dryrun.py.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single-pod; (2, 16, 16) pod x data x model."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CI-style tests on host placeholder devices."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


HW = dict(
    # TPU v5e-class constants used by the roofline (per chip)
    peak_flops_bf16=197e12,     # FLOP/s
    hbm_bw=819e9,               # B/s
    ici_bw=50e9,                # B/s per link
)
