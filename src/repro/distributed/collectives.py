"""Compute/communication overlap primitives.

``ring_allgather_matmul``: the "collective matmul" (overlap class used by
Megatron/MaxText): computing y = all_gather(x) @ w_local as P ring steps.
Each step multiplies the currently-resident x shard into its row-block of
the output while the next shard travels one ICI hop — on TPU the permute
hides behind the MXU work, removing the serial all-gather from the critical
path. Used by the §Perf collective-bound iteration; the one-shot
``allgather_matmul`` is the baseline it replaces.

Both run inside shard_map with ``axis`` sharding x's leading dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import axis_size


def allgather_matmul(x_shard, w_local, axis: str):
    """Baseline: y = all_gather(x) @ w_local, serial collective."""
    x_full = jax.lax.all_gather(x_shard, axis, axis=0, tiled=True)
    return jnp.dot(x_full, w_local, preferred_element_type=jnp.float32)


def ring_allgather_matmul(x_shard, w_local, axis: str):
    """Ring-overlapped y = all_gather(x) @ w_local.

    x_shard [Bs, K] (leading dim sharded over ``axis``), w_local [K, N].
    Returns y [Bs*P, K->N] identical to the baseline (up to fp reorder).
    """
    p = axis_size(axis)
    me = jax.lax.axis_index(axis)
    bs = x_shard.shape[0]
    # receive from the next rank each step: after t hops we hold shard me+t
    perm = [(i, (i - 1) % p) for i in range(p)]
    y0 = jnp.zeros((bs * p,) + (w_local.shape[-1],), jnp.float32)

    def step(carry, t):
        y, xs = carry
        src = (me + t) % p
        block = jnp.dot(xs, w_local, preferred_element_type=jnp.float32)
        y = jax.lax.dynamic_update_slice(y, block, (src * bs, 0))
        xs = jax.lax.ppermute(xs, axis, perm)
        return (y, xs), None

    (y, _), _ = jax.lax.scan(step, (y0, x_shard), jnp.arange(p))
    return y
