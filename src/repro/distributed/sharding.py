"""Sharding rules: map every param/cache/batch leaf to a PartitionSpec.

Baseline policy (the §Perf starting point — deliberately simple and always
divisibility-safe):

  * batch/data-parallel over ("pod", "data") for all activations;
  * Megatron-style tensor parallel over "model" for MLP hidden, MoE experts,
    SSM channels, RG-LRU width, and the vocab dim (when divisible by the
    model-axis size);
  * attention q-heads shard over "model" only when the head count divides
    the axis; kv projections shard at kv-head granularity when divisible,
    else stay replicated (MQA/GQA with few kv heads).

Rules are name-based over the param tree paths emitted by the model inits.
``pad_heads`` (a §Perf hillclimb lever) is applied at the model level, not
here.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _model_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _bat(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _div(n: int, m: int) -> bool:
    return n % m == 0


def param_spec_tree(cfg: ModelConfig, params: Any, mesh: Mesh,
                    fsdp: bool = False):
    """PartitionSpec pytree matching ``params`` (works on abstract trees).

    ``fsdp``: additionally shard every large weight over the "data" axis on
    a free (unsharded, divisible) dim — ZeRO-3-style; parameters and both
    Adam moments then scale 1/(data*model). XLA inserts the per-layer
    just-in-time all-gathers; the §Perf log prices that traffic.
    """
    ms = _model_size(mesh)
    bat = _bat(mesh)          # ("pod", "data") on the multi-pod mesh
    ds = 1
    for a in bat:
        ds *= mesh.shape[a]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

    def fsdpify(spec: P, shape) -> P:
        if not fsdp:
            return spec
        n = 1
        for d in shape:
            n *= d
        if n < (1 << 20):
            return spec
        parts = list(spec) + [None] * (len(shape) - len(spec))
        for i, (dim, sp) in enumerate(zip(shape, parts)):
            if sp is None and dim % ds == 0 and dim >= ds:
                parts[i] = bat if len(bat) > 1 else bat[0]
                return P(*parts)
        return spec

    def leaf_spec(path, leaf) -> P:
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = names[-1]
        joined = "/".join(str(n) for n in names)
        shape = leaf.shape
        rank = len(shape)

        def last_dims(spec_tail: tuple) -> P:
            """Pad spec with leading Nones for stack dims."""
            lead = rank - len(spec_tail)
            return P(*([None] * lead + list(spec_tail)))

        # ---- embeddings / heads
        if name == "embed":
            return P("model", None) if _div(shape[0], ms) else P(None, None)
        if name == "lm_head":
            return P(None, "model") if _div(shape[1], ms) else P(None, None)
        if name == "dec_pos":
            return P(None, None)

        # ---- attention projections
        if name in ("wq",):
            return last_dims((None, "model")) if _div(h, ms) \
                else last_dims((None, None))
        if name in ("bq",):
            return last_dims(("model",)) if _div(h, ms) else last_dims((None,))
        if name in ("wk", "wv"):
            return last_dims((None, "model")) if _div(kv, ms) \
                else last_dims((None, None))
        if name in ("bk", "bv"):
            return last_dims(("model",)) if _div(kv, ms) else last_dims((None,))
        if name == "wo":
            return last_dims(("model", None)) if _div(h, ms) \
                else last_dims((None, None))

        # ---- MoE (expert parallel; router replicated)
        if name == "wr":
            return last_dims((None, None))
        if "moe" in joined and name in ("wg", "wu", "wd"):
            return last_dims(("model", None, None)) \
                if _div(cfg.num_experts, ms) else last_dims((None,) * 3)

        # ---- dense MLP (column/row parallel)
        if name in ("wg", "wu", "w1"):
            return last_dims((None, "model")) if _div(shape[-1], ms) \
                else last_dims((None, None))
        if name in ("b1",):
            return last_dims(("model",)) if _div(shape[-1], ms) \
                else last_dims((None,))
        if name in ("wd", "w2"):
            return last_dims(("model", None)) if _div(shape[-2], ms) \
                else last_dims((None, None))
        if name in ("b2",):
            return last_dims((None,))

        # ---- SSM (channel parallel)
        if name == "w_in":
            return last_dims((None, "model")) if _div(shape[-1], ms) \
                else last_dims((None, None))
        if name in ("conv_w", "conv_b", "norm_scale"):
            return last_dims((None,) * (1 if name != "conv_w" else 2)) \
                if not _div(shape[-1], ms) else (
                    last_dims(("model",)) if name != "conv_w"
                    else last_dims((None, "model")))
        if name in ("A_log", "dt_bias", "D_skip"):
            return last_dims(("model",)) if _div(shape[-1], ms) \
                else last_dims((None,))
        if name == "w_out":
            return last_dims(("model", None)) if _div(shape[-2], ms) \
                else last_dims((None, None))

        # ---- RG-LRU
        if name in ("w_gate", "w_branch"):
            return last_dims((None, "model")) if _div(shape[-1], ms) \
                else last_dims((None, None))
        if name in ("w_r", "w_i"):
            return last_dims(("model", None)) if _div(shape[-2], ms) \
                else last_dims((None, None))
        if name in ("b_r", "b_i", "lam"):
            return last_dims(("model",)) if _div(shape[-1], ms) \
                else last_dims((None,))

        # ---- norms, gates, scalars
        return P(*([None] * rank))

    def leaf_spec_fsdp(path, leaf):
        return fsdpify(leaf_spec(path, leaf), leaf.shape)

    return jax.tree_util.tree_map_with_path(leaf_spec_fsdp, params)


def batch_axes_for(b: int, mesh: Mesh,
                   reserve_model: bool = False) -> tuple[str, ...]:
    """Largest prefix of (pod, data[, model]) whose product divides b.

    Sharding the batch over "model" too (when divisible) makes attention
    compute fully local — no head-divisibility constraint — and scales
    activation memory by 1/mesh_size; tensor-parallel weight shards still
    contract correctly against batch-sharded activations.
    ``reserve_model``: MoE models keep the model axis free so the expert
    (EP) dimension can live there.
    """
    axes: list[str] = []
    prod = 1
    tail = () if reserve_model else ("model",)
    for a in _bat(mesh) + tail:
        n = mesh.shape[a]
        if b % (prod * n) == 0:
            axes.append(a)
            prod *= n
        else:
            break
    return tuple(axes)


def batch_spec_tree(cfg: ModelConfig, batch: Any, mesh: Mesh):
    reserve = cfg.num_experts > 0
    def leaf(path, leaf):
        rank = len(leaf.shape)
        axes = batch_axes_for(leaf.shape[0], mesh, reserve_model=reserve)
        return P(axes, *([None] * (rank - 1)))

    return jax.tree_util.tree_map_with_path(leaf, batch)


def cache_spec_tree(cfg: ModelConfig, cache: Any, mesh: Mesh):
    """Decode cache: batch over data axes; kv-heads over model if divisible.

    Cache layouts (transformer.init_cache): [stack..., B, S, KV, hd] for k/v,
    [stack..., B, ...] for states, cache_len [B].
    """
    ms = _model_size(mesh)

    def leaf(path, leaf):
        names = [str(getattr(p, "key", "")) for p in path]
        name = names[-1]
        shape = leaf.shape
        if name == "cache_len":
            return P(batch_axes_for(shape[0], mesh))
        if name in ("k", "v", "cross_k", "cross_v") or name.endswith("_k") \
                or name.endswith("_v"):
            # [..., B, S, KV, hd]: kv-heads over model when divisible, else
            # the cache SEQUENCE dim — SPMD partitions the attention
            # contraction (softmax max/sum become small all-reduces), which
            # trades a little collective time for 1/16th the cache memory.
            lead = len(shape) - 4
            kv = shape[-2]
            bat = batch_axes_for(shape[lead], mesh)
            if kv % ms == 0 and "model" not in bat:
                return P(*([None] * lead), bat, None, "model", None)
            if shape[-3] % ms == 0 and "model" not in bat:
                return P(*([None] * lead), bat, "model", None, None)
            return P(*([None] * lead), bat, None, None, None)
        if name in ("lru_h",) or name.endswith("_h"):
            lead = len(shape) - 2
            w = shape[-1]
            bat = batch_axes_for(shape[lead], mesh)
            return P(*([None] * lead), bat,
                     "model" if (w % ms == 0 and "model" not in bat) else None)
        if name == "conv" or name.endswith("_conv"):
            lead = len(shape) - 3
            c = shape[-1]
            bat = batch_axes_for(shape[lead], mesh)
            return P(*([None] * lead), bat, None,
                     "model" if (c % ms == 0 and "model" not in bat) else None)
        if name == "h":  # ssm state [L, B, H, P, N]
            lead = len(shape) - 4
            nh = shape[-3]
            bat = batch_axes_for(shape[lead], mesh)
            return P(*([None] * lead), bat,
                     "model" if (nh % ms == 0 and "model" not in bat)
                     else None, None, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf, cache)


def to_named(spec_tree: Any, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def bytes_of(tree: Any) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))
