"""Elastic scaling: move state between differently-sized meshes.

Checkpoints are logical (keyed by param path, device-layout-free), so
elastic restore = rebuild shardings for the new mesh and device_put. This
module is the in-memory variant a controller calls when the fleet grows or
shrinks: ``plan`` summarizes the mesh change, ``reshard_tree`` moves a
pytree across it. Three tree families are supported:

* **model params** (the training stack): re-place per the name-based
  sharding rules — needs the ``cfg=`` the rules key on;
* **``ShardedIndex``** (the serving corpus): repartition the stacked row
  arrays across the new shard count — quantized codes/scales are re-blocked
  exactly, per-shard graphs rebuilt deterministically
  (``sharded_search.reshard_index``);
* **``ShardedSearchState``** (in-flight lane beams): re-bucket every lane's
  per-shard queue + visited set by global id
  (``sharded_search.migrate_sharded_state``), so paused searches resume on
  the new topology without redoing expansions.

The serving index/state paths need no ``ModelConfig`` — their layout is
fully determined by the tree itself plus the target mesh:

    new_mesh = make_mesh((4,), ("data",))
    idx4 = reshard_tree(idx2, new_mesh, all_vectors=x)
    st4 = reshard_tree(st2, new_mesh, capacity=idx4_capacity)

Works for any mesh whose axis sizes still divide the sharded dims — the
same divisibility rules the baseline sharding layer enforces.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.distributed import sharding as sh


def plan(old_mesh, new_mesh) -> dict:
    """Summary of what changes between meshes (for logs/controllers).

    Pure mesh diff — no model config: ``dp_change``/``tp_change`` are the
    data/model axis growth ratios and ``axis_changes`` covers every named
    axis. ``plan(a, b)`` and ``plan(b, a)`` are exact inverses: ``old`` and
    ``new`` swap and every ratio is reciprocal.
    """
    old = dict(zip(old_mesh.axis_names, old_mesh.devices.shape))
    new = dict(zip(new_mesh.axis_names, new_mesh.devices.shape))
    changes = {a: new.get(a, 1) / old.get(a, 1)
               for a in sorted(set(old) | set(new))}
    return dict(
        old=old,
        new=new,
        dp_change=changes.get("data", 1.0),
        tp_change=changes.get("model", 1.0),
        axis_changes=changes,
    )


def reshard_tree(tree: Any, new_mesh=None, cfg=None,
                 spec_fn=None, *, axis: str = "data",
                 shards: int | None = None, all_vectors=None,
                 M: int | None = None, builder: str = "knng",
                 capacity: int | None = None) -> Any:
    """Re-place ``tree`` onto ``new_mesh`` (or a bare ``shards=`` count).

    Dispatches on the tree type (see module docstring). ``cfg``/``spec_fn``
    belong to the model-param path only; ``all_vectors``/``M``/``builder``
    to ``ShardedIndex`` (quantized corpora and non-default graph builds);
    ``capacity`` to ``ShardedSearchState`` (the target queue width —
    default keeps the current one). The serving paths accept ``shards=``
    without any mesh for host-side round-trip testing.
    """
    from repro.sharded_search.search import (ShardedIndex,
                                             ShardedSearchState,
                                             migrate_sharded_state,
                                             reshard_index)

    if shards is None:
        if new_mesh is None:
            raise ValueError("reshard_tree needs a new_mesh or shards=")
        shards = int(dict(zip(new_mesh.axis_names,
                              new_mesh.devices.shape)).get(axis, 1))
    if isinstance(tree, ShardedIndex):
        return reshard_index(tree, shards, all_vectors, M=M, builder=builder)
    if isinstance(tree, ShardedSearchState):
        return migrate_sharded_state(tree, shards, capacity,
                                     mesh=new_mesh, axis=axis)
    if cfg is None:
        raise ValueError("resharding a model-param tree needs cfg= "
                         "(the sharding rules key on it)")
    specs = (spec_fn or sh.param_spec_tree)(cfg, tree, new_mesh)
    named = sh.to_named(specs, new_mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(jax.numpy.asarray(x), s), tree, named)
