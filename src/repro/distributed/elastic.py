"""Elastic scaling: move a training state between differently-sized meshes.

Checkpoints are logical (keyed by param path, device-layout-free), so
elastic restore = rebuild shardings for the new mesh and device_put. This
module adds the in-memory variant (``reshard_tree``) and the planning helper
(``plan``) a controller would call when the fleet grows/shrinks:

    new_mesh = make_mesh((new_dp, new_tp), ("data", "model"))
    params = reshard_tree(params, cfg, new_mesh)

Works for any mesh whose axis sizes still divide the sharded dims — the
same divisibility rules the baseline sharding layer enforces.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.configs.base import ModelConfig
from repro.distributed import sharding as sh


def plan(cfg: ModelConfig, old_mesh, new_mesh) -> dict:
    """Summary of what changes between meshes (for logs/controllers)."""
    return dict(
        old=dict(zip(old_mesh.axis_names, old_mesh.devices.shape)),
        new=dict(zip(new_mesh.axis_names, new_mesh.devices.shape)),
        dp_change=new_mesh.shape.get("data", 1) / old_mesh.shape.get("data", 1),
        tp_change=new_mesh.shape.get("model", 1)
        / old_mesh.shape.get("model", 1),
    )


def reshard_tree(tree: Any, cfg: ModelConfig, new_mesh,
                 spec_fn=sh.param_spec_tree) -> Any:
    """Re-place a (param-like) tree onto ``new_mesh`` per the sharding rules."""
    specs = spec_fn(cfg, tree, new_mesh)
    shards = sh.to_named(specs, new_mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(jax.numpy.asarray(x), s), tree, shards)
