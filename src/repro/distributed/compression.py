"""Gradient compression: int8 block-quantized all-reduce with error feedback.

For DP gradient sync at 1000-node scale the wire format matters more than
the math: this module all-reduces int8-quantized gradients (4x fewer bytes
than f32) with per-block scales, and keeps the quantization residual in an
error-feedback buffer that is re-added next step — the standard EF-SGD
construction that preserves convergence.

``compressed_psum(grads, axis, ef)`` runs inside shard_map over the data
axis. Quantize -> psum(int32) -> dequantize; scales psum'd alongside. The
approximation: blocks share the max-abs scale across the axis (max-reduced),
so the reconstruction error stays bounded by one quantization step.

The quantizer itself lives in ``repro.quant`` (:data:`~repro.quant.BLOCK`,
:func:`~repro.quant.quantize_blocks`, :func:`~repro.quant.block_view`) so
gradient sync and the compressed search corpus share one audited
implementation; this module keeps its wire format bit-exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..quant import BLOCK, block_view as _block_view, \
    quantize_blocks as _quantize

__all__ = ["BLOCK", "compressed_psum", "tree_compressed_psum"]


def compressed_psum(grad: jnp.ndarray, axis: str,
                    ef: jnp.ndarray | None = None):
    """int8 EF all-reduce of one tensor inside shard_map.

    Returns (mean_grad, new_ef). ``ef`` is the local error-feedback buffer
    (same shape as grad; zeros initially).
    """
    g = grad.astype(jnp.float32)
    if ef is not None:
        g = g + ef
    flat = g.reshape(-1)
    blocks, n = _block_view(flat)
    local_amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    # shared scale across the axis so int32 sums dequantize consistently
    amax = jax.lax.pmax(local_amax, axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = _quantize(blocks, scale)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    world = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    mean = (total.astype(jnp.float32) * scale) / world.astype(jnp.float32)
    # local error feedback: what the wire lost of OUR contribution
    sent = q.astype(jnp.float32) * scale
    new_ef = (blocks - sent).reshape(-1)[:n].reshape(grad.shape)
    out = mean.reshape(-1)[:n].reshape(grad.shape)
    return out.astype(grad.dtype), new_ef


def tree_compressed_psum(grads, axis: str, ef_tree=None):
    """Apply compressed_psum over a pytree. Returns (means, new_ef_tree)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    ef_leaves = (jax.tree_util.tree_leaves(ef_tree) if ef_tree is not None
                 else [None] * len(leaves))
    outs, efs = [], []
    for g, e in zip(leaves, ef_leaves):
        o, ne = compressed_psum(g, axis, e)
        outs.append(o)
        efs.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, efs))
