"""Minimal stand-in for ``hypothesis`` when the real package is absent.

CI installs real hypothesis via the ``dev`` extra; hermetic containers
without it still need the property tests to *collect and run*. This module
implements exactly the subset of the API the test-suite uses — ``given``,
``settings``, and the ``integers/floats/lists/tuples/composite`` strategies —
driving each test with a fixed number of deterministic pseudo-random examples
(seeded per test name, so runs are reproducible and failures re-fire).

No shrinking, no example database, no edge-case bias: this is a smoke-grade
fallback, not a hypothesis replacement. ``install()`` registers the shim in
``sys.modules`` under the real names; it must run before the test modules
import ``hypothesis`` (the repo's ``tests/conftest.py`` does this).
"""
from __future__ import annotations

import functools
import sys
import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class Strategy:
    """A strategy is just a deterministic sampler: rng -> value."""

    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: np.random.Generator):
        return self._sample(rng)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, allow_nan: bool = False,
           allow_infinity: bool = False) -> Strategy:
    del allow_nan, allow_infinity  # bounded draws are always finite
    return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    def sample(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(size)]
    return Strategy(sample)


def tuples(*elements: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(e.example(rng) for e in elements))


def composite(fn):
    """``@st.composite`` — fn(draw, *args) becomes a strategy factory."""
    @functools.wraps(fn)
    def factory(*args, **kwargs):
        def sample(rng):
            return fn(lambda strat: strat.example(rng), *args, **kwargs)
        return Strategy(sample)
    return factory


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def apply(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return apply


def given(*strategies: Strategy):
    def decorate(fn):
        # per-test deterministic seed: stable across runs and processes
        seed = zlib.crc32(fn.__qualname__.encode())

        @functools.wraps(fn)
        def runner():
            # read at call time from the runner itself, so @settings works
            # both above and below @given (functools.wraps copies the attr
            # from fn; settings applied above sets it on runner directly)
            max_examples = getattr(runner, "_fallback_max_examples",
                                   DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(seed)
            for _ in range(max_examples):
                fn(*(s.example(rng) for s in strategies))

        # hide the original argument list from pytest's fixture resolution
        runner.__wrapped__ = None
        del runner.__wrapped__
        return runner
    return decorate


def install() -> None:
    """Register this shim as ``hypothesis`` + ``hypothesis.strategies``."""
    if "hypothesis" in sys.modules:
        return
    root = types.ModuleType("hypothesis")
    root.given = given
    root.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "tuples", "composite"):
        setattr(strategies, name, globals()[name])
    strategies.Strategy = Strategy
    root.strategies = strategies
    root.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = root
    sys.modules["hypothesis.strategies"] = strategies
