"""Test-support utilities (hypothesis fallback, helpers)."""
