"""ShardedEngine: the mesh-sharded implementation of ``LaneBackend``.

A *lane* here is one query row of the replicated batch that rides over the
device mesh: the database is sharded P ways along the mesh's data axis, every
dispatch runs the shard-local beam search + tournament merge + replicated
div-A* of ``sharded_search.sharded_diverse_search``, and each lane carries
its own ``(k, eps, K-budget)`` — the paper's query-owned diversification
level at mesh scale.

Round structure (one ``step()``):

1. Occupied lanes are bucketed by their current ``(K-budget, k)`` and each
   bucket is dispatched at exactly that budget, padded to a power-of-two
   lane count (``core.bucketing``) so compile signatures stay logarithmic in
   batch size. ``eps`` is traced per lane, so mixed-eps traffic shares one
   compilation per bucket shape.
2. A lane whose Theorem-2 certificate fires (or whose budget hit the corpus
   / its ``max_K`` cap) finishes and its mesh slot is freed — the serving
   scheduler admits the next queued request into it *between rounds*, while
   sibling lanes keep their budgets. This is the request-queue half that
   ``sharded_progressive_diverse`` alone never had (per-lane budgets only).
3. Surviving lanes double their budget (clamped) for the next round; a lane
   that exhausts ``max_rounds`` finishes uncertified with its last results.

Parity contract: a harvested lane's result is exactly
``sharded_diverse_search`` for that query at the lane's final K-budget —
every dispatch *is* that function, lanes are vmapped rows, and padding rows
only duplicate a real lane's work. Admission order can therefore never leak
between requests. ``tests/dist_scripts/sharded_scheduler_check.py`` enforces
this on a 4-device host mesh, plus mid-run admission into a freed lane.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.backend import LaneRequest
from repro.core.batch_progressive import SignatureLog
from repro.core.bucketing import pow2_group_sizes, pow2_padded_indices
from repro.core.pgs import DiverseResult
from repro.core.progressive import SearchStats
from repro.sharded_search.search import ShardedIndex, sharded_diverse_search

LANE_FREE, LANE_RUN, LANE_DONE = range(3)


class ShardedEngine:
    """Per-lane progressive budgets over a sharded mesh index.

    Implements ``core.backend.LaneBackend``; drive it directly (the
    ``sharded_progressive_diverse`` wrapper does) or through
    ``serve.scheduler.LaneScheduler`` for continuous batching, backpressure
    and latency stats on an N-device mesh.
    """

    methods = ("sharded",)

    def __init__(self, index: ShardedIndex, all_vectors, mesh,
                 num_lanes: int = 8, *, axis: str = "data",
                 K0: int = 32, L_factor: int = 4, merge: str = "tournament",
                 max_expansions: int = 100_000, max_rounds: int = 8,
                 max_k: int = 16, default_ef: int = 0,
                 max_signatures: int | None = 1024):
        self.index = index
        self.all_vectors = jnp.asarray(all_vectors)
        self.mesh = mesh
        self.axis = axis
        self.K0 = K0
        self.L_factor = L_factor
        self.merge = merge
        self.max_expansions = max_expansions
        self.max_rounds = max_rounds
        self.max_k = max_k
        # the mesh backend has no beam-ef knob (beam width = K * L_factor);
        # kept so the scheduler's ef plumbing is backend-neutral
        self.default_ef = default_ef
        self.B = int(num_lanes)
        self.n_total = index.num_shards * index.shard_size
        d = int(index.vectors.shape[-1])
        self.qs = np.zeros((self.B, d), np.float32)
        self.status = np.full(self.B, LANE_FREE, np.int8)
        self.ks = np.ones(self.B, np.int64)
        self.epss = np.zeros(self.B, np.float64)
        self.K = np.zeros(self.B, np.int64)
        self.maxK = np.full(self.B, self.n_total, np.int64)
        self.rounds = np.zeros(self.B, np.int64)
        self.out_ids = np.full((self.B, max_k), -1, np.int32)
        self.out_sc = np.zeros((self.B, max_k), np.float32)
        self.cert = np.zeros(self.B, bool)
        self.signatures = SignatureLog(max_signatures)
        self._unharvested: list[int] = []

    # -- protocol surface ---------------------------------------------------
    @property
    def num_lanes(self) -> int:
        return self.B

    @property
    def signature_log(self) -> SignatureLog:
        return self.signatures

    def free_lanes(self) -> np.ndarray:
        return np.flatnonzero(self.status == LANE_FREE)

    def active_count(self) -> int:
        return int((self.status == LANE_RUN).sum())

    def admit(self, lane: int, request: LaneRequest) -> None:
        """Hand a free mesh lane to ``request``: fresh budget ladder from
        ``K0``; sibling lanes keep their in-flight budgets."""
        if self.status[lane] != LANE_FREE:
            raise RuntimeError(f"mesh lane {lane} is still occupied")
        k = int(request.k)
        if k > self.max_k:
            raise ValueError(f"k={k} exceeds engine max_k={self.max_k}")
        if request.method not in self.methods:
            raise ValueError(
                f"unknown sharded method {request.method!r}")
        self.qs[lane] = np.asarray(request.q, np.float32)
        self.ks[lane] = k
        self.epss[lane] = float(request.eps)
        self.maxK[lane] = min(request.max_K or self.n_total, self.n_total)
        self.K[lane] = min(max(self.K0, 2 * k), self.maxK[lane])
        self.rounds[lane] = 0
        self.out_ids[lane] = -1
        self.out_sc[lane] = 0.0
        self.cert[lane] = False
        self.status[lane] = LANE_RUN

    def recycle(self, lane: int) -> None:
        """Return a harvested lane's mesh slot to the free pool."""
        if self.status[lane] != LANE_DONE:
            raise RuntimeError(f"mesh lane {lane} is not finished")
        self.status[lane] = LANE_FREE

    # -- the round ----------------------------------------------------------
    def _dispatch(self, idx: np.ndarray, Kval: int, k_g: int) -> None:
        padded = pow2_padded_indices(idx)
        self.signatures.note("sharded", len(padded), Kval, k_g)
        ids, scores, cert = sharded_diverse_search(
            self.index, self.all_vectors, jnp.asarray(self.qs[padded]), k_g,
            jnp.asarray(self.epss[padded], jnp.float32), Kval, self.mesh,
            self.axis, self.L_factor, self.merge, "div_astar",
            self.max_expansions)
        m = len(idx)
        self.out_ids[idx, :k_g] = np.asarray(ids)[:m]
        self.out_sc[idx, :k_g] = np.asarray(scores)[:m]
        self.cert[idx] = np.asarray(cert)[:m]

    def step(self) -> list[int]:
        """Advance every occupied mesh lane one budget round; returns the
        lanes that finished (also queued for ``harvest``)."""
        active = self.status == LANE_RUN
        if not active.any():
            return []
        buckets: dict[tuple, list[int]] = {}
        for i in np.flatnonzero(active):
            buckets.setdefault((int(self.K[i]), int(self.ks[i])), []).append(i)
        for (Kval, k_g), idx in sorted(buckets.items()):
            self._dispatch(np.asarray(idx), Kval, k_g)
        self.rounds[active] += 1
        finished = active & (self.cert | (self.K >= self.maxK))
        still = active & ~finished
        # a lane out of rounds retires uncertified at its *current* budget
        # (so K_final is always a budget that was actually dispatched — the
        # parity anchor); only true survivors double for the next round
        retired = still & (self.rounds >= self.max_rounds)
        cont = still & ~retired
        self.K[cont] = np.minimum(self.K[cont] * 2, self.maxK[cont])
        done = np.flatnonzero(finished | retired)
        for lane in done:
            self.status[lane] = LANE_DONE
            self._unharvested.append(int(lane))
        return [int(x) for x in done]

    def harvest(self) -> list[tuple[int, DiverseResult]]:
        """Drain finished lanes since the last harvest; each lane stays
        reserved until ``recycle``."""
        out = [(lane, self.result(lane)) for lane in self._unharvested]
        self._unharvested = []
        return out

    def result(self, lane: int) -> DiverseResult:
        """Solo-call-compatible result: equals ``sharded_diverse_search`` for
        this query at ``stats.K_final``."""
        k = int(self.ks[lane])
        ids = self.out_ids[lane, :k].copy()
        sc = self.out_sc[lane, :k].copy()
        certified = bool(self.cert[lane])
        stats = SearchStats(
            expansions=0, growths=max(0, int(self.rounds[lane]) - 1),
            search_calls=int(self.rounds[lane]),
            div_calls=int(self.rounds[lane]),
            certified=certified, exhausted=not certified,
            K_final=int(self.K[lane]))
        return DiverseResult(ids.astype(np.int32), sc.astype(np.float32),
                             float(sc.sum()), stats)

    # -- prewarm ------------------------------------------------------------
    def prewarm(self, *, max_capacity: int | None = None, ks: tuple = (),
                widths: tuple = ()) -> list[tuple]:
        """Compile the mesh dispatch ladder ahead of serving.

        Walks the power-of-two group sizes up to ``num_lanes`` crossed with
        the budget-doubling ladder from ``K0`` up to ``max_capacity``
        (default: one rung, ``K0`` only — mesh dispatches *execute* the
        search, so a full-corpus warmup is a real cost the caller opts into)
        for each ``k`` in ``ks`` (default: ``max_k``). ``widths`` is accepted
        for signature-compatibility with the single-host backend and
        ignored (the mesh backend has no prefix-width stage).
        """
        del widths
        if (self.status != LANE_FREE).any():
            raise RuntimeError("prewarm before admitting requests (prewarm "
                               "dispatches scribble on lane 0's result row)")
        top = min(max_capacity or self.K0, self.n_total)
        ks = tuple(int(k) for k in ks) or (self.max_k,)
        warmed: list[tuple] = []
        for g in pow2_group_sizes(self.B):
            for k in ks:
                K = min(max(self.K0, 2 * k), self.n_total)
                while True:
                    self._dispatch(np.zeros(g, np.int64), K, k)
                    warmed.append(("sharded", g, K, k))
                    if K >= top:
                        break
                    K = min(K * 2, self.n_total)
        # prewarm dispatches scribble on (free) lane 0's result row; wipe it
        self.out_ids[0] = -1
        self.out_sc[0] = 0.0
        self.cert[0] = False
        return warmed
