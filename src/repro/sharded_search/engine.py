"""ShardedEngine: the mesh-sharded implementation of ``LaneBackend``.

A *lane* here is one query row of the replicated batch that rides over the
device mesh: the database is sharded P ways along the mesh's data axis, every
dispatch runs the shard-local beam search + tournament merge + replicated
div-A* of ``sharded_search.sharded_diverse_search``, and each lane carries
its own ``(k, eps, K-budget)`` — the paper's query-owned diversification
level at mesh scale.

Round structure (one ``step()``):

1. Occupied lanes are bucketed by their current ``(K-budget, k)`` and each
   bucket is dispatched at exactly that budget, padded to a power-of-two
   lane count (``core.bucketing``) so compile signatures stay logarithmic in
   batch size. ``eps`` is traced per lane, so mixed-eps traffic shares one
   compilation per bucket shape.
2. A lane whose Theorem-2 certificate fires (or whose budget hit the corpus
   / its ``max_K`` cap) finishes and its mesh slot is freed — the serving
   scheduler admits the next queued request into it *between rounds*, while
   sibling lanes keep their budgets. This is the request-queue half that
   ``sharded_progressive_diverse`` alone never had (per-lane budgets only).
3. Surviving lanes double their budget (clamped) for the next round; a lane
   that exhausts ``max_rounds`` finishes uncertified with its last results.

Resumption contract (``resume=``):

* ``"beam"`` (default) — truly progressive: a fixed-shape
  ``ShardedSearchState`` pytree (per-lane, per-shard beam queue + visited
  set, capacity sized once to the lane's max beam width) is carried across
  rounds, so a doubled budget *continues* each shard-local beam from the
  previous round's frontier instead of restarting ``_local_topk`` cold.
  A lane that finishes in its **first** round is bit-exact with
  ``sharded_diverse_search`` at its final K-budget (a fresh seed's round is
  the scratch computation). A **multi-round** lane reuses its expansions —
  its candidate frontier may differ from a cold run near score ties — and
  instead carries the soundness contract: a certified lane's result passes
  an independent Theorem-2 re-check against its final candidate frontier
  (``last_candidates``), and recall vs the exact diverse oracle is no worse
  than the scratch path (tested on the 10k graph), at strictly fewer
  cumulative shard expansions.
* ``"scratch"`` — the lockstep-parity escape hatch: every round re-runs the
  beams from scratch at ``K * L_factor``; every harvested lane (single- or
  multi-round) equals ``sharded_diverse_search`` at its final K-budget,
  bit-exact on the CPU host mesh.
  ``tests/dist_scripts/sharded_scheduler_check.py`` enforces this on a
  4-device host mesh, plus mid-run admission into a freed lane.

Either way ``result()`` reports *real* per-lane counters: ``expansions`` is
the lane's cumulative shard-local expansion count (summed over shards; under
``"beam"`` expansions are counted once, under ``"scratch"`` every round's
restart re-counts its redone work — the measured difference is exactly what
resumption saves), ``growths`` the budget doublings actually applied, and
``exhausted`` marks a lane whose ladder hit its ``max_K``/corpus cap without
certifying (a round-limited retirement is truncated, not exhausted).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.backend import LaneRequest
from repro.core.batch_progressive import SignatureLog
from repro.core.bucketing import pow2_group_sizes, pow2_padded_indices
from repro.core.pgs import DiverseResult
from repro.core.progressive import SearchStats
from repro.sharded_search.search import (ShardedIndex, beam_state_capacity,
                                         init_sharded_state,
                                         migrate_sharded_state,
                                         reshard_index,
                                         sharded_diverse_resume,
                                         sharded_diverse_search)

LANE_FREE, LANE_RUN, LANE_DONE = range(3)


class ShardedEngine:
    """Per-lane progressive budgets over a sharded mesh index.

    Implements ``core.backend.LaneBackend``; drive it directly (the
    ``sharded_progressive_diverse`` wrapper does) or through
    ``serve.scheduler.LaneScheduler`` for continuous batching, backpressure
    and latency stats on an N-device mesh. ``resume="beam"`` carries each
    lane's shard-local beam state across budget rounds (see the module
    docstring for the contract); ``resume="scratch"`` is the lockstep
    bit-parity mode. ``record_candidates`` keeps each lane's last merged
    candidate frontier host-side (``last_candidates``) so certificates can
    be re-verified independently.
    """

    methods = ("sharded",)

    def __init__(self, index: ShardedIndex, all_vectors, mesh,
                 num_lanes: int = 8, *, axis: str = "data",
                 K0: int = 32, L_factor: int = 4, merge: str = "tournament",
                 max_expansions: int = 100_000, max_rounds: int = 8,
                 max_k: int = 16, default_ef: int = 0,
                 max_signatures: int | None = 1024,
                 resume: str = "beam", state_capacity: int | None = None,
                 record_candidates: bool = False):
        if resume not in ("beam", "scratch"):
            raise ValueError(f"unknown resume mode {resume!r}")
        self.index = index
        #: True when the index stores compressed codes — search rounds then
        #: score quantized, and the float corpus stays HOST-side, touched
        #: only by the exact rerank of each merged frontier (contract 13)
        self.compressed = index.scheme is not None
        self.all_vectors = (np.asarray(all_vectors, np.float32)
                            if self.compressed else jnp.asarray(all_vectors))
        self.mesh = mesh
        self.axis = axis
        self.K0 = K0
        self.L_factor = L_factor
        self.merge = merge
        self.max_expansions = max_expansions
        self.max_rounds = max_rounds
        self.max_k = max_k
        # the mesh backend has no beam-ef knob (beam width = K * L_factor);
        # kept so the scheduler's ef plumbing is backend-neutral
        self.default_ef = default_ef
        self.resume = resume
        self.record_candidates = record_candidates
        self._state_capacity = state_capacity
        self._max_signatures = max_signatures
        self.B = int(num_lanes)
        self.n_total = index.num_shards * index.shard_size
        d = int(index.dim)
        self.qs = np.zeros((self.B, d), np.float32)
        self.status = np.full(self.B, LANE_FREE, np.int8)
        self.ks = np.ones(self.B, np.int64)
        self.epss = np.zeros(self.B, np.float64)
        self.K = np.zeros(self.B, np.int64)
        self.maxK = np.full(self.B, self.n_total, np.int64)
        self.rounds = np.zeros(self.B, np.int64)
        self.out_ids = np.full((self.B, max_k), -1, np.int32)
        self.out_sc = np.zeros((self.B, max_k), np.float32)
        self.cert = np.zeros(self.B, bool)
        self.expansions = np.zeros(self.B, np.int64)
        self.fresh = np.ones(self.B, bool)
        #: per-lane (cand_ids, cand_scores) of the last dispatched round,
        #: populated when ``record_candidates`` — the frontier a Theorem-2
        #: re-check verifies the certificate against
        self.last_candidates: list = [None] * self.B
        if resume == "beam":
            floor = beam_state_capacity(index, self.n_total, L_factor)
            cap = state_capacity or floor
            if cap < floor:
                # a narrower queue silently drops beam candidates: harvest
                # pads with -inf rows, which trivially satisfies the
                # certificate's min_value > s_K and voids both the parity
                # and the soundness contract — refuse at construction
                raise ValueError(
                    f"state_capacity={cap} is below the resumable-beam "
                    f"floor {floor} (beam_state_capacity); the widening "
                    "contract needs the queue to hold every rung's beam "
                    "or the whole shard")
            self.beam_state = init_sharded_state(index, self.B, cap, mesh,
                                                 axis)
        else:
            self.beam_state = None
        self.signatures = SignatureLog(max_signatures)
        self._unharvested: list[int] = []
        #: prepared elastic targets: shard count -> (mesh, index), built and
        #: prewarmed ahead of the scale event by ``prepare_rescale``
        self._rescale_targets: dict[int, tuple] = {}

    # -- protocol surface ---------------------------------------------------
    @property
    def num_lanes(self) -> int:
        return self.B

    @property
    def num_shards(self) -> int:
        return self.index.num_shards

    @property
    def bytes_per_vector(self) -> float:
        """Stored corpus bytes per vector on a device (f32: ``4 * d``;
        quantized: codes + amortized scale/codebook sidecars)."""
        return float(self.index.corpus_bytes_per_vector())

    @property
    def signature_log(self) -> SignatureLog:
        return self.signatures

    def free_lanes(self) -> np.ndarray:
        return np.flatnonzero(self.status == LANE_FREE)

    def active_count(self) -> int:
        return int((self.status == LANE_RUN).sum())

    def admit(self, lane: int, request: LaneRequest) -> None:
        """Hand a free mesh lane to ``request``: fresh budget ladder from
        ``K0``; sibling lanes keep their in-flight budgets (and, under
        ``resume="beam"``, their in-flight beam frontiers)."""
        if self.status[lane] != LANE_FREE:
            raise RuntimeError(f"mesh lane {lane} is still occupied")
        k = int(request.k)
        if k > self.max_k:
            raise ValueError(f"k={k} exceeds engine max_k={self.max_k}")
        if request.method not in self.methods:
            raise ValueError(
                f"unknown sharded method {request.method!r}")
        self.qs[lane] = np.asarray(request.q, np.float32)
        self.ks[lane] = k
        self.epss[lane] = float(request.eps)
        self.maxK[lane] = min(request.max_K or self.n_total, self.n_total)
        self.K[lane] = min(max(self.K0, 2 * k), self.maxK[lane])
        self.rounds[lane] = 0
        self.out_ids[lane] = -1
        self.out_sc[lane] = 0.0
        self.cert[lane] = False
        self.expansions[lane] = 0
        self.fresh[lane] = True   # first dispatch re-seeds the beam state
        self.last_candidates[lane] = None
        self.status[lane] = LANE_RUN

    def recycle(self, lane: int) -> None:
        """Return a harvested lane's mesh slot to the free pool; the lane's
        carried beam state is cleared (re-seeded on the next admit)."""
        if self.status[lane] != LANE_DONE:
            raise RuntimeError(f"mesh lane {lane} is not finished")
        self.fresh[lane] = True
        self.status[lane] = LANE_FREE

    # -- the round ----------------------------------------------------------
    def _dispatch(self, idx: np.ndarray, Kval: int, k_g: int) -> None:
        padded = pow2_padded_indices(idx)
        self.signatures.note("sharded", len(padded), Kval, k_g)
        m = len(idx)
        if self.resume == "beam":
            ids, scores, cand_ids, cand_sc, cert, self.beam_state = \
                sharded_diverse_resume(
                    self.index, self.all_vectors, self.beam_state,
                    jnp.asarray(self.qs[padded]), padded,
                    self.fresh[padded], k_g,
                    jnp.asarray(self.epss[padded], jnp.float32), Kval,
                    self.mesh, self.axis, self.L_factor, self.merge,
                    "div_astar", self.max_expansions)
            self.fresh[idx] = False
            # cumulative per-lane expansions since the lane's seed: the
            # carried state's step counters summed over shards
            steps = np.asarray(self.beam_state.steps).sum(axis=0)
            self.expansions[idx] = steps[idx]
        else:
            ids, scores, cert, exp = sharded_diverse_search(
                self.index, self.all_vectors, jnp.asarray(self.qs[padded]),
                k_g, jnp.asarray(self.epss[padded], jnp.float32), Kval,
                self.mesh, self.axis, self.L_factor, self.merge,
                "div_astar", self.max_expansions, with_expansions=True)
            cand_ids = cand_sc = None
            # every scratch round redoes (and re-counts) its prior work
            self.expansions[idx] += np.asarray(exp)[:m]
        self.out_ids[idx, :k_g] = np.asarray(ids)[:m]
        self.out_sc[idx, :k_g] = np.asarray(scores)[:m]
        self.cert[idx] = np.asarray(cert)[:m]
        if self.record_candidates and cand_ids is not None:
            cids, csc = np.asarray(cand_ids), np.asarray(cand_sc)
            for row, lane in enumerate(idx):
                self.last_candidates[int(lane)] = (cids[row].copy(),
                                                   csc[row].copy())

    def step(self) -> list[int]:
        """Advance every occupied mesh lane one budget round; returns the
        lanes that finished (also queued for ``harvest``)."""
        active = self.status == LANE_RUN
        if not active.any():
            return []
        buckets: dict[tuple, list[int]] = {}
        for i in np.flatnonzero(active):
            buckets.setdefault((int(self.K[i]), int(self.ks[i])), []).append(i)
        for (Kval, k_g), idx in sorted(buckets.items()):
            self._dispatch(np.asarray(idx), Kval, k_g)
        self.rounds[active] += 1
        finished = active & (self.cert | (self.K >= self.maxK))
        still = active & ~finished
        # a lane out of rounds retires uncertified at its *current* budget
        # (so K_final is always a budget that was actually dispatched — the
        # parity anchor); only true survivors double for the next round
        retired = still & (self.rounds >= self.max_rounds)
        cont = still & ~retired
        self.K[cont] = np.minimum(self.K[cont] * 2, self.maxK[cont])
        done = np.flatnonzero(finished | retired)
        for lane in done:
            self.status[lane] = LANE_DONE
            self._unharvested.append(int(lane))
        return [int(x) for x in done]

    def harvest(self) -> list[tuple[int, DiverseResult]]:
        """Drain finished lanes since the last harvest; each lane stays
        reserved until ``recycle``."""
        out = [(lane, self.result(lane)) for lane in self._unharvested]
        self._unharvested = []
        return out

    def result(self, lane: int) -> DiverseResult:
        """Solo-call-compatible result with the lane's real counters.

        Under ``resume="scratch"`` (or a single-round lane under
        ``resume="beam"``) the (ids, scores, certified) equal
        ``sharded_diverse_search`` for this query at ``stats.K_final``.
        """
        k = int(self.ks[lane])
        ids = self.out_ids[lane, :k].copy()
        sc = self.out_sc[lane, :k].copy()
        certified = bool(self.cert[lane])
        stats = SearchStats(
            expansions=int(self.expansions[lane]),
            growths=max(0, int(self.rounds[lane]) - 1),
            search_calls=int(self.rounds[lane]),
            div_calls=int(self.rounds[lane]),
            certified=certified,
            exhausted=bool(not certified
                           and int(self.K[lane]) >= int(self.maxK[lane])),
            K_final=int(self.K[lane]))
        return DiverseResult(ids.astype(np.int32), sc.astype(np.float32),
                             float(sc.sum()), stats)

    # -- epoch swap ----------------------------------------------------------
    def swap_index(self, index: ShardedIndex, all_vectors) -> None:
        """Install a new epoch's sharded index (the mutable index's rebuild
        swap). Only legal with no lane ``LANE_RUN``: the carried
        ``ShardedSearchState`` is laid out per shard of the *old* corpus,
        so it is re-initialized over the new one — the serving layer drains
        in-flight lanes first (contract 15; finished-but-unrecycled lanes
        keep their host-side results). The shard count and mesh are fixed
        across swaps (the rebuild pads the corpus to divisibility instead);
        the signature log carries across so recompile audits span epochs
        (a grown shard legitimately traces new shapes)."""
        if self.active_count():
            raise RuntimeError("cannot swap the index under occupied lanes "
                               "— drain in-flight lanes first (contract 15)")
        if index.num_shards != self.index.num_shards:
            raise ValueError(
                f"epoch swap cannot change the shard count "
                f"({self.index.num_shards} -> {index.num_shards}); pad the "
                "corpus to divisibility instead")
        self.index = index
        self.compressed = index.scheme is not None
        self.all_vectors = (np.asarray(all_vectors, np.float32)
                            if self.compressed else jnp.asarray(all_vectors))
        self.n_total = index.num_shards * index.shard_size
        self.maxK = np.minimum(self.maxK, self.n_total)
        if self.resume == "beam":
            floor = beam_state_capacity(self.index, self.n_total,
                                        self.L_factor)
            cap = self._state_capacity or floor
            if cap < floor:
                raise ValueError(
                    f"state_capacity={cap} is below the new epoch's "
                    f"resumable-beam floor {floor}")
            self.beam_state = init_sharded_state(self.index, self.B, cap,
                                                 self.mesh, self.axis)
            self.fresh[:] = True
        # prepared elastic targets hold the *old* epoch's rows — serving a
        # rescale onto one would resurrect the pre-swap corpus; the elastic
        # controller re-prepares its targets over the new epoch
        self._rescale_targets.clear()
        self.signatures.note("swap", self.B, self.n_total)

    # -- elastic rescale -----------------------------------------------------
    def _target_capacity(self, index: ShardedIndex) -> int:
        floor = beam_state_capacity(index, self.n_total, self.L_factor)
        cap = self._state_capacity or floor
        if cap < floor:
            # shrinking the mesh grows the shard size, which can RAISE the
            # resumable-beam floor past a pinned state_capacity — refuse at
            # prepare time, not mid-migration
            raise ValueError(
                f"state_capacity={cap} is below the {index.num_shards}-shard "
                f"resumable-beam floor {floor} (beam_state_capacity)")
        return cap

    def prepare_rescale(self, shards: int, mesh, index: ShardedIndex | None
                        = None, *, M: int | None = None,
                        builder: str = "knng", prewarm: bool = True,
                        max_capacity: int | None = None,
                        ks: tuple = (),
                        num_lanes: int | None = None) -> ShardedIndex:
        """Build (or adopt) and prewarm an elastic target mesh.

        Resharding and compilation are the expensive halves of a scale
        event, so both happen here, ahead of load: the corpus is
        repartitioned onto ``shards`` (``reshard_index`` — quantized codes
        re-blocked exactly, graphs rebuilt), and the target mesh's dispatch
        ladder is compiled by executing dummy rounds against a throwaway
        beam state at the *post-rescale* queue capacity, so post-scale
        traffic re-enters cached jit callables (``resume_jit_cache_sizes``
        stays flat — the zero-recompile discipline extends to the new
        mesh). Signatures are mesh-independent ``("sharded", …)`` tuples
        plus one planned ``("rescale", shards)`` marker, so preparing both
        targets before ``signature_log.freeze()`` keeps scale events off
        the unplanned list. The actual ``rescale`` is then only the
        in-flight state migration — milliseconds, not a rebuild.

        ``num_lanes`` gives the target its own lane count (default: keep
        the current one) — serving capacity follows the mesh, so a grow
        typically scales lanes with devices and the prewarmed ladder here
        covers the wider lane groups. A lane shrink is applied only when
        the tail lanes are free at rescale time (it never drops an
        occupied lane; the scheduler's elastic trigger only shrinks an
        idle engine).
        """
        if shards & (shards - 1) or shards < 1:
            raise ValueError(f"shards={shards} must be a power of two")
        B_t = int(num_lanes or self.B)
        if B_t < 1:
            raise ValueError(f"num_lanes={B_t} must be >= 1")
        if index is None:
            index = reshard_index(
                self.index, shards,
                self.all_vectors if self.compressed else None,
                M=M, builder=builder)
        if index.num_shards != shards:
            raise ValueError(f"prepared index has {index.num_shards} "
                             f"shards, expected {shards}")
        if index.num_shards * index.shard_size != self.n_total:
            raise ValueError("elastic targets must cover the same corpus "
                             "(resharding is a capacity knob)")
        self.signatures.note("rescale", shards)
        # preparing a target implies the return path: scaling back to the
        # current topology is planned too
        self.signatures.note("rescale", self.index.num_shards)
        if prewarm and shards != self.index.num_shards:
            cap = (self._target_capacity(index)
                   if self.resume == "beam" else 0)
            state = (init_sharded_state(index, B_t, cap, mesh, self.axis)
                     if self.resume == "beam" else None)
            d = int(index.dim)
            top = min(max_capacity or self.K0, self.n_total)
            for g in pow2_group_sizes(B_t):
                qs = jnp.zeros((g, d), jnp.float32)
                epss = jnp.zeros((g,), jnp.float32)
                for k in tuple(int(kk) for kk in ks) or (self.max_k,):
                    K = min(max(self.K0, 2 * k), self.n_total)
                    while True:
                        self.signatures.note("sharded", g, K, k)
                        if self.resume == "beam":
                            sharded_diverse_resume(
                                index, self.all_vectors, state, qs,
                                np.zeros(g, np.int64), np.ones(g, bool),
                                k, epss, K, mesh, self.axis, self.L_factor,
                                self.merge, "div_astar", self.max_expansions)
                        else:
                            sharded_diverse_search(
                                index, self.all_vectors, qs, k, epss, K,
                                mesh, self.axis, self.L_factor, self.merge,
                                "div_astar", self.max_expansions,
                                with_expansions=True)
                        if K >= top:
                            break
                        K = min(K * 2, self.n_total)
        self._rescale_targets[shards] = (mesh, index, B_t)
        return index

    def rescale_options(self) -> tuple[int, ...]:
        """Shard counts this engine can serve at right now: the current
        mesh plus every prepared elastic target."""
        return tuple(sorted(set(self._rescale_targets)
                            | {self.index.num_shards}))

    def rescale(self, shards: int) -> bool:
        """Quiesce-free scale event: move the corpus AND every in-flight
        lane to the prepared ``shards``-shard mesh, between rounds.

        Unlike ``swap_index`` (same corpus *content* change, which drains
        lanes first), a rescale migrates the carried ``ShardedSearchState``
        — each lane's per-shard queues re-bucket by global id, visited
        bits follow their rows, step counters keep their per-lane totals —
        so occupied lanes resume their budget ladder on the new topology
        without redoing expansions (contract 16). When the target was
        prepared with its own lane count, the lane axis scales too:
        serving capacity follows the mesh. Extra lanes are appended
        ``LANE_FREE``; a lane shrink is applied only if the tail lanes are
        free right now — an occupied lane is never dropped, the engine
        just keeps its current width until the tail drains. The outgoing
        configuration is remembered as a target, so scaling back is always
        one prepared ``rescale`` away. Returns False for a no-op (already
        at ``shards``); raises if the target was never prepared.
        """
        if shards == self.index.num_shards:
            return False
        target = self._rescale_targets.get(shards)
        if target is None:
            raise RuntimeError(
                f"no prepared target for {shards} shards — call "
                "prepare_rescale first (resharding + compilation are the "
                "expensive halves; the scale event itself must not pay "
                "them)")
        mesh, index, B_t = target
        # remember the outgoing config so the controller can scale back
        self._rescale_targets[self.index.num_shards] = (self.mesh,
                                                        self.index, self.B)
        B_new = B_t
        if B_new < self.B and (self.status[B_new:] != LANE_FREE).any():
            B_new = self.B   # occupied tail: keep width, shrink shards only
        if self.resume == "beam":
            self.beam_state = migrate_sharded_state(
                self.beam_state, shards, self._target_capacity(index),
                mesh=mesh, axis=self.axis, num_lanes=B_new)
        if B_new != self.B:
            self._resize_lanes(B_new)
        self.index = index
        self.mesh = mesh
        self.signatures.note("rescale", shards)
        return True

    def _resize_lanes(self, B_new: int) -> None:
        """Pad (grow) or slice (shrink) every per-lane host array to
        ``B_new`` lanes, preserving the surviving prefix verbatim. The
        caller guarantees dropped tail lanes are ``LANE_FREE``."""
        B = self.B

        def grow(a, fill):
            out = np.full((B_new,) + a.shape[1:], fill, a.dtype)
            out[:B] = a
            return out

        if B_new > B:
            self.qs = grow(self.qs, 0)
            self.status = grow(self.status, LANE_FREE)
            self.ks = grow(self.ks, 1)
            self.epss = grow(self.epss, 0)
            self.K = grow(self.K, 0)
            self.maxK = grow(self.maxK, self.n_total)
            self.rounds = grow(self.rounds, 0)
            self.out_ids = grow(self.out_ids, -1)
            self.out_sc = grow(self.out_sc, 0)
            self.cert = grow(self.cert, False)
            self.expansions = grow(self.expansions, 0)
            self.fresh = grow(self.fresh, True)
            self.last_candidates += [None] * (B_new - B)
        else:
            for name in ("qs", "status", "ks", "epss", "K", "maxK",
                         "rounds", "out_ids", "out_sc", "cert",
                         "expansions", "fresh"):
                setattr(self, name, getattr(self, name)[:B_new])
            self.last_candidates = self.last_candidates[:B_new]
        self.B = B_new

    # -- prewarm ------------------------------------------------------------
    def prewarm(self, *, max_capacity: int | None = None, ks: tuple = (),
                widths: tuple = ()) -> list[tuple]:
        """Compile the mesh dispatch ladder ahead of serving.

        Walks the power-of-two group sizes up to ``num_lanes`` crossed with
        the budget-doubling ladder from ``K0`` up to ``max_capacity``
        (default: one rung, ``K0`` only — mesh dispatches *execute* the
        search, so a full-corpus warmup is a real cost the caller opts into)
        for each ``k`` in ``ks`` (default: ``max_k``). Under
        ``resume="beam"`` the fresh/resumed distinction is traced, so the
        ladder covers both; signatures stay one per (group, K, k) rung.
        ``widths`` is accepted for signature-compatibility with the
        single-host backend and ignored (the mesh backend has no
        prefix-width stage).
        """
        del widths
        if (self.status != LANE_FREE).any():
            raise RuntimeError("prewarm before admitting requests (prewarm "
                               "dispatches scribble on lane 0's result row)")
        top = min(max_capacity or self.K0, self.n_total)
        ks = tuple(int(k) for k in ks) or (self.max_k,)
        warmed: list[tuple] = []
        for g in pow2_group_sizes(self.B):
            for k in ks:
                K = min(max(self.K0, 2 * k), self.n_total)
                self.fresh[0] = True   # each ladder seeds lane 0 afresh
                while True:
                    self._dispatch(np.zeros(g, np.int64), K, k)
                    warmed.append(("sharded", g, K, k))
                    if K >= top:
                        break
                    K = min(K * 2, self.n_total)
        # prewarm dispatches scribble on (free) lane 0's rows; wipe them
        self.out_ids[0] = -1
        self.out_sc[0] = 0.0
        self.cert[0] = False
        self.expansions[0] = 0
        self.fresh[0] = True
        self.last_candidates[0] = None
        return warmed
