"""Distributed ADk-NNS: the paper's technique mapped onto a device mesh.

Scale-out story (DESIGN.md §2/§5): the database is partitioned into P shards
along the mesh's data axis (pod x data at multi-pod scale). Each device owns
one shard's proximity graph and runs the *same* fixed-shape beam search as
the single-device path (shard-local candidates carry global ids). Results
combine via a **tournament merge**: log2(P) butterfly rounds of
``ppermute`` + bitonic ``topk_merge``, so each device moves O(L log P) bytes
instead of the O(L * P) an all-gather-then-sort would ship. Diversification
(greedy or div-A*) then runs on the replicated merged candidates — its cost
is independent of N, exactly the paper's candidates-then-diversify split.

Naive all-gather merge is kept as ``merge="allgather"`` for the §Perf
baseline/optimized comparison.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import beam_search as bs
from repro.core import div_astar as da
from repro.core.graph import make_flat_graph
from repro.core.theorems import theorem2_min_value
from repro.kernels import ops as kops


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedIndex:
    """Per-shard graphs stacked on a leading shard axis."""
    vectors: jnp.ndarray    # [P, Ns, d]
    neighbors: jnp.ndarray  # [P, Ns, M0]
    entries: jnp.ndarray    # [P]
    bases: jnp.ndarray      # [P] global-id base of each shard
    metric: str = dataclasses.field(metadata=dict(static=True), default="l2")

    @property
    def num_shards(self) -> int:
        return self.vectors.shape[0]

    @property
    def shard_size(self) -> int:
        return self.vectors.shape[1]


def build_sharded_index(vectors: np.ndarray, num_shards: int, metric: str,
                        M: int = 16, builder="knng") -> ShardedIndex:
    """Partition the database round-robin and build one graph per shard."""
    from repro.index.flat import build_knn_graph
    from repro.index.hnsw import build_hnsw

    n = vectors.shape[0]
    ns = n // num_shards
    assert ns * num_shards == n, "dataset must split evenly across shards"
    vecs, nbrs, entries, bases = [], [], [], []
    for s in range(num_shards):
        chunk = np.asarray(vectors[s * ns:(s + 1) * ns], np.float32)
        if builder == "hnsw":
            g = build_hnsw(chunk, metric=metric, M=M)
        else:
            g = build_knn_graph(chunk, metric=metric, M=M)
        vecs.append(np.asarray(g.vectors))
        nbrs.append(np.asarray(g.neighbors))
        entries.append(int(g.entry))
        bases.append(s * ns)
    m0 = max(a.shape[1] for a in nbrs)
    nbrs = [np.pad(a, ((0, 0), (0, m0 - a.shape[1])), constant_values=-1)
            for a in nbrs]
    return ShardedIndex(
        vectors=jnp.asarray(np.stack(vecs)),
        neighbors=jnp.asarray(np.stack(nbrs)),
        entries=jnp.asarray(np.array(entries, np.int32)),
        bases=jnp.asarray(np.array(bases, np.int32)),
        metric=metric,
    )


def _local_topk(vectors, neighbors, entry, base, qs, metric: str,
                k: int, L: int):
    """Shard-local beam search for a query batch; returns GLOBAL ids."""
    graph = make_flat_graph(vectors, neighbors, None, entry, metric)

    def one(q):
        state = bs.init_state(graph, q, L, use_descent=False)
        state = bs.run_search(graph, q, state, stable_limit=L)
        ids = state.queue.ids[:k]
        return jnp.where(ids >= 0, ids + base, -1), state.queue.scores[:k]

    return jax.vmap(one)(qs)


def _tournament_merge(ids, scores, axis: str, p: int):
    """Butterfly merge: after log2(p) rounds every device holds global top-k."""
    assert p & (p - 1) == 0, "tournament merge needs power-of-two shards"
    rounds = p.bit_length() - 1
    for r in range(rounds):
        stride = 1 << r
        perm = [(i, i ^ stride) for i in range(p)]
        other_ids = jax.lax.ppermute(ids, axis, perm)
        other_scores = jax.lax.ppermute(scores, axis, perm)
        merged = jax.vmap(kops.topk_merge)(ids, scores, other_ids, other_scores)
        ids, scores = merged
    return ids, scores


def _allgather_merge(ids, scores, axis: str, k: int):
    all_ids = jax.lax.all_gather(ids, axis, axis=1)       # [B, P, k]
    all_scores = jax.lax.all_gather(scores, axis, axis=1)
    b = ids.shape[0]
    flat_ids = all_ids.reshape(b, -1)
    flat_scores = all_scores.reshape(b, -1)

    def pick(i, s):
        order = jnp.lexsort((i, -s))[:k]
        return i[order], s[order]

    return jax.vmap(pick)(flat_ids, flat_scores)


def sharded_topk(index: ShardedIndex, qs: jnp.ndarray, k: int, L: int,
                 mesh: Mesh, axis: str = "data", merge: str = "tournament"):
    """Global top-k over all shards; output replicated on every device."""
    p = index.num_shards

    def shard_fn(vectors, neighbors, entries, bases, qs):
        ids, scores = _local_topk(vectors[0], neighbors[0], entries[0],
                                  bases[0], qs, index.metric, k, L)
        if p > 1:
            if merge == "tournament":
                ids, scores = _tournament_merge(ids, scores, axis, p)
            else:
                ids, scores = _allgather_merge(ids, scores, axis, k)
        return ids, scores

    shard_spec = P(axis)
    fn = shard_map(
        shard_fn, mesh,
        in_specs=(shard_spec, shard_spec, shard_spec, shard_spec, P()),
        out_specs=(P(), P()),
    )
    return fn(index.vectors, index.neighbors, index.entries, index.bases, qs)


def sharded_diverse_search(index: ShardedIndex, all_vectors: jnp.ndarray,
                           qs: jnp.ndarray, k: int, eps, K: int,
                           mesh: Mesh, axis: str = "data",
                           L_factor: int = 4, merge: str = "tournament",
                           method: str = "div_astar",
                           max_expansions: int = 100_000):
    """Distributed diverse search: sharded candidates + replicated diversify.

    Returns (ids[B, k], scores[B, k], certified[B]).
    ``all_vectors`` [N, d] is the global database used to gather candidate
    vectors for the adjacency build (replicated or resharded by the caller).
    ``eps`` may be a scalar or a per-query ``[B]`` vector (the scheduler's
    query-owned diversification level): lanes with different eps share one
    dispatch because eps is traced, never baked into the compilation.
    """
    ids, scores = sharded_topk(index, qs, K, K * L_factor, mesh, axis, merge)
    epss = jnp.broadcast_to(jnp.asarray(eps, jnp.float32), (qs.shape[0],))

    def diversify(cand_ids, cand_scores, eps_q):
        vecs = all_vectors[jnp.maximum(cand_ids, 0)]
        adj = kops.pairwise_adjacency(vecs, eps_q, index.metric, cand_ids >= 0)
        if method == "greedy":
            sel, count = kops.greedy_diversify(cand_scores, adj, k,
                                               valid=cand_ids >= 0)
            certified = count >= k
        else:
            res = da.div_astar(
                jnp.where(cand_ids >= 0, cand_scores, -jnp.inf), adj, k,
                max_expansions=max_expansions)
            sel = res.best_sets[k - 1]
            min_value = theorem2_min_value(res.best_scores, k)
            certified = (min_value > cand_scores[K - 1]) & res.complete
        out_ids = jnp.where(sel >= 0, cand_ids[jnp.maximum(sel, 0)], -1)
        out_sc = jnp.where(sel >= 0, cand_scores[jnp.maximum(sel, 0)], 0.0)
        return out_ids, out_sc, certified

    return jax.vmap(diversify)(ids, scores, epss)


def sharded_progressive_diverse(index: ShardedIndex, all_vectors: jnp.ndarray,
                                qs: jnp.ndarray, k: int, eps,
                                mesh: Mesh, axis: str = "data",
                                K0: int = 32, L_factor: int = 4,
                                merge: str = "tournament",
                                max_expansions: int = 100_000,
                                max_rounds: int = 8):
    """Progressive distributed diverse search (the paper's loop at mesh scale).

    The fixed-budget ``sharded_diverse_search`` can return uncertified lanes
    (Theorem-2 check fails: the optimal diverse set may extend past the K
    merged candidates). This entry point is a thin lockstep wrapper over
    ``sharded_search.engine.ShardedEngine`` — the mesh implementation of the
    ``core.backend.LaneBackend`` protocol: every lane carries its *own*
    candidate budget, a certified lane leaves the working set immediately,
    and each round re-dispatches only the uncertified lanes, bucketed by
    budget and padded to power-of-two sub-batch sizes so compile signatures
    stay logarithmic. (For continuous admission — new queries entering freed
    mesh lanes mid-run — drive the engine through
    ``serve.scheduler.LaneScheduler`` instead.)

    Returns (ids[B, k], scores[B, k], certified[B], K_final[B]) with
    ``K_final`` the per-lane budget at which each lane stopped — always a
    budget that was actually dispatched, so every lane's (ids, scores,
    certified) equals ``sharded_diverse_search`` for that query at its
    ``K_final``. (Previously a round-limited lane reported the doubled
    budget it never ran.)
    """
    from repro.core.backend import LaneRequest
    from repro.sharded_search.engine import ShardedEngine

    B = int(qs.shape[0])
    eng = ShardedEngine(index, all_vectors, mesh, num_lanes=B, axis=axis,
                        K0=K0, L_factor=L_factor, merge=merge,
                        max_expansions=max_expansions, max_rounds=max_rounds,
                        max_k=k)
    qs_np = np.asarray(qs, np.float32)
    epss = np.broadcast_to(np.asarray(eps, np.float64), (B,))
    for lane in range(B):
        eng.admit(lane, LaneRequest(q=qs_np[lane], k=k, eps=float(epss[lane]),
                                    method="sharded"))
    out_ids = np.full((B, k), -1, np.int32)
    out_sc = np.zeros((B, k), np.float32)
    out_cert = np.zeros(B, bool)
    K_final = np.zeros(B, np.int64)
    while eng.active_count():
        eng.step()
        for lane, res in eng.harvest():
            out_ids[lane], out_sc[lane] = res.ids, res.scores
            out_cert[lane] = res.stats.certified
            K_final[lane] = res.stats.K_final
            eng.recycle(lane)
    return out_ids, out_sc, out_cert, K_final
