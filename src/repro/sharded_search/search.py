"""Distributed ADk-NNS: the paper's technique mapped onto a device mesh.

Scale-out story (DESIGN.md §2/§5): the database is partitioned into P shards
along the mesh's data axis (pod x data at multi-pod scale). Each device owns
one shard's proximity graph and runs the *same* fixed-shape beam search as
the single-device path (shard-local candidates carry global ids). Results
combine via a **tournament merge**: log2(P) butterfly rounds of
``ppermute`` + bitonic ``topk_merge``, so each device moves O(L log P) bytes
instead of the O(L * P) an all-gather-then-sort would ship. Diversification
(greedy or div-A*) then runs on the replicated merged candidates — its cost
is independent of N, exactly the paper's candidates-then-diversify split.

Naive all-gather merge is kept as ``merge="allgather"`` for the §Perf
baseline/optimized comparison.

Progressive resumption (the paper's pause/inspect/resume at mesh scale):
the budget-doubling ladder used to re-run every shard-local beam from
scratch at each rung. ``ShardedSearchState`` now carries each lane's
per-shard queue + visited set across rounds — ``sharded_topk_resume``
re-enters ``beam_search.resume_search`` under the widened stable limit, so
a doubled budget continues expanding from the previous frontier.
``sharded_topk`` / ``sharded_diverse_search`` remain the scratch halves
(one fixed budget, no state) and stay the bit-parity reference; both paths
share the same tournament merge over harvested frontiers and the same
replicated diversify stage.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import quant
from repro.compat import shard_map
from repro.core import beam_search as bs
from repro.core import div_astar as da
from repro.core import queue as qmod
from repro.core.bucketing import next_pow2
from repro.core.graph import make_flat_graph
from repro.core.theorems import theorem2_min_value
from repro.kernels import ops as kops


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedIndex:
    """Per-shard graphs stacked on a leading shard axis.

    Float corpora live in ``vectors``. Quantized corpora (``scheme`` set by
    ``build_sharded_index(quantized=...)``) instead carry ``codes`` — plus
    ``scales`` (int8) or ``codebooks`` (pq, replicated, trained at index
    build) — sharded alongside the graph; ``vectors`` is then None and the
    float rows are retained *host-side by the caller* for the exact rerank
    stage (quantization is a memory knob, never a certificate knob:
    ``docs/ARCHITECTURE.md`` contract 13).
    """
    vectors: jnp.ndarray | None      # f32[P, Ns, d]; None when quantized
    neighbors: jnp.ndarray           # int32[P, Ns, M0]
    entries: jnp.ndarray             # int32[P]
    bases: jnp.ndarray               # int32[P] global-id base of each shard
    codes: jnp.ndarray | None = None       # int8[P, Ns, d] | uint8[P, Ns, M]
    scales: jnp.ndarray | None = None      # f32[P, nb]   (int8 scheme)
    codebooks: jnp.ndarray | None = None   # f32[M, C, ds] (pq, replicated)
    metric: str = dataclasses.field(metadata=dict(static=True), default="l2")
    scheme: str | None = dataclasses.field(metadata=dict(static=True),
                                           default=None)
    scale_rows: int = dataclasses.field(metadata=dict(static=True), default=8)

    @property
    def num_shards(self) -> int:
        return self.neighbors.shape[0]

    @property
    def shard_size(self) -> int:
        return self.neighbors.shape[1]

    @property
    def dim(self) -> int:
        if self.scheme == "pq":
            m, _, ds = self.codebooks.shape
            return m * ds
        if self.scheme == "int8":
            return self.codes.shape[-1]
        return self.vectors.shape[-1]

    def corpus_bytes_per_vector(self) -> float:
        """Stored corpus bytes per vector on a device (graph excluded;
        replicated PQ codebooks amortized over one shard — the honest
        per-device number)."""
        ns = self.shard_size
        if self.scheme == "int8":
            return (ns * self.codes.shape[-1] + self.scales.shape[-1] * 4) / ns
        if self.scheme == "pq":
            return (ns * self.codes.shape[-1] + self.codebooks.size * 4) / ns
        return 4.0 * self.dim


def _corpus_parts(index: ShardedIndex):
    """The corpus operands a shard_map dispatch needs.

    Returns ``(arrays, kinds, make)``: operand arrays, a "shard"/"repl"
    placement per operand, and a closure rebuilding the device-local corpus
    (float array or quantized corpus object) from the mapped blocks. Both
    the scratch and the resume dispatch build their operand list from this
    one helper, so the two paths cannot drift.
    """
    if index.scheme is None:
        return (index.vectors,), ("shard",), lambda a: a[0][0]
    if index.scheme == "int8":
        sr = index.scale_rows
        return ((index.codes, index.scales), ("shard", "shard"),
                lambda a: quant.Int8Corpus(codes=a[0][0], scales=a[1][0],
                                           scale_rows=sr))
    return ((index.codes, index.codebooks), ("shard", "repl"),
            lambda a: quant.PQCorpus(codes=a[0][0], codebooks=a[1]))


def build_sharded_index(vectors: np.ndarray, num_shards: int, metric: str,
                        M: int = 16, builder="knng",
                        quantized: str | None = None, scale_rows: int = 8,
                        pq_m: int | None = None, pq_codes: int = 256,
                        pq_iters: int = 10, pq_sample: int = 16384,
                        seed: int = 0) -> ShardedIndex:
    """Partition the database round-robin and build one graph per shard.

    ``quantized`` in {None, "int8", "pq"} selects the on-device corpus
    representation: graphs are always built from the float rows, but with a
    scheme set each shard stores only compressed codes (int8: one f32 scale
    per ``scale_rows`` rows; pq: uint8 codebook indices, codebooks k-means
    trained here on the full corpus and replicated; ``pq_m=None`` picks
    ``quant.default_pq_m`` for the corpus width). Callers keep the float
    ``vectors`` host-side for the exact rerank stage.
    """
    from repro.index.flat import build_knn_graph
    from repro.index.hnsw import build_hnsw

    n = vectors.shape[0]
    ns = n // num_shards
    assert ns * num_shards == n, "dataset must split evenly across shards"
    pq_global = None
    if quantized == "pq":
        if pq_m is None:
            pq_m = quant.default_pq_m(int(vectors.shape[-1]))
        pq_global = quant.train_pq(np.asarray(vectors, np.float32), m=pq_m,
                                   codes=pq_codes, iters=pq_iters, seed=seed,
                                   sample=pq_sample)
    elif quantized is not None and quantized not in quant.QUANT_SCHEMES:
        raise ValueError(f"unknown quantized scheme {quantized!r}; "
                         f"expected one of {quant.QUANT_SCHEMES} or None")
    vecs, nbrs, entries, bases = [], [], [], []
    codes, scales = [], []
    for s in range(num_shards):
        chunk = np.asarray(vectors[s * ns:(s + 1) * ns], np.float32)
        if builder == "hnsw":
            g = build_hnsw(chunk, metric=metric, M=M)
        else:
            g = build_knn_graph(chunk, metric=metric, M=M)
        vecs.append(np.asarray(g.vectors))
        nbrs.append(np.asarray(g.neighbors))
        entries.append(int(g.entry))
        bases.append(s * ns)
        if quantized == "int8":
            c = quant.quantize_int8(chunk, scale_rows=scale_rows)
            codes.append(np.asarray(c.codes))
            scales.append(np.asarray(c.scales))
        elif quantized == "pq":
            codes.append(quant.pq_encode(chunk,
                                         np.asarray(pq_global.codebooks)))
    m0 = max(a.shape[1] for a in nbrs)
    nbrs = [np.pad(a, ((0, 0), (0, m0 - a.shape[1])), constant_values=-1)
            for a in nbrs]
    return ShardedIndex(
        vectors=None if quantized else jnp.asarray(np.stack(vecs)),
        neighbors=jnp.asarray(np.stack(nbrs)),
        entries=jnp.asarray(np.array(entries, np.int32)),
        bases=jnp.asarray(np.array(bases, np.int32)),
        codes=jnp.asarray(np.stack(codes)) if quantized else None,
        scales=jnp.asarray(np.stack(scales)) if quantized == "int8" else None,
        codebooks=(jnp.asarray(pq_global.codebooks)
                   if quantized == "pq" else None),
        metric=metric,
        scheme=quantized,
        scale_rows=int(scale_rows),
    )


def reshard_index(index: ShardedIndex, num_shards: int,
                  all_vectors=None, *, M: int | None = None,
                  builder: str = "knng") -> ShardedIndex:
    """Repartition a ``ShardedIndex`` across a new power-of-two shard count.

    The partition is round-robin contiguous (shard ``s`` owns global rows
    ``[s*ns, (s+1)*ns)``), so repartitioning is a pure re-blocking of the
    stacked row arrays: global ids never move, and a quantized corpus's
    codes/scales are re-blocked **exactly** — no requantization (int8 scale
    blocks are ``scale_rows``-row aligned, which must divide the new shard
    size; PQ codebooks are replicated and untouched). Per-shard proximity
    graphs are shard-local structures and are rebuilt deterministically
    over each new partition from the float rows — resharding is a capacity
    knob, never a results knob (``docs/ARCHITECTURE.md`` contract 16), so
    a reshard round trip (4 -> 8 -> 4 with the same build parameters) is
    bit-identical to the original.

    ``all_vectors`` is the host-retained float corpus, required when the
    index is quantized (``vectors`` is None); ``M``/``builder`` must match
    the original build (``M`` defaults to the stored neighbor width
    divided by 2 — ``build_knn_graph``'s ``M0 = 2 * M`` — which is only
    correct for the default ``knng`` builder).
    """
    p_old, ns_old = index.num_shards, index.shard_size
    n = p_old * ns_old
    if num_shards & (num_shards - 1) or num_shards < 1:
        raise ValueError(f"num_shards={num_shards} must be a power of two "
                         "(tournament merge)")
    if n % num_shards:
        raise ValueError(f"corpus of {n} rows does not split across "
                         f"{num_shards} shards")
    if num_shards == p_old:
        return index
    ns_new = n // num_shards
    if index.scheme == "int8" and (ns_old % index.scale_rows
                                   or ns_new % index.scale_rows):
        raise ValueError(
            f"int8 scale blocks ({index.scale_rows} rows) must divide both "
            f"shard sizes ({ns_old} -> {ns_new}); rebuild instead of "
            "resharding")
    if index.vectors is not None:
        flat = np.asarray(index.vectors).reshape(n, -1)
    elif all_vectors is not None:
        flat = np.asarray(all_vectors, np.float32)[:n]
    else:
        raise ValueError("resharding a quantized index needs the "
                         "host-retained float corpus (all_vectors=)")
    if M is None:
        M = index.neighbors.shape[-1] // 2

    from repro.index.flat import build_knn_graph
    from repro.index.hnsw import build_hnsw

    vecs, nbrs, entries = [], [], []
    for s in range(num_shards):
        chunk = flat[s * ns_new:(s + 1) * ns_new]
        if builder == "hnsw":
            g = build_hnsw(chunk, metric=index.metric, M=M)
        else:
            g = build_knn_graph(chunk, metric=index.metric, M=M)
        vecs.append(np.asarray(g.vectors))
        nbrs.append(np.asarray(g.neighbors))
        entries.append(int(g.entry))
    m0 = max(a.shape[1] for a in nbrs)
    nbrs = [np.pad(a, ((0, 0), (0, m0 - a.shape[1])), constant_values=-1)
            for a in nbrs]
    codes = scales = None
    if index.codes is not None:
        c = np.asarray(index.codes)
        codes = jnp.asarray(c.reshape(n, *c.shape[2:])
                            .reshape(num_shards, ns_new, *c.shape[2:]))
    if index.scales is not None:
        sc = np.asarray(index.scales)
        scales = jnp.asarray(sc.reshape(-1).reshape(num_shards, -1))
    return ShardedIndex(
        vectors=None if index.scheme else jnp.asarray(np.stack(vecs)),
        neighbors=jnp.asarray(np.stack(nbrs)),
        entries=jnp.asarray(np.array(entries, np.int32)),
        bases=jnp.asarray(np.arange(num_shards, dtype=np.int32) * ns_new),
        codes=codes,
        scales=scales,
        codebooks=index.codebooks,
        metric=index.metric,
        scheme=index.scheme,
        scale_rows=index.scale_rows,
    )


def _local_topk(vectors, neighbors, entry, base, qs, metric: str,
                k: int, L: int):
    """Shard-local beam search for a query batch; returns GLOBAL ids plus
    the per-lane expansion (step) counts."""
    graph = make_flat_graph(vectors, neighbors, None, entry, metric)

    def one(q):
        state = bs.init_state(graph, q, L, use_descent=False)
        state = bs.run_search(graph, q, state, stable_limit=L)
        ids = state.queue.ids[:k]
        return (jnp.where(ids >= 0, ids + base, -1),
                state.queue.scores[:k], state.steps)

    return jax.vmap(one)(qs)


def _tournament_merge(ids, scores, axis: str, p: int):
    """Butterfly merge: after log2(p) rounds every device holds global top-k."""
    assert p & (p - 1) == 0, "tournament merge needs power-of-two shards"
    rounds = p.bit_length() - 1
    for r in range(rounds):
        stride = 1 << r
        perm = [(i, i ^ stride) for i in range(p)]
        other_ids = jax.lax.ppermute(ids, axis, perm)
        other_scores = jax.lax.ppermute(scores, axis, perm)
        merged = jax.vmap(kops.topk_merge)(ids, scores, other_ids, other_scores)
        ids, scores = merged
    return ids, scores


def _allgather_merge(ids, scores, axis: str, k: int):
    all_ids = jax.lax.all_gather(ids, axis, axis=1)       # [B, P, k]
    all_scores = jax.lax.all_gather(scores, axis, axis=1)
    b = ids.shape[0]
    flat_ids = all_ids.reshape(b, -1)
    flat_scores = all_scores.reshape(b, -1)

    def pick(i, s):
        order = jnp.lexsort((i, -s))[:k]
        return i[order], s[order]

    return jax.vmap(pick)(flat_ids, flat_scores)


def sharded_topk(index: ShardedIndex, qs: jnp.ndarray, k: int, L: int,
                 mesh: Mesh, axis: str = "data", merge: str = "tournament",
                 with_expansions: bool = False):
    """Global top-k over all shards; output replicated on every device.

    This is the *scratch* half: every call restarts each shard-local beam at
    its entry point (see ``sharded_topk_resume`` for the stateful half).
    With ``with_expansions`` the per-lane expansion counts summed over
    shards come back as a third output. Quantized indexes score compressed
    codes inside the shard_map — same loop, same merge; only the scoring
    representation changes.
    """
    p = index.num_shards
    arrays, kinds, make = _corpus_parts(index)
    nc = len(arrays)

    def shard_fn(*args):
        corpus = make(args[:nc])
        neighbors, entries, bases, qs = args[nc:]
        ids, scores, steps = _local_topk(corpus, neighbors[0], entries[0],
                                         bases[0], qs, index.metric, k, L)
        if p > 1:
            if merge == "tournament":
                ids, scores = _tournament_merge(ids, scores, axis, p)
            else:
                ids, scores = _allgather_merge(ids, scores, axis, k)
        return ids, scores, jax.lax.psum(steps, axis)

    shard_spec = P(axis)
    fn = shard_map(
        shard_fn, mesh,
        in_specs=tuple(shard_spec if kd == "shard" else P() for kd in kinds)
        + (shard_spec, shard_spec, shard_spec, P()),
        out_specs=(P(), P(), P()),
    )
    ids, scores, expansions = fn(*arrays, index.neighbors,
                                 index.entries, index.bases, qs)
    if with_expansions:
        return ids, scores, expansions
    return ids, scores


# ------------------------------------------------- resumable shard beams ----

class ShardedSearchState(NamedTuple):
    """Fixed-shape per-lane, per-shard beam state carried across budget
    rounds (leading axis sharded along the mesh's data axis).

    One lane's slice ``(ids[s, b], scores[s, b], stable[s, b], visited[s, b],
    steps[s, b])`` is exactly a ``beam_search.SearchState`` for that lane's
    beam on shard ``s``. Capacity is sized once, at the lane's max beam
    width (``beam_state_capacity``), so the queue never changes shape as the
    budget ladder doubles — the "wider queue" of each rung is the same
    queue under a wider stable limit.
    """
    ids: jnp.ndarray      # int32[P, B, C] shard-local candidate ids
    scores: jnp.ndarray   # f32[P, B, C]
    stable: jnp.ndarray   # bool[P, B, C]
    visited: jnp.ndarray  # bool[P, B, Ns]
    steps: jnp.ndarray    # int32[P, B]

    @property
    def capacity(self) -> int:
        return self.ids.shape[-1]


def beam_state_capacity(index: ShardedIndex, K_max: int,
                        L_factor: int = 4) -> int:
    """Queue width for resumable shard-local beams.

    Wide enough that either no dispatched rung's beam (``K * L_factor``)
    ever drops a candidate, or the whole shard fits — the precondition for
    the first round being bit-exact with the scratch search at the narrow
    width (see ``beam_search.resume_search``'s widening contract).
    """
    return min(next_pow2(max(int(K_max) * int(L_factor), 1)),
               next_pow2(index.shard_size))


def init_sharded_state(index: ShardedIndex, num_lanes: int, capacity: int,
                       mesh: Mesh | None = None,
                       axis: str = "data") -> ShardedSearchState:
    """Empty (all lanes unseeded) state, device-sharded along ``axis``."""
    p, ns = index.num_shards, index.shard_size
    leaves = ShardedSearchState(
        ids=jnp.full((p, num_lanes, capacity), -1, jnp.int32),
        scores=jnp.full((p, num_lanes, capacity), -jnp.inf, jnp.float32),
        stable=jnp.ones((p, num_lanes, capacity), jnp.bool_),
        visited=jnp.zeros((p, num_lanes, ns), jnp.bool_),
        steps=jnp.zeros((p, num_lanes), jnp.int32),
    )
    if mesh is None:
        return leaves
    sharding = NamedSharding(mesh, P(axis))
    return ShardedSearchState(
        *(jax.device_put(leaf, sharding) for leaf in leaves))


def migrate_sharded_state(state: ShardedSearchState, num_shards: int,
                          capacity: int | None = None,
                          mesh: Mesh | None = None,
                          axis: str = "data",
                          num_lanes: int | None = None) -> ShardedSearchState:
    """Re-bucket in-flight per-lane beam state onto a new shard layout.

    The contiguous partition makes every queue entry's global id
    ``local + s * ns``; migration maps each entry to its new shard, re-sorts
    every (lane, shard) queue under the canonical (score desc, id asc)
    order, and re-blocks the visited bitmap — set bits follow their global
    row, so no expansion is ever redone after a scale event. ``steps``
    preserves each lane's per-shard totals (a split shard's counter rides
    on its first child; merged shards sum), which keeps both the engine's
    cumulative-expansion counters and ``resume_search``'s relative step
    budget exact. With the engine-default capacity
    (``beam_state_capacity``) no entry can be dropped: a new shard holds at
    most ``ns_new <= capacity`` distinct ids.

    ``num_lanes`` resizes the lane axis alongside the shard axis (serving
    capacity follows the mesh): extra lanes are appended empty (unseeded),
    a smaller count keeps lanes ``[:num_lanes]`` verbatim and drops the
    tail — the caller is responsible for only dropping lanes whose beams
    are dead (the engine drops ``LANE_FREE`` tails only).

    Host-side by design — scale events are rare, and the migrated pytree is
    ``device_put`` onto ``mesh`` exactly like ``init_sharded_state``.
    """
    ids = np.asarray(state.ids)
    scores = np.asarray(state.scores)
    stable = np.asarray(state.stable)
    visited = np.asarray(state.visited)
    steps = np.asarray(state.steps)
    p_old, B, C_old = ids.shape
    ns_old = visited.shape[-1]
    n = p_old * ns_old
    if num_shards & (num_shards - 1) or n % num_shards:
        raise ValueError(f"cannot migrate {p_old}x{ns_old} beam state to "
                         f"{num_shards} shards")
    ns_new = n // num_shards
    C_new = int(capacity or C_old)

    # queue entries -> global ids, flattened over the old shard axis
    bases_old = (np.arange(p_old, dtype=np.int64) * ns_old)[:, None, None]
    gids = np.where(ids >= 0, ids.astype(np.int64) + bases_old, -1)
    gids = gids.transpose(1, 0, 2).reshape(B, -1)       # [B, p_old*C_old]
    sc = scores.transpose(1, 0, 2).reshape(B, -1)
    st = stable.transpose(1, 0, 2).reshape(B, -1)

    new_ids = np.full((num_shards, B, C_new), -1, np.int32)
    new_sc = np.full((num_shards, B, C_new), -np.inf, np.float32)
    new_st = np.ones((num_shards, B, C_new), np.bool_)
    for s in range(num_shards):
        lo, hi = s * ns_new, (s + 1) * ns_new
        for b in range(B):
            sel = (gids[b] >= lo) & (gids[b] < hi)
            g, s_b, t_b = gids[b][sel], sc[b][sel], st[b][sel]
            if len(g) > C_new:
                # silently dropping beam candidates would void the widening
                # contract the same way an under-floor state_capacity does
                raise ValueError(
                    f"capacity {C_new} cannot hold the {len(g)} migrated "
                    f"candidates of lane {b} shard {s}; size the target "
                    "state with beam_state_capacity")
            order = np.lexsort((g, -s_b))
            m = len(order)
            new_ids[s, b, :m] = (g[order] - lo).astype(np.int32)
            new_sc[s, b, :m] = s_b[order]
            new_st[s, b, :m] = t_b[order]

    new_vis = (visited.transpose(1, 0, 2).reshape(B, n)
               .reshape(B, num_shards, ns_new).transpose(1, 0, 2))
    if num_shards >= p_old:
        f = num_shards // p_old
        new_steps = np.zeros((num_shards, B), np.int32)
        new_steps[::f] = steps
    else:
        f = p_old // num_shards
        new_steps = steps.reshape(num_shards, f, B).sum(axis=1,
                                                        dtype=np.int32)
    B_new = int(num_lanes or B)
    if B_new != B:
        def _lanes(a, fill):
            out = np.full(a.shape[:1] + (B_new,) + a.shape[2:], fill,
                          a.dtype)
            out[:, :min(B, B_new)] = a[:, :min(B, B_new)]
            return out
        new_ids = _lanes(new_ids, -1)
        new_sc = _lanes(new_sc, -np.inf)
        new_st = _lanes(new_st, True)
        new_vis = _lanes(new_vis, False)
        new_steps = _lanes(new_steps, 0)
    leaves = ShardedSearchState(
        ids=jnp.asarray(new_ids), scores=jnp.asarray(new_sc),
        stable=jnp.asarray(new_st), visited=jnp.asarray(new_vis),
        steps=jnp.asarray(new_steps))
    if mesh is None:
        return leaves
    sharding = NamedSharding(mesh, P(axis))
    return ShardedSearchState(
        *(jax.device_put(leaf, sharding) for leaf in leaves))


_RESUME_DISPATCH_FNS: dict[tuple, object] = {}


def _resume_dispatch_fn(index: ShardedIndex, mesh: Mesh, axis: str, K: int,
                        C: int, merge: str):
    """Jitted shard_map dispatch for one (mesh, K-harvest, capacity) rung.

    Cached on its static key — which includes the corpus scheme, so float
    and quantized indexes never share a rung — so repeat traffic re-enters
    the same jit callable; the resume path's equivalent of the single-host
    engine's module-level jits (``resume_jit_cache_sizes`` audits these).
    """
    metric, p = index.metric, index.num_shards
    key = (mesh, axis, metric, p, K, C, merge, index.scheme,
           index.scale_rows)
    fn = _RESUME_DISPATCH_FNS.get(key)
    if fn is not None:
        return fn
    _, kinds, make = _corpus_parts(index)
    nc = len(kinds)

    def shard_fn(*args):
        corpus = make(args[:nc])
        (neighbors, entries, bases, s_ids, s_sc, s_st, s_vis, s_steps,
         qs, idx, fresh, limit, budget) = args[nc:]
        graph = make_flat_graph(corpus, neighbors[0], None, entries[0],
                                metric)
        base = bases[0]
        ids_b, sc_b, st_b = s_ids[0], s_sc[0], s_st[0]       # [B, C]
        vis_b, steps_b = s_vis[0], s_steps[0]                # [B, Ns], [B]

        def one(q, f, ids, sc, st, vis, steps):
            cur = bs.SearchState(qmod.Queue(ids, sc, st), vis, steps)
            seeded = bs.init_state(graph, q, C, use_descent=False)
            cur = jax.tree_util.tree_map(
                lambda a, b: jnp.where(f, a, b), seeded, cur)
            cur = bs.resume_search(graph, q, cur, stable_limit=limit,
                                   step_budget=budget)
            h = min(K, C)
            hid = cur.queue.ids[:h]
            out_ids = jnp.where(hid >= 0, hid + base, -1)
            out_sc = cur.queue.scores[:h]
            if h < K:              # budget exceeds the shard's own content
                pad = K - h
                out_ids = jnp.concatenate(
                    [out_ids, jnp.full((pad,), -1, jnp.int32)])
                out_sc = jnp.concatenate(
                    [out_sc, jnp.full((pad,), qmod.NEG_INF, jnp.float32)])
            return out_ids, out_sc, cur

        out_ids, out_sc, new = jax.vmap(one)(
            qs, fresh, ids_b[idx], sc_b[idx], st_b[idx], vis_b[idx],
            steps_b[idx])
        # scatter the group's rows back; padded duplicate indices recompute
        # the same lane from the same state, so duplicate writes carry
        # identical values and the scatter stays deterministic
        ids_b = ids_b.at[idx].set(new.queue.ids)
        sc_b = sc_b.at[idx].set(new.queue.scores)
        st_b = st_b.at[idx].set(new.queue.stable)
        vis_b = vis_b.at[idx].set(new.visited)
        steps_b = steps_b.at[idx].set(new.steps)
        if p > 1:
            if merge == "tournament":
                out_ids, out_sc = _tournament_merge(out_ids, out_sc, axis, p)
            else:
                out_ids, out_sc = _allgather_merge(out_ids, out_sc, axis, K)
        return (out_ids, out_sc, ids_b[None], sc_b[None], st_b[None],
                vis_b[None], steps_b[None])

    sspec = P(axis)
    mapped = shard_map(
        shard_fn, mesh,
        in_specs=tuple(sspec if kd == "shard" else P() for kd in kinds)
        + (sspec, sspec, sspec,
           sspec, sspec, sspec, sspec, sspec,
           P(), P(), P(), P(), P()),
        out_specs=(P(), P(), sspec, sspec, sspec, sspec, sspec),
    )
    fn = jax.jit(mapped)
    _RESUME_DISPATCH_FNS[key] = fn
    return fn


def resume_jit_cache_sizes() -> dict[str, int]:
    """Compile-cache audit for the resume dispatch ladder (test hook,
    mirroring ``core.batch_progressive.jit_cache_sizes``): the number of
    distinct dispatch rungs and the total jit traces behind them. A serving
    pass that recompiles shows up as either number growing."""
    traces = sum(int(f._cache_size()) for f in _RESUME_DISPATCH_FNS.values()
                 if hasattr(f, "_cache_size"))
    return dict(dispatch_fns=len(_RESUME_DISPATCH_FNS), traces=traces)


def sharded_topk_resume(index: ShardedIndex, state: ShardedSearchState,
                        qs: jnp.ndarray, lane_idx, fresh, K: int, L: int,
                        mesh: Mesh, axis: str = "data",
                        merge: str = "tournament"):
    """Resume (or seed) the shard-local beams of the lanes in ``lane_idx``.

    ``qs``/``fresh`` are the group's query rows and seed flags (``fresh``
    is traced, so seeding vs resuming shares one compilation). Expands each
    selected lane's beam until its first ``L`` entries are stable —
    continuing from the carried frontier, never redoing prior expansions —
    then harvests each shard's top-``K`` prefix and runs the same
    tournament merge as the scratch path. Returns
    ``(ids[g, K], scores[g, K], new_state)``; lanes outside ``lane_idx``
    keep their bits. A freshly seeded lane's round is bit-exact with
    ``sharded_topk`` at the same ``(K, L)``.
    """
    fn = _resume_dispatch_fn(index, mesh, axis, int(K), state.capacity,
                             merge)
    arrays, _, _ = _corpus_parts(index)
    out = fn(*arrays, index.neighbors, index.entries, index.bases,
             state.ids, state.scores, state.stable, state.visited,
             state.steps, jnp.asarray(qs, jnp.float32),
             jnp.asarray(lane_idx, jnp.int32),
             jnp.asarray(fresh, jnp.bool_),
             jnp.asarray(L, jnp.int32),
             jnp.asarray(4 * int(L) + 64, jnp.int32))
    ids, scores, *leaves = out
    return ids, scores, ShardedSearchState(*leaves)


def _diversify_one(vecs, cand_ids, cand_scores, eps_q, metric: str, k: int,
                   K: int, method: str, max_expansions: int):
    """One lane's diversify over an already-gathered candidate tile."""
    adj = kops.pairwise_adjacency(vecs, eps_q, metric, cand_ids >= 0)
    if method == "greedy":
        sel, count = kops.greedy_diversify(cand_scores, adj, k,
                                           valid=cand_ids >= 0)
        certified = count >= k
    else:
        res = da.div_astar(
            jnp.where(cand_ids >= 0, cand_scores, -jnp.inf), adj, k,
            max_expansions=max_expansions)
        sel = res.best_sets[k - 1]
        min_value = theorem2_min_value(res.best_scores, k)
        certified = (min_value > cand_scores[K - 1]) & res.complete
    out_ids = jnp.where(sel >= 0, cand_ids[jnp.maximum(sel, 0)], -1)
    out_sc = jnp.where(sel >= 0, cand_scores[jnp.maximum(sel, 0)], 0.0)
    return out_ids, out_sc, certified


def _diversify_batch(all_vectors, metric: str, ids, scores, epss, k: int,
                     K: int, method: str, max_expansions: int):
    """Replicated diversify over merged candidates — the single stage both
    the scratch and the resume paths run, so a freshly seeded resume round
    stays bit-exact with ``sharded_diverse_search`` end to end."""

    def diversify(cand_ids, cand_scores, eps_q):
        vecs = all_vectors[jnp.maximum(cand_ids, 0)]
        return _diversify_one(vecs, cand_ids, cand_scores, eps_q, metric, k,
                              K, method, max_expansions)

    return jax.vmap(diversify)(ids, scores, epss)


def _diversify_batch_gathered(cand_vecs, metric: str, ids, scores, epss,
                              k: int, K: int, method: str,
                              max_expansions: int):
    """Same stage over pre-gathered candidate vectors [B, K, d] — the
    quantized path's variant: candidate float rows were already gathered
    host-side by the exact rerank, so the device never needs the full
    float corpus."""

    def diversify(vecs, cand_ids, cand_scores, eps_q):
        return _diversify_one(vecs, cand_ids, cand_scores, eps_q, metric, k,
                              K, method, max_expansions)

    return jax.vmap(diversify)(cand_vecs, ids, scores, epss)


def exact_rerank_frontier(all_vectors, qs, ids, metric: str):
    """Host-side exact float rerank of merged frontiers (quantized path).

    Same candidate *set*, re-scored with exact float similarity and
    re-sorted (descending score, ascending-id ties) via
    ``index.flat.exact_rerank``, so everything downstream — greedy/div-A*
    diversification, the ``cand_scores[K-1]`` certificate threshold, and
    any ``theorem2_recheck`` a caller runs on the returned frontier — sees
    only true float scores. Returns ``(ids, scores, vecs)`` with ``vecs``
    the gathered candidate float rows for the adjacency build.
    """
    from repro.index.flat import exact_rerank

    xs = np.asarray(all_vectors, np.float32)
    ids_r, sc_r = exact_rerank(np.asarray(qs, np.float32),
                               np.asarray(ids), xs, metric)
    vecs = xs[np.maximum(ids_r, 0)]
    return jnp.asarray(ids_r), jnp.asarray(sc_r), jnp.asarray(vecs)


def sharded_diverse_search(index: ShardedIndex, all_vectors: jnp.ndarray,
                           qs: jnp.ndarray, k: int, eps, K: int,
                           mesh: Mesh, axis: str = "data",
                           L_factor: int = 4, merge: str = "tournament",
                           method: str = "div_astar",
                           max_expansions: int = 100_000,
                           with_expansions: bool = False):
    """Distributed diverse search: sharded candidates + replicated diversify.

    Returns (ids[B, k], scores[B, k], certified[B]) — plus the per-lane
    shard-expansion totals as a fourth output with ``with_expansions``.
    ``all_vectors`` [N, d] is the global database used to gather candidate
    vectors for the adjacency build (replicated or resharded by the caller).
    ``eps`` may be a scalar or a per-query ``[B]`` vector (the scheduler's
    query-owned diversification level): lanes with different eps share one
    dispatch because eps is traced, never baked into the compilation.

    Quantized indexes (``index.scheme`` set) search and merge over
    compressed scores, then run the host-side exact float rerank on the
    merged frontier before diversification (``all_vectors`` is the
    host-retained float corpus) — contract 13.
    """
    ids, scores, expansions = sharded_topk(index, qs, K, K * L_factor, mesh,
                                           axis, merge, with_expansions=True)
    epss = jnp.broadcast_to(jnp.asarray(eps, jnp.float32), (qs.shape[0],))
    if index.scheme is not None:
        ids, scores, vecs = exact_rerank_frontier(all_vectors, qs, ids,
                                                   index.metric)
        out = _diversify_batch_gathered(vecs, index.metric, ids, scores,
                                        epss, k, K, method, max_expansions)
    else:
        out = _diversify_batch(all_vectors, index.metric, ids, scores, epss,
                               k, K, method, max_expansions)
    if with_expansions:
        return (*out, expansions)
    return out


def sharded_diverse_resume(index: ShardedIndex, all_vectors: jnp.ndarray,
                           state: ShardedSearchState, qs: jnp.ndarray,
                           lane_idx, fresh, k: int, eps, K: int,
                           mesh: Mesh, axis: str = "data",
                           L_factor: int = 4, merge: str = "tournament",
                           method: str = "div_astar",
                           max_expansions: int = 100_000):
    """One resumable budget round: continue the selected lanes' shard-local
    beams to the ``K * L_factor`` stable limit, merge, diversify.

    Returns (ids[g, k], scores[g, k], cand_ids[g, K], cand_scores[g, K],
    certified[g], new_state). The candidate frontier comes back so callers
    can re-verify the Theorem-2 certificate independently of the engine —
    on a quantized index it is the *reranked* frontier (exact float scores,
    re-sorted), so ``theorem2_recheck`` against the float corpus sees the
    very scores that produced the certificate. Lanes dispatched with
    ``fresh`` seeds are bit-exact with ``sharded_diverse_search`` at the
    same budget; resumed lanes instead satisfy the certificate-soundness +
    recall contract (their candidate frontier is at least as deep as a
    scratch one, but expansion order — hence near-tie content — may
    differ).
    """
    ids, scores, new_state = sharded_topk_resume(
        index, state, qs, lane_idx, fresh, K, K * L_factor, mesh, axis,
        merge)
    epss = jnp.broadcast_to(jnp.asarray(eps, jnp.float32), (qs.shape[0],))
    if index.scheme is not None:
        ids, scores, vecs = exact_rerank_frontier(all_vectors, qs, ids,
                                                   index.metric)
        out_ids, out_sc, cert = _diversify_batch_gathered(
            vecs, index.metric, ids, scores, epss, k, K, method,
            max_expansions)
    else:
        out_ids, out_sc, cert = _diversify_batch(
            all_vectors, index.metric, ids, scores, epss, k, K, method,
            max_expansions)
    return out_ids, out_sc, ids, scores, cert, new_state


def sharded_progressive_diverse(index: ShardedIndex, all_vectors: jnp.ndarray,
                                qs: jnp.ndarray, k: int, eps,
                                mesh: Mesh, axis: str = "data",
                                K0: int = 32, L_factor: int = 4,
                                merge: str = "tournament",
                                max_expansions: int = 100_000,
                                max_rounds: int = 8,
                                resume: str = "beam"):
    """Progressive distributed diverse search (the paper's loop at mesh scale).

    The fixed-budget ``sharded_diverse_search`` can return uncertified lanes
    (Theorem-2 check fails: the optimal diverse set may extend past the K
    merged candidates). This entry point is a thin lockstep wrapper over
    ``sharded_search.engine.ShardedEngine`` — the mesh implementation of the
    ``core.backend.LaneBackend`` protocol: every lane carries its *own*
    candidate budget, a certified lane leaves the working set immediately,
    and each round re-dispatches only the uncertified lanes, bucketed by
    budget and padded to power-of-two sub-batch sizes so compile signatures
    stay logarithmic. (For continuous admission — new queries entering freed
    mesh lanes mid-run — drive the engine through
    ``serve.scheduler.LaneScheduler`` instead.)

    Returns (ids[B, k], scores[B, k], certified[B], K_final[B]) with
    ``K_final`` the per-lane budget at which each lane stopped — always a
    budget that was actually dispatched.

    Resumption contract (``resume``): with the default ``"beam"`` each
    budget-doubling round *continues* the shard-local beams from the
    previous round's frontier (``ShardedSearchState``), so a lane that
    finishes in its first round still equals ``sharded_diverse_search`` at
    its ``K_final`` bit-exactly, while a multi-round lane reuses its prior
    expansions and instead carries the certificate-soundness + recall
    contract (see ``ShardedEngine``). ``resume="scratch"`` restarts every
    round cold — the lockstep-parity mode in which *every* lane equals
    ``sharded_diverse_search`` at its ``K_final``.
    """
    from repro.core.backend import LaneRequest
    from repro.sharded_search.engine import ShardedEngine

    B = int(qs.shape[0])
    eng = ShardedEngine(index, all_vectors, mesh, num_lanes=B, axis=axis,
                        K0=K0, L_factor=L_factor, merge=merge,
                        max_expansions=max_expansions, max_rounds=max_rounds,
                        max_k=k, resume=resume)
    qs_np = np.asarray(qs, np.float32)
    epss = np.broadcast_to(np.asarray(eps, np.float64), (B,))
    for lane in range(B):
        eng.admit(lane, LaneRequest(q=qs_np[lane], k=k, eps=float(epss[lane]),
                                    method="sharded"))
    out_ids = np.full((B, k), -1, np.int32)
    out_sc = np.zeros((B, k), np.float32)
    out_cert = np.zeros(B, bool)
    K_final = np.zeros(B, np.int64)
    while eng.active_count():
        eng.step()
        for lane, res in eng.harvest():
            out_ids[lane], out_sc[lane] = res.ids, res.scores
            out_cert[lane] = res.stats.certified
            K_final[lane] = res.stats.K_final
            eng.recycle(lane)
    return out_ids, out_sc, out_cert, K_final
