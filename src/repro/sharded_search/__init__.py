from repro.sharded_search.engine import ShardedEngine  # noqa: F401
from repro.sharded_search.search import (  # noqa: F401
    ShardedIndex,
    build_sharded_index,
    sharded_diverse_search,
    sharded_progressive_diverse,
    sharded_topk,
)
