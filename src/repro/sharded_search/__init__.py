from repro.sharded_search.engine import ShardedEngine  # noqa: F401
from repro.sharded_search.search import (  # noqa: F401
    ShardedIndex,
    ShardedSearchState,
    beam_state_capacity,
    build_sharded_index,
    exact_rerank_frontier,
    init_sharded_state,
    migrate_sharded_state,
    reshard_index,
    resume_jit_cache_sizes,
    sharded_diverse_resume,
    sharded_diverse_search,
    sharded_progressive_diverse,
    sharded_topk,
    sharded_topk_resume,
)
