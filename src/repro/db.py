"""``DiverseVectorDB``: the one front door to the serving stack.

Before this module, every caller — ``serve/rag.py``, ``launch/serve.py``,
each example and test — hand-wired the same four layers (build a graph,
wrap an engine, wrap the ``LaneScheduler``, maybe attach the cache), and
the write path would have added a fifth ad-hoc entry point. The facade
assembles index → backend → scheduler → cache from one constructor and
exposes the complete serving surface:

* ``search(query)`` — one diverse search (a ``serve.query.Query``, an
  embedding, or text when constructed with ``embed=``), served through the
  scheduler: admission policies, semantic cache, continuous batching.
* ``upsert(vectors)`` / ``delete(ids)`` — the write path (tentpole):
  writes are admitted through the scheduler alongside reads, land in the
  mutable index's delta segment / deletion bitmap at the next pump
  boundary, invalidate intersecting cache entries, and trigger background
  rebuild-and-epoch-swap when the delta fills (contract 15).
* ``search_batch(queries)`` — a closed batch, continuously batched over
  the backend's lanes.
* ``stats()`` — scheduler latency stats + index (epoch/delta/bitmap)
  stats in one snapshot.

Everything underneath stays reachable (``db.scheduler``, ``db.backend``,
``db.index``, ``db.cache``) — the facade adds no policy of its own beyond
assembly defaults.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import FlatGraph
from repro.core.pgs import DiverseResult
from repro.index.mutable import MutableBackend, MutableIndex
from repro.serve.query import Query
from repro.serve.scheduler import (LaneScheduler, RequestDeferred,
                                   RequestShed, SchedulerSaturated)

__all__ = ["DiverseVectorDB", "Query"]


class DiverseVectorDB:
    """Index + engine + scheduler + cache behind one constructor.

    ``vectors`` (float ``[n, d]``) or a prebuilt ``index=`` (a
    ``FlatGraph``) seeds the corpus; ``metric`` in {"l2", "ip", "cos"}.

    * ``shards=None`` serves single-host (``ProgressiveEngine``); an int
      builds a mesh-sharded ``ShardedEngine`` over that many shards
      (``mesh=`` optionally supplies the device mesh; by default one is
      built over ``shards`` devices on the ``"data"`` axis). The corpus is
      padded with tombstoned rows to split evenly. ``shards="auto"`` picks
      the largest power of two the visible devices allow — or, under
      ``elastic=``, half of it, leaving headroom to grow.
    * ``elastic=`` (True or a ``serve.scheduler.ElasticPolicy``) makes the
      sharded mesh follow traffic (contract 16): the two standard targets
      (the device-count power of two and its half) are resharded and
      prewarmed at construction, and the scheduler migrates the corpus and
      every in-flight lane between them on sustained queue depth — a
      quiesce-free scale event at the pump boundary. The corpus is padded
      to divisibility by the *largest* target so every mesh splits the
      same rows.
    * ``quantized`` in {None, "int8", "pq"} stores the searched corpus
      compressed (exact float rerank before certificates, contract 13;
      the delta segment keeps int8 codes too and is always float-reranked).
    * ``cache_size=N`` attaches the semantic result cache, live-bound to
      the mutable index so hits revalidate against the written corpus;
      ``policy`` / ``cost_model`` configure admission
      (``serve.policies``).
    * ``embed=`` (a ``str -> vector`` callable) enables text queries.
    * ``num_lanes`` / ``max_k`` / ``default_ef`` / ``M`` / ``builder`` /
      ``delta_capacity`` / ``background_rebuild`` size the stack;
      ``backend_kw`` passes extra engine-constructor knobs through
      (e.g. ``dict(K0=16, resume="beam")`` for a sharded backend);
      ``scheduler_kw`` likewise for ``LaneScheduler`` (e.g.
      ``dict(admission="lockstep", max_pending=64)``).
    """

    def __init__(self, vectors=None, metric: str = "l2", *,
                 index: FlatGraph | None = None,
                 shards: int | str | None = None,
                 quantized: str | None = None,
                 cache_size: int = 0, policy="fifo", cost_model=None,
                 embed=None, num_lanes: int = 8, max_k: int = 16,
                 default_ef: int = 40, M: int = 16, builder: str = "knng",
                 delta_capacity: int = 256, background_rebuild: bool = True,
                 mesh=None, axis: str = "data", prewarm: bool = True,
                 elastic=None, seed: int = 0, backend_kw: dict | None = None,
                 scheduler_kw: dict | None = None):
        self.embed = embed
        elastic = elastic or None
        shard_align = None
        elastic_targets: tuple[int, ...] = ()
        if shards == "auto" or elastic is not None:
            import jax
            p_big = 1
            while p_big * 2 <= jax.device_count():
                p_big *= 2
        if shards == "auto":
            # leave headroom to grow when elastic; otherwise use the mesh
            shards = max(1, p_big // 2) if elastic is not None else p_big
        if elastic is not None:
            if shards is None:
                raise ValueError("elastic= needs a sharded backend — pass "
                                 "shards=int or shards='auto'")
            if p_big < 2:
                raise ValueError(
                    "elastic serving needs >= 2 visible devices to scale "
                    f"between (found {jax.device_count()})")
            p_small = p_big // 2
            if shards not in (p_small, p_big):
                raise ValueError(
                    "elastic serving scales between the standard targets "
                    f"{p_small} and {p_big} on this host; start on one of "
                    f"them (got shards={shards})")
            elastic_targets = tuple(t for t in (p_small, p_big)
                                    if t != shards)
            shard_align = p_big
        self.index = MutableIndex(
            vectors, metric, graph=index, delta_capacity=delta_capacity,
            M=M, builder=builder, shards=shards, quantized=quantized,
            background=background_rebuild, seed=seed,
            shard_align=shard_align)
        backend_kw = dict(backend_kw or {})
        if shards is not None:
            from repro.compat import make_mesh
            from repro.sharded_search.engine import ShardedEngine
            if mesh is None:
                mesh = make_mesh((shards,), (axis,))
            self.mesh = mesh
            n_epoch = (self.index.sharded.num_shards
                       * self.index.sharded.shard_size)
            engine = ShardedEngine(
                self.index.sharded, self.index.float_view()[:n_epoch],
                mesh, num_lanes, axis=axis, max_k=max_k,
                default_ef=default_ef, **backend_kw)
        else:
            from repro.core.batch_progressive import ProgressiveEngine
            self.mesh = None
            engine = ProgressiveEngine(
                self.index.graph, num_lanes, max_k=max_k,
                default_ef=default_ef, **backend_kw)
        self.backend = MutableBackend(engine, self.index)
        skw = dict(scheduler_kw or {})
        self.scheduler = LaneScheduler(
            backend=self.backend, policy=policy, cost_model=cost_model,
            cache_size=cache_size, prewarm=prewarm, elastic=elastic, **skw)
        # Pay the scale-event costs up front (contract 16): reshard the
        # corpus onto each elastic target and prewarm its dispatch ladder,
        # so the scheduler's trigger only ever migrates between rounds.
        # Serving capacity follows the mesh: each target's lane count
        # scales with its device count (floor 1), so a grow adds lanes —
        # admitting queued requests — and a shrink returns them.
        for t in elastic_targets:
            self.backend.prepare_rescale(
                t, make_mesh((t,), (axis,)), M=M, builder=builder,
                prewarm=prewarm, max_capacity=skw.get("prewarm_capacity"),
                ks=tuple(skw.get("prewarm_ks") or ()),
                num_lanes=max(1, num_lanes * t // shards))

    @property
    def cache(self):
        return self.scheduler.cache

    @property
    def engine(self):
        return self.backend.inner

    # -- reads ---------------------------------------------------------------
    def _as_query(self, query, k, eps, kw) -> Query:
        if isinstance(query, Query):
            if k is not None or eps is not None or kw:
                raise ValueError(
                    "search(Query) takes no overrides — set the fields on "
                    "the Query itself (dataclasses.replace)")
            return query
        if k is None or eps is None:
            raise TypeError("search needs (query, k=, eps=) or a Query")
        return Query(query, k=int(k), eps=float(eps), **kw)

    def search(self, query, k: int | None = None, eps: float | None = None,
               **kw) -> DiverseResult:
        """Serve one diverse search to completion; returns its
        ``DiverseResult``.

        ``query`` is a ``Query``, an embedding, or text (``embed=`` was
        given); with a raw embedding/text, ``k=``/``eps=`` are required and
        the remaining ``Query`` fields (``method``, ``tenant``, ``slo``,
        ``ef``, ``max_K``) ride as keywords. Backpressure and policy
        deferral are absorbed by pumping; a policy *shed* raises
        ``RequestShed`` (the policy's verdict is deterministic — there is
        nothing to retry).
        """
        q = self._as_query(query, k, eps, kw).resolve(self.embed)
        while True:
            try:
                req = self.scheduler.submit(q)
                break
            except (SchedulerSaturated, RequestDeferred):
                self.scheduler.pump()
        while req.result is None:
            self.scheduler.pump()
        return req.result

    def search_batch(self, queries, k=None, eps=None, **kw) -> list:
        """Serve a closed batch (list of ``Query``, or an ``[m, d]``
        embedding array with broadcast ``k=``/``eps=``), continuously
        batched over the lanes; results in submission order (``None`` for
        a request the admission policy shed)."""
        if not isinstance(queries, (list, tuple)):
            arr = np.asarray(queries, np.float32)
            queries = [self._as_query(arr[i], k, eps, kw)
                       for i in range(arr.shape[0])]
        elif k is not None or eps is not None or kw:
            raise ValueError("per-Query parameters are set on each Query")
        reqs = []
        for q in queries:
            q = q.resolve(self.embed)
            while True:
                try:
                    reqs.append(self.scheduler.submit(q))
                    break
                except RequestShed:
                    reqs.append(None)
                    break
                except (SchedulerSaturated, RequestDeferred):
                    self.scheduler.pump()
        self.scheduler.drain()
        return [r.result if r is not None else None for r in reqs]

    # -- writes --------------------------------------------------------------
    def upsert(self, vectors) -> np.ndarray:
        """Add fresh vectors to the live corpus; returns their assigned ids.

        The write is admitted through the scheduler (shared front door with
        reads) and applied immediately at this pump boundary: subsequent
        searches see the new points via the delta merge, intersecting cache
        entries are evicted, and a full delta triggers a background
        rebuild + epoch swap. In-flight searches pick the write up at
        harvest (contract 15)."""
        ticket = self.scheduler.submit_write("upsert", vectors)
        self.scheduler.apply_writes()
        return ticket.ids

    def delete(self, ids) -> int:
        """Tombstone ids in the live corpus; returns how many were newly
        deleted. Served sets never contain a deleted id from this point on
        (bitmap filter at harvest + cache invalidation)."""
        ticket = self.scheduler.submit_write("delete", ids)
        self.scheduler.apply_writes()
        return int(np.asarray(ticket.ids).size)

    def rebuild(self, wait: bool = True) -> bool:
        """Force a rebuild of the epoch structure over the current rows;
        with ``wait`` the built structure is also swapped in (the engine is
        drained first — the swap needs idle lanes). Returns True if the
        swap was installed."""
        self.index.request_rebuild()
        if not wait:
            return False
        self.index.wait_rebuild()
        self.scheduler.drain()
        return self.backend.maybe_swap()

    # -- reporting -----------------------------------------------------------
    def stats(self) -> dict:
        """One snapshot: the scheduler's ``latency_stats()`` plus the
        mutable index's corpus/epoch counters under ``"index"`` and the
        backend's swap count under ``"epoch_swaps"``."""
        out = self.scheduler.latency_stats()
        out["index"] = self.index.stats()
        out["epoch_swaps"] = self.backend.swaps
        return out
