"""Version compatibility shims for the jax API surface this repo uses.

The repo targets the modern jax API (``jax.make_mesh(..., axis_types=...)``,
``jax.shard_map(..., check_vma=...)``) but must also run on the 0.4.x line
shipped in CI/container images, where mesh axis types do not exist yet and
shard_map lives in ``jax.experimental`` under the ``check_rep`` spelling.
Everything else (``jax.tree``, ``jax.sharding.NamedSharding``) is stable
across the supported range.
"""
from __future__ import annotations

import inspect

import jax

_HAS_AXIS_TYPES = "axis_types" in inspect.signature(jax.make_mesh).parameters


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _HAS_AXIS_TYPES:
        from jax.sharding import AxisType
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` (newer jax) or the psum(1) classic."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` when available, else the experimental spelling."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)
