"""Pallas TPU kernel: diversity-graph adjacency build (paper Def. 2).

A[i, j] = sim(x_i, x_j) > eps over a candidate tile x[K, d]. The paper builds
G^eps with an O(K^2) loop at query time; here each (B, B) tile is one MXU
Gram-block + threshold, so the build is a single pass over K^2/B^2 tiles.

The kernel emits the *raw* thresholded Gram tile (including the diagonal);
the ops.py wrapper removes the diagonal and applies the validity mask — that
keeps the kernel free of global-index bookkeeping.

Output is int8 (TPU-friendly mask dtype); wrapper casts to bool.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(eps_ref, xi_ref, xj_ref, o_ref, *, metric: str):
    xi = xi_ref[...].astype(jnp.float32)   # (B, d)
    xj = xj_ref[...].astype(jnp.float32)   # (B, d)
    eps = eps_ref[0, 0]
    dots = jax.lax.dot_general(
        xi, xj, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    if metric == "ip":
        sims = dots
    elif metric == "cos":
        ni = jnp.sqrt(jnp.maximum(jnp.sum(xi * xi, axis=1, keepdims=True), 1e-12))
        nj = jnp.sqrt(jnp.maximum(jnp.sum(xj * xj, axis=1, keepdims=True), 1e-12))
        sims = dots / (ni * nj.T)
    elif metric == "l2":
        i2 = jnp.sum(xi * xi, axis=1, keepdims=True)
        j2 = jnp.sum(xj * xj, axis=1, keepdims=True)
        d2 = jnp.maximum(i2 + j2.T - 2.0 * dots, 0.0)
        sims = 1.0 - jnp.sqrt(d2)
    else:
        raise ValueError(metric)
    o_ref[...] = (sims > eps).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("metric", "block", "interpret"))
def pairwise_adjacency_pallas(x: jnp.ndarray, eps, metric: str,
                              block: int = 128,
                              interpret: bool = False) -> jnp.ndarray:
    """Raw thresholded Gram matrix (int8[K, K]) — see module docstring."""
    k, d = x.shape
    kp = -(-k // block) * block
    dp = -(-d // 128) * 128
    x_p = jnp.zeros((kp, dp), x.dtype).at[:k, :d].set(x)
    eps_arr = jnp.asarray(eps, jnp.float32).reshape(1, 1)
    grid = (kp // block, kp // block)
    out = pl.pallas_call(
        functools.partial(_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((block, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((kp, kp), jnp.int8),
        interpret=interpret,
    )(eps_arr, x_p, x_p)
    return out[:k, :k]
