"""Pallas TPU kernel: one fused progressive-round diversify stage per lane.

The progressive serving loop's hot path (``ProgressiveEngine._pgs_round``)
used to be a chain of separate dispatches per prefix group — prefix-mask,
gather + G^eps adjacency build, greedy diversification, output extraction —
each bouncing the (K, K) candidate tile through HBM between stages. This
kernel fuses the whole per-lane round into one ``pallas_call``:

* **grid** — one program per occupied lane (``grid=(B,)``), exactly like
  ``greedy_diversify_batch_pallas``: each program sees its own lane's
  ``(1, W)`` score row and ``(W, d)`` gathered candidate tile.
* **similarity scoring** — the candidate tile's pairwise similarities are
  one MXU Gram block (``dot_general`` + the metric transform, the same math
  as ``batch_similarity``/``pairwise_adjacency``).
* **eps-adjacency** — thresholded against the lane's own ``eps`` and stored
  in **kernel scratch memory** (a ``(W, W)`` int8 VMEM buffer): the
  adjacency matrix never exists in HBM at all.
* **greedy diversification** — the k sequential steps of paper §II-B-2 run
  on-chip against the scratch adjacency: masked argmax, ban the picked
  row's neighbors, repeat. Picks and their scores stream straight to the
  outputs.

Outputs per lane: ``sel`` (local candidate indices, -1 padded) and
``selsc`` (the picked scores — zero where no pick). The wrapper in
``ops.fused_round_batch`` derives global ids, the pick count, and the
Theorem-2 certificate inputs ``(total, s_K)`` from these plus the masked
score row (kept outside the kernel so both the ref and Pallas paths share
one bit-exact reduction).

VMEM budget per program at W=1024, d<=512, f32:
  scores 4KB + tile 2MB + scratch adj 1MB + outputs 1KB  < 4MB   (OK)

Parity contract: identical greedy decisions to ``ref.fused_round`` given
identical adjacency; the adjacency itself is a thresholded Gram tile whose
edges can flip vs the jnp oracle only for pairs within one float rounding
step of ``eps`` (the repo-wide documented near-eps tie caveat) — bit-exact
on tie-free inputs, which is what ``tests/test_fused_round.py`` pins.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(eps_ref, scores_ref, vecs_ref, sel_ref, selsc_ref, adj_ref, *,
            k: int, metric: str):
    W = scores_ref.shape[1]
    x = vecs_ref[...].astype(jnp.float32)                 # (W, d) tile
    eps = eps_ref[0, 0]

    # -- similarity scoring: one Gram block on the MXU ------------------------
    dots = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (W, W)
    if metric == "ip":
        sims = dots
    elif metric == "cos":
        n = jnp.sqrt(jnp.maximum(jnp.sum(x * x, axis=1, keepdims=True), 1e-12))
        sims = dots / (n * n.T)
    elif metric == "l2":
        sq = jnp.sum(x * x, axis=1, keepdims=True)
        d2 = jnp.maximum(sq + sq.T - 2.0 * dots, 0.0)
        sims = 1.0 - jnp.sqrt(d2)
    else:
        raise ValueError(metric)

    # -- eps-adjacency, thresholded straight into VMEM scratch ----------------
    # (The diagonal and invalid rows/columns are NOT masked here: the greedy
    # loop below bans the picked index explicitly, and invalid candidates
    # carry -inf scores so they are banned from step zero — banning them
    # again through a spurious edge is a no-op. This keeps the kernel free
    # of global-index bookkeeping, like pairwise_adjacency_pallas.)
    adj_ref[...] = (sims > eps).astype(jnp.int8)

    # -- greedy diversification over the scratch tile -------------------------
    scores = scores_ref[...]                              # (1, W), -inf = invalid
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)

    def body(t, banned):
        avail = jnp.where(banned, -jnp.inf, scores)
        j = jnp.argmax(avail, axis=1)[0]
        ok = avail[0, j] > -jnp.inf
        pick = jnp.where(ok, j, -1).astype(jnp.int32)
        pl.store(sel_ref, (slice(0, 1), pl.dslice(t, 1)), pick[None, None])
        psc = jnp.where(ok, avail[0, j], 0.0).astype(jnp.float32)
        pl.store(selsc_ref, (slice(0, 1), pl.dslice(t, 1)), psc[None, None])
        row = pl.load(adj_ref, (pl.dslice(j, 1), slice(None)))   # (1, W)
        new_banned = banned | (row > 0) | (lane == j)
        return jnp.where(ok, new_banned, banned)

    jax.lax.fori_loop(0, k, body, ~jnp.isfinite(scores))


@functools.partial(jax.jit, static_argnames=("k", "metric", "interpret"))
def fused_round_batch_pallas(vectors: jnp.ndarray, ids: jnp.ndarray,
                             scores: jnp.ndarray, Ks: jnp.ndarray,
                             eps: jnp.ndarray, k: int, metric: str,
                             interpret: bool = False):
    """Fused round over a lane batch: one grid program per lane.

    ``ids``/``scores`` are the raw ``(B, W)`` queue prefix rows (sorted,
    -1 / -inf sentinels), ``Ks`` int32[B] the per-lane candidate budgets
    (positions >= Ks[b] are masked off — the fused equivalent of the
    engine's ``_mask_prefix`` stage), ``eps`` f32[B] the per-lane
    diversification thresholds. The candidate gather stays outside the
    kernel (one XLA gather feeding the flattened ``(B*W, d)`` row blocks),
    inside the same jit, so the whole round is still a single dispatch.

    Returns ``(sel int32[B, k] local indices -1-padded,
    selsc f32[B, k] picked scores, ids_m int32[B, W] the masked prefix,
    scores_m f32[B, W] the masked scores)`` — callers derive global ids,
    counts and certificate inputs from these (see ``ops.fused_round_batch``).
    """
    B, W = ids.shape
    pos = jnp.arange(W)[None, :]
    keep = pos < Ks[:, None]
    ids_m = jnp.where(keep, ids, -1)
    scores_m = jnp.where(keep, scores, -jnp.inf)
    valid = ids_m >= 0
    s_in = jnp.where(valid, scores_m, -jnp.inf)
    vecs = vectors[jnp.maximum(ids_m, 0)]                 # (B, W, d)

    d = vectors.shape[1]
    Wp = -(-W // 128) * 128
    dp = -(-d // 128) * 128
    kp = -(-k // 128) * 128
    s_p = jnp.full((B, Wp), -jnp.inf, jnp.float32).at[:, :W].set(
        s_in.astype(jnp.float32))
    v_p = jnp.zeros((B, Wp, dp), jnp.float32).at[:, :W, :d].set(
        vecs.astype(jnp.float32))
    # flatten the lane axis into rows so each program's tile stays 2D
    v_rows = v_p.reshape(B * Wp, dp)
    eps_col = jnp.asarray(eps, jnp.float32).reshape(B, 1)

    sel, selsc = pl.pallas_call(
        functools.partial(_kernel, k=k, metric=metric),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, Wp), lambda b: (b, 0)),
            pl.BlockSpec((Wp, dp), lambda b: (b, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, kp), lambda b: (b, 0)),
            pl.BlockSpec((1, kp), lambda b: (b, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, kp), jnp.int32),
            jax.ShapeDtypeStruct((B, kp), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((Wp, Wp), jnp.int8)],
        interpret=interpret,
    )(eps_col, s_p, v_rows)
    return sel[:, :k], selsc[:, :k], ids_m, scores_m
