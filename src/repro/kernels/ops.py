"""Public kernel entry points with backend dispatch.

impl resolution:
  * "auto" (default): compiled Pallas on TPU, jnp oracle elsewhere — interpret
    mode executes kernels in Python and would dominate CPU benchmark latency.
  * "pallas": compiled Pallas (TPU target).
  * "interpret": Pallas interpret mode (CPU validation path used by tests).
  * "ref": the pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.batch_similarity import batch_similarity_many_pallas
from repro.kernels.greedy_diversify import (greedy_diversify_batch_pallas,
                                            greedy_diversify_pallas)
from repro.kernels.pairwise_adjacency import pairwise_adjacency_pallas
from repro.kernels.topk_merge import topk_merge_pallas

_DEFAULT_IMPL = None  # overridable for tests via set_default_impl

# jitted oracle entry points — eager lax.scan/sort would otherwise re-trace
# (and on cache-unfriendly closures re-compile) on every driver call.
_ref_batch_similarity = jax.jit(_ref.batch_similarity,
                                static_argnames=("metric",))
_ref_batch_similarity_many = jax.jit(_ref.batch_similarity_many,
                                     static_argnames=("metric",))
_ref_pairwise_adjacency = jax.jit(_ref.pairwise_adjacency,
                                  static_argnames=("metric",))
_ref_topk_merge = jax.jit(_ref.topk_merge)
_ref_greedy_diversify = jax.jit(_ref.greedy_diversify,
                                static_argnames=("k",))


def set_default_impl(impl: str | None) -> None:
    global _DEFAULT_IMPL
    _DEFAULT_IMPL = impl


def _resolve(impl: str | None) -> str:
    if impl is None:
        impl = _DEFAULT_IMPL or "auto"
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def batch_similarity(q: jnp.ndarray, x: jnp.ndarray, metric: str,
                     impl: str | None = None) -> jnp.ndarray:
    """sim(q[d], x[n, d]) -> f32[n]."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref_batch_similarity(q, x, metric)
    out = batch_similarity_many_pallas(q[None, :], x, metric,
                                       interpret=(impl == "interpret"))
    return out[0]


def batch_similarity_many(qs: jnp.ndarray, x: jnp.ndarray, metric: str,
                          impl: str | None = None) -> jnp.ndarray:
    """sim(qs[b, d], x[n, d]) -> f32[b, n]."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref_batch_similarity_many(qs, x, metric)
    return batch_similarity_many_pallas(qs, x, metric,
                                        interpret=(impl == "interpret"))


def pairwise_adjacency(x: jnp.ndarray, eps, metric: str,
                       valid: jnp.ndarray | None = None,
                       impl: str | None = None) -> jnp.ndarray:
    """Diversity-graph adjacency bool[K, K] (no diagonal; padding masked)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref_pairwise_adjacency(x, eps, metric, valid)
    raw = pairwise_adjacency_pallas(x, eps, metric,
                                    interpret=(impl == "interpret"))
    k = x.shape[0]
    adj = raw.astype(bool) & ~jnp.eye(k, dtype=bool)
    if valid is not None:
        adj = adj & valid[:, None] & valid[None, :]
    return adj


def topk_merge(ids_a, scores_a, ids_b, scores_b, impl: str | None = None):
    """Merge two descending-sorted lists; keep top len(a)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref_topk_merge(ids_a, scores_a, ids_b, scores_b)
    return topk_merge_pallas(ids_a, scores_a, ids_b, scores_b,
                             interpret=(impl == "interpret"))


def greedy_diversify(scores, adj, k: int, valid=None, impl: str | None = None):
    """Greedy diverse selection -> (sel int32[k] local idx, count)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref_greedy_diversify(scores, adj, k, valid)
    s = scores if valid is None else jnp.where(valid, scores, -jnp.inf)
    sel = greedy_diversify_pallas(s, adj, k,
                                  interpret=(impl == "interpret"))
    return sel, jnp.sum(sel >= 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k",))
def _ref_greedy_diversify_batch(scores, adj, k):
    return jax.vmap(lambda s, a: _ref.greedy_diversify(s, a, k))(scores, adj)


def greedy_diversify_batch(scores, adj, k: int, valid=None,
                           impl: str | None = None):
    """Batched greedy selection over a request batch.

    scores (B, K), adj (B, K, K), valid (B, K) or None.
    Returns (sel int32[B, k] local idx -1-padded, count int32[B]).
    """
    impl = _resolve(impl)
    s = scores if valid is None else jnp.where(valid, scores, -jnp.inf)
    if impl == "ref":
        return _ref_greedy_diversify_batch(s, adj, k)
    sel = greedy_diversify_batch_pallas(s, adj, k,
                                        interpret=(impl == "interpret"))
    return sel, jnp.sum(sel >= 0, axis=1).astype(jnp.int32)
