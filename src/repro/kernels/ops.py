"""Public kernel entry points with backend dispatch.

impl resolution:
  * "auto" (default): compiled Pallas on TPU, jnp oracle elsewhere — interpret
    mode executes kernels in Python and would dominate CPU benchmark latency.
  * "pallas": compiled Pallas (TPU target).
  * "interpret": Pallas interpret mode (CPU validation path used by tests).
  * "ref": the pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import quant as _quant
from repro.kernels import ref as _ref
from repro.kernels.batch_similarity import batch_similarity_many_pallas
from repro.kernels.fused_round import fused_round_batch_pallas
from repro.kernels.greedy_diversify import (greedy_diversify_batch_pallas,
                                            greedy_diversify_pallas)
from repro.kernels.int8_similarity import int8_dot_pallas
from repro.kernels.pairwise_adjacency import pairwise_adjacency_pallas
from repro.kernels.pq_lut_similarity import pq_lut_sum_pallas
from repro.kernels.topk_merge import topk_merge_pallas

_DEFAULT_IMPL = None  # overridable for tests via set_default_impl
_IMPLS = ("auto", "ref", "interpret", "pallas")

# jitted oracle entry points — eager lax.scan/sort would otherwise re-trace
# (and on cache-unfriendly closures re-compile) on every driver call.
_ref_batch_similarity = jax.jit(_ref.batch_similarity,
                                static_argnames=("metric",))
_ref_batch_similarity_many = jax.jit(_ref.batch_similarity_many,
                                     static_argnames=("metric",))
_ref_pairwise_adjacency = jax.jit(_ref.pairwise_adjacency,
                                  static_argnames=("metric",))
_ref_int8_similarity_many = jax.jit(_ref.int8_similarity_many,
                                    static_argnames=("metric",))
_ref_pq_similarity_many = jax.jit(_ref.pq_similarity_many,
                                  static_argnames=("metric",))
_ref_topk_merge = jax.jit(_ref.topk_merge)
_ref_greedy_diversify = jax.jit(_ref.greedy_diversify,
                                static_argnames=("k",))


def set_default_impl(impl: str | None) -> None:
    """Set the process-wide default backend (None restores "auto").

    Ops entry points in this module resolve their backend at *call* time, so
    flipping the default redirects every subsequent ops-level call. Jitted
    callers that bake an op into their own traced function (e.g. the
    engine's ``_batched_adjacency``) resolve at first trace — set the
    default before the first engine call to affect those.
    """
    if impl is not None and impl not in _IMPLS:
        raise ValueError(
            f"unknown kernel impl {impl!r}; expected one of {_IMPLS} or None")
    global _DEFAULT_IMPL
    _DEFAULT_IMPL = impl


def _resolve(impl: str | None) -> str:
    if impl is None:
        impl = _DEFAULT_IMPL or "auto"
    if impl not in _IMPLS:
        raise ValueError(
            f"unknown kernel impl {impl!r}; expected one of {_IMPLS}")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def batch_similarity(q: jnp.ndarray, x: jnp.ndarray, metric: str,
                     impl: str | None = None) -> jnp.ndarray:
    """sim(q[d], x[n, d]) -> f32[n]."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref_batch_similarity(q, x, metric)
    out = batch_similarity_many_pallas(q[None, :], x, metric,
                                       interpret=(impl == "interpret"))
    return out[0]


def batch_similarity_many(qs: jnp.ndarray, x: jnp.ndarray, metric: str,
                          impl: str | None = None) -> jnp.ndarray:
    """sim(qs[b, d], x[n, d]) -> f32[b, n]."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref_batch_similarity_many(qs, x, metric)
    return batch_similarity_many_pallas(qs, x, metric,
                                        interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def _int8_similarity_many_kernel(qs, corpus, metric, interpret):
    q_codes, q_scales = _quant.quantize_queries(qs)
    dots = int8_dot_pallas(q_codes, corpus.codes, interpret=interpret)
    return _quant.int8_score_from_dots(dots, q_codes, q_scales, corpus,
                                       metric)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def _pq_similarity_many_kernel(qs, corpus, metric, interpret):
    T, S, qn = _quant.pq_luts_many(qs, corpus.codebooks, metric)
    sumT = pq_lut_sum_pallas(T, corpus.codes, interpret=interpret)
    sumS = _quant.pq_lut_sum(S, corpus.codes)
    return _quant.pq_postprocess(sumT, sumS[None, :], qn[:, None], metric)


def quantized_similarity_many(qs: jnp.ndarray, corpus, metric: str,
                              impl: str | None = None) -> jnp.ndarray:
    """sim(qs[b, d], compressed corpus[n]) -> f32[b, n].

    ``corpus`` is a ``quant.Int8Corpus`` (int8 x int8 dot with int32
    accumulation) or ``quant.PQCorpus`` (per-subspace LUT gather-sum).
    All rungs are **bit-exact** against the ``ref`` oracle: the kernels
    compute only exact arithmetic (integer dots / one-hot float matmuls)
    and share their float postprocess with the oracle (``repro.quant``).
    """
    impl = _resolve(impl)
    if isinstance(corpus, _quant.Int8Corpus):
        if impl == "ref":
            return _ref_int8_similarity_many(qs, corpus, metric)
        return _int8_similarity_many_kernel(qs, corpus, metric,
                                            impl == "interpret")
    if isinstance(corpus, _quant.PQCorpus):
        if impl == "ref":
            return _ref_pq_similarity_many(qs, corpus, metric)
        return _pq_similarity_many_kernel(qs, corpus, metric,
                                          impl == "interpret")
    raise TypeError(
        f"quantized_similarity_many needs a quantized corpus, got "
        f"{type(corpus).__name__}")


def pairwise_adjacency(x: jnp.ndarray, eps, metric: str,
                       valid: jnp.ndarray | None = None,
                       impl: str | None = None) -> jnp.ndarray:
    """Diversity-graph adjacency bool[K, K] (no diagonal; padding masked)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref_pairwise_adjacency(x, eps, metric, valid)
    raw = pairwise_adjacency_pallas(x, eps, metric,
                                    interpret=(impl == "interpret"))
    k = x.shape[0]
    adj = raw.astype(bool) & ~jnp.eye(k, dtype=bool)
    if valid is not None:
        adj = adj & valid[:, None] & valid[None, :]
    return adj


def topk_merge(ids_a, scores_a, ids_b, scores_b, impl: str | None = None):
    """Merge two descending-sorted lists; keep top len(a)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref_topk_merge(ids_a, scores_a, ids_b, scores_b)
    return topk_merge_pallas(ids_a, scores_a, ids_b, scores_b,
                             interpret=(impl == "interpret"))


def greedy_diversify(scores, adj, k: int, valid=None, impl: str | None = None):
    """Greedy diverse selection -> (sel int32[k] local idx, count)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref_greedy_diversify(scores, adj, k, valid)
    s = scores if valid is None else jnp.where(valid, scores, -jnp.inf)
    sel = greedy_diversify_pallas(s, adj, k,
                                  interpret=(impl == "interpret"))
    return sel, jnp.sum(sel >= 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k",))
def _ref_greedy_diversify_batch(scores, adj, k):
    return jax.vmap(lambda s, a: _ref.greedy_diversify(s, a, k))(scores, adj)


def greedy_diversify_batch(scores, adj, k: int, valid=None,
                           impl: str | None = None):
    """Batched greedy selection over a request batch.

    scores (B, K), adj (B, K, K), valid (B, K) or None.
    Returns (sel int32[B, k] local idx -1-padded, count int32[B]).
    """
    impl = _resolve(impl)
    s = scores if valid is None else jnp.where(valid, scores, -jnp.inf)
    if impl == "ref":
        return _ref_greedy_diversify_batch(s, adj, k)
    sel = greedy_diversify_batch_pallas(s, adj, k,
                                        interpret=(impl == "interpret"))
    return sel, jnp.sum(sel >= 0, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def _ref_fused_round_batch(vectors, ids, scores, Ks, eps, k, metric):
    return jax.vmap(
        lambda i, s, K, e: _ref.fused_round(vectors, i, s, K, e, k, metric)
    )(ids, scores, Ks, eps)


@functools.partial(jax.jit, static_argnames=("k", "metric", "interpret"))
def _fused_round_batch_kernel(vectors, ids, scores, Ks, eps, k, metric,
                              interpret):
    sel, selsc, ids_m, scores_m = fused_round_batch_pallas(
        vectors, ids, scores, Ks, eps, k, metric, interpret=interpret)
    picked = sel >= 0
    gidx = jnp.maximum(sel, 0)
    sel_ids = jnp.where(picked, jnp.take_along_axis(ids_m, gidx, axis=1), -1)
    count = jnp.sum(picked, axis=1).astype(jnp.int32)
    valid = ids_m >= 0
    total = jnp.sum(selsc, axis=1)
    s_K = jnp.min(jnp.where(valid, scores_m, jnp.inf), axis=1)
    s_K = jnp.where(jnp.any(valid, axis=1), s_K, -jnp.inf)
    cert = jnp.stack([total, s_K], axis=1)
    return sel_ids, selsc, count, cert


def fused_round_batch(vectors, ids, scores, Ks, eps, k: int, metric: str,
                      impl: str | None = None):
    """One fused progressive round over a lane batch — a single dispatch.

    Replaces the per-round chain prefix-mask -> gather -> adjacency ->
    greedy -> extract with one call (one ``pallas_call`` on the kernel
    paths, one jitted vmap of ``ref.fused_round`` on the oracle path).

    vectors (n, d) corpus, ids int32 (B, W) raw sorted queue prefixes
    (-1 sentinels), scores f32 (B, W) (-inf sentinels), Ks int (B,)
    per-lane candidate budgets, eps f32 (B,) per-lane thresholds.

    Returns ``(sel_ids int32[B, k] global ids -1-padded,
    sel_scores f32[B, k] zero-padded, count int32[B],
    cert f32[B, 2] = (total, s_K) Theorem-2 certificate inputs)``.

    Backend dispatch happens here at call time (not trace time), so
    ``set_default_impl`` redirects the engine's hot path without a retrace.
    Parity: kernel paths are bit-exact vs "ref" on tie-free inputs (no
    candidate pair within float rounding of its lane's eps) — the greedy
    decisions consume the queue scores as-is and the certificate
    reductions run outside the kernel, so the adjacency threshold is the
    only place a kernel/oracle bit can differ.
    """
    impl = _resolve(impl)
    ids = jnp.asarray(ids, jnp.int32)
    scores = jnp.asarray(scores, jnp.float32)
    Ks = jnp.asarray(Ks, jnp.int32)
    eps = jnp.asarray(eps, jnp.float32)
    if impl == "ref":
        return _ref_fused_round_batch(vectors, ids, scores, Ks, eps, k,
                                      metric)
    return _fused_round_batch_kernel(vectors, ids, scores, Ks, eps, k,
                                     metric, impl == "interpret")
