"""Pallas PQ similarity: LUT gather-sum as per-subspace one-hot matmuls.

The asymmetric-distance score of PQ row ``r`` is
``sum_m T[q, m, codes[r, m]]`` — a gather the TPU has no native fast path
for. The kernel instead expands each corpus tile's subspace codes into a
one-hot (BN, C) matrix with ``broadcasted_iota`` (TPU needs >= 2D iota)
and contracts it against the query tile's LUT slab on the MXU:

    partial_m[q, r] = sum_c T[q, m, c] * onehot(codes[r, m])[r, c]

Each partial is *bitwise* the gathered entry — every non-selected addend
is ``T * 0.0``, an exact float zero, and adding exact zeros is exact — and
partials accumulate in subspace order m = 0..M-1, matching the jnp
oracle's explicitly left-to-right ``quant.pq_lut_sum``. So the kernel is
bit-exact vs the oracle, not merely allclose (``docs/KERNELS.md``).

The query LUT slab lives in VMEM flattened to (BQ, M*Cp) f32 (Cp = C
padded to a 128 lane multiple, zero-filled — codes never index the pad);
codes ride transposed as (Mp, BN) int32 tiles so the lane dimension is the
corpus axis. Metric postprocessing (sqrt / norm division) happens outside
the pallas_call in ``quant.pq_postprocess``, shared with the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(t_ref, c_ref, o_ref, *, m: int, cp: int):
    bn = o_ref.shape[1]
    acc = None
    for j in range(m):
        tm = t_ref[:, j * cp:(j + 1) * cp]                       # (bq, cp)
        code = c_ref[j, :]                                       # (bn,)
        oh = (jax.lax.broadcasted_iota(jnp.int32, (bn, cp), 1)
              == code[:, None]).astype(jnp.float32)
        part = jax.lax.dot_general(tm, oh, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        acc = part if acc is None else acc + part
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def pq_lut_sum_pallas(T: jnp.ndarray, codes: jnp.ndarray,
                      bq: int = 8, bn: int = 128,
                      interpret: bool = False) -> jnp.ndarray:
    """``sum_m T[b, m, codes[n, m]] -> f32[b, n]`` via one-hot matmuls.

    ``T`` f32[b, M, C] per-query per-subspace lookup tables
    (``quant.pq_luts_many``), ``codes`` uint8/int[n, M] corpus codes.
    Bit-exact vs ``quant.pq_lut_sum`` on the same inputs.
    """
    T = jnp.asarray(T, jnp.float32)
    b, m, c = T.shape
    n = codes.shape[0]
    cp = -(-c // 128) * 128
    bq = min(bq, max(8, -(-b // 8) * 8))
    bn = min(bn, max(128, -(-n // 128) * 128))
    mp = max(8, -(-m // 8) * 8)
    bp = -(-b // bq) * bq
    np_ = -(-n // bn) * bn
    tp = jnp.zeros((bp, m, cp), jnp.float32).at[:b, :, :c].set(T)
    tp = tp.reshape(bp, m * cp)
    ct = jnp.zeros((mp, np_), jnp.int32).at[:m, :n].set(
        codes.astype(jnp.int32).T)
    out = pl.pallas_call(
        functools.partial(_kernel, m=m, cp=cp),
        grid=(bp // bq, np_ // bn),
        in_specs=[pl.BlockSpec((bq, m * cp), lambda i, j: (i, 0)),
                  pl.BlockSpec((mp, bn), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.float32),
        interpret=interpret,
    )(tp, ct)
    return out[:b, :n]
