# Pallas TPU kernels + jnp oracles behind one `impl` dispatch layer
# (`ops.py`: auto / ref / interpret / pallas — docs/KERNELS.md is the
# per-kernel catalog). Seven kernels:
#
#   batch_similarity   — query-tile x database-tile scoring (ip/cos/l2)
#   pairwise_adjacency — candidate Gram tiles -> G^eps adjacency (int8)
#   topk_merge         — bitonic merge of sorted score/id runs
#   greedy_diversify   — lane-grid greedy diversification over G^eps
#   fused_round        — PR 6: score -> adjacency (VMEM scratch) ->
#                        greedy -> Theorem-2 certificate inputs, one
#                        pallas_call per engine PGS round
#   int8_similarity    — PR 7: exact int32 Gram of int8 codes (the
#                        compressed-corpus scorer; float postprocess
#                        shared with the oracle in repro/quant.py)
#   pq_lut_similarity  — PR 7: PQ ADC gather-sum as per-subspace
#                        LUT x one-hot(code) matmuls (bitwise vs the
#                        quant.pq_lut_sum oracle)
#
# `ref.py` holds the bit-parity jnp oracles; each kernel module owns its
# pallas_call. Add a kernel ONLY for a compute hot-spot the paper's
# serving path actually exercises.
