# Pallas TPU kernels + jnp oracles behind one `impl` dispatch layer
# (`ops.py`: auto / ref / interpret / pallas — docs/KERNELS.md is the
# per-kernel catalog). Five kernels:
#
#   batch_similarity   — query-tile x database-tile scoring (ip/cos/l2)
#   pairwise_adjacency — candidate Gram tiles -> G^eps adjacency (int8)
#   topk_merge         — bitonic merge of sorted score/id runs
#   greedy_diversify   — lane-grid greedy diversification over G^eps
#   fused_round        — PR 6: score -> adjacency (VMEM scratch) ->
#                        greedy -> Theorem-2 certificate inputs, one
#                        pallas_call per engine PGS round
#
# `ref.py` holds the bit-parity jnp oracles; each kernel module owns its
# pallas_call. Add a kernel ONLY for a compute hot-spot the paper's
# serving path actually exercises.
