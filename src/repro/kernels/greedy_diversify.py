"""Pallas TPU kernel: fused greedy diverse selection (paper §II-B-2 / Alg. 2).

Given a scored candidate tile and its diversity-graph adjacency, run the k
greedy steps entirely in VMEM: pick the best non-banned candidate, then ban
its adjacency row. Each step is one masked argmax + one vectorized mask OR
over K lanes — the sequential-k loop stays on-chip instead of bouncing
score/mask tensors through HBM between steps.

Inputs: scores (1, K) f32 (-inf marks invalid/padded candidates),
        adj (K, K) int8. Output: sel (1, k_pad) int32 local indices (-1 pad).

The batched entry point (``greedy_diversify_batch_pallas``) runs the same
kernel over a (B, K) score grid with one program per request lane — the
batched progressive engine diversifies a whole serving batch in one launch,
each lane's greedy loop staying in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(scores_ref, adj_ref, sel_ref, *, k: int):
    K = scores_ref.shape[1]
    scores = scores_ref[...]                          # (1, K)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)

    def body(t, banned):
        avail = jnp.where(banned, -jnp.inf, scores)
        j = jnp.argmax(avail, axis=1)[0]
        ok = avail[0, j] > -jnp.inf
        pick = jnp.where(ok, j, -1).astype(jnp.int32)
        pl.store(sel_ref, (slice(0, 1), pl.dslice(t, 1)), pick[None, None])
        row = pl.load(adj_ref, (pl.dslice(j, 1), slice(None)))  # (1, K)
        new_banned = banned | (row > 0) | (lane == j)
        return jnp.where(ok, new_banned, banned)

    banned0 = ~jnp.isfinite(scores)
    jax.lax.fori_loop(0, k, body, banned0)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def greedy_diversify_pallas(scores: jnp.ndarray, adj: jnp.ndarray, k: int,
                            interpret: bool = False) -> jnp.ndarray:
    """Returns sel int32[k] (local indices into scores; -1 padded)."""
    K = scores.shape[0]
    Kp = -(-K // 128) * 128
    kp = -(-k // 128) * 128
    s_p = jnp.full((1, Kp), -jnp.inf, jnp.float32).at[0, :K].set(
        scores.astype(jnp.float32))
    a_p = jnp.zeros((Kp, Kp), jnp.int8).at[:K, :K].set(adj.astype(jnp.int8))
    sel = pl.pallas_call(
        functools.partial(_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((1, kp), jnp.int32),
        interpret=interpret,
    )(s_p, a_p)
    return sel[0, :k]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def greedy_diversify_batch_pallas(scores: jnp.ndarray, adj: jnp.ndarray,
                                  k: int, interpret: bool = False) -> jnp.ndarray:
    """Batched greedy selection: one grid program per request lane.

    scores (B, K) f32 (-inf = invalid), adj (B, K, K). Returns sel
    int32[B, k] local indices (-1 padded). Each program sees exactly the
    (1, K) + (K, K) tiles of the single-query kernel, so the per-lane
    semantics are identical to ``greedy_diversify_pallas``.
    """
    B, K = scores.shape
    Kp = -(-K // 128) * 128
    kp = -(-k // 128) * 128
    s_p = jnp.full((B, Kp), -jnp.inf, jnp.float32).at[:, :K].set(
        scores.astype(jnp.float32))
    a_p = jnp.zeros((B, Kp, Kp), jnp.int8).at[:, :K, :K].set(
        adj.astype(jnp.int8))
    # flatten the lane axis into rows so each program's adj tile stays 2D
    a_rows = a_p.reshape(B * Kp, Kp)
    sel = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Kp), lambda b: (b, 0)),
            pl.BlockSpec((Kp, Kp), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, kp), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, kp), jnp.int32),
        interpret=interpret,
    )(s_p, a_rows)
    return sel[:, :k]
