"""Pallas TPU kernel: tiled batched similarity scoring (the search hot loop).

Computes scores[b, n] = sim(qs[b], x[n]) for metric in {l2, ip, cos} as one
MXU pass per (BQ, BN) tile. This is the paper's per-node ``sim(v, q)``
re-expressed as a blocked matmul (DESIGN.md §2): beam-search neighbor
expansion scores an (M0, d) gather block at once, and batched / sharded
search scores (BQ, d) x (d, BN) tiles.

Tiling: qs tile (BQ, d) and x tile (BN, d) live in VMEM; d is kept whole
(padded to a multiple of 128 by the wrapper so the MXU contraction dim is
aligned); accumulation in f32 via preferred_element_type.

VMEM budget at defaults (BQ=128, BN=512, d<=1024, f32):
  128*1024*4 + 512*1024*4 + 128*512*4 = 0.5MB + 2MB + 0.25MB < 3MB  (OK)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30


def _kernel(q_ref, x_ref, o_ref, *, metric: str):
    q = q_ref[...].astype(jnp.float32)          # (BQ, d)
    x = x_ref[...].astype(jnp.float32)          # (BN, d)
    dots = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # (BQ, BN)
    if metric == "ip":
        out = dots
    elif metric == "cos":
        qn = jnp.sqrt(jnp.maximum(jnp.sum(q * q, axis=1, keepdims=True), 1e-12))
        xn = jnp.sqrt(jnp.maximum(jnp.sum(x * x, axis=1, keepdims=True), 1e-12))
        out = dots / (qn * xn.T)
    elif metric == "l2":
        q2 = jnp.sum(q * q, axis=1, keepdims=True)
        x2 = jnp.sum(x * x, axis=1, keepdims=True)
        d2 = jnp.maximum(q2 + x2.T - 2.0 * dots, 0.0)
        out = 1.0 - jnp.sqrt(d2)
    else:
        raise ValueError(metric)
    o_ref[...] = out


@functools.partial(jax.jit,
                   static_argnames=("metric", "bq", "bn", "interpret"))
def batch_similarity_many_pallas(qs: jnp.ndarray, x: jnp.ndarray, metric: str,
                                 bq: int = 128, bn: int = 512,
                                 interpret: bool = False) -> jnp.ndarray:
    """scores[b, n] for qs[b, d], x[n, d]. Pads internally; exact output."""
    b, d = qs.shape
    n, _ = x.shape
    bq = min(bq, max(8, -(-b // 8) * 8))
    bn = min(bn, max(128, -(-n // 128) * 128))
    bp = -(-b // bq) * bq
    np_ = -(-n // bn) * bn
    dp = -(-d // 128) * 128
    # zero padding preserves dots and norms; padded rows are sliced away.
    qs_p = jnp.zeros((bp, dp), qs.dtype).at[:b, :d].set(qs)
    x_p = jnp.zeros((np_, dp), x.dtype).at[:n, :d].set(x)
    grid = (bp // bq, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.float32),
        interpret=interpret,
    )(qs_p, x_p)
    out = out[:b, :n]
    if metric == "l2":
        # guard: padded-dim zeros do not alter l2 (norms include zeros only)
        pass
    return out
