"""Pallas TPU kernel: bitonic merge of two sorted candidate lists.

The tournament reducer of the sharded search (DESIGN.md §5) repeatedly merges
two descending-sorted (ids, scores) lists of length L and keeps the top L.
Concatenating ``a`` (descending) with ``reverse(b)`` (ascending) forms a
bitonic sequence, so log2(2L) vectorized compare-exchange stages produce a
fully sorted result — no data-dependent control flow, pure VPU work.

Comparator matches the ref's lexsort exactly: (score desc, id asc).
L must be a power of two (wrapper pads with -inf sentinels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(scores, ids, dist):
    """One bitonic stage at the given distance over a (1, 2L) vector."""
    n = scores.shape[1]
    s = scores.reshape(n // (2 * dist), 2, dist)
    i = ids.reshape(n // (2 * dist), 2, dist)
    s_hi, s_lo = s[:, 0, :], s[:, 1, :]
    i_hi, i_lo = i[:, 0, :], i[:, 1, :]
    # "hi" slot should hold the (score desc, id asc)-greater element.
    take_lo = (s_lo > s_hi) | ((s_lo == s_hi) & (i_lo < i_hi))
    new_s_hi = jnp.where(take_lo, s_lo, s_hi)
    new_s_lo = jnp.where(take_lo, s_hi, s_lo)
    new_i_hi = jnp.where(take_lo, i_lo, i_hi)
    new_i_lo = jnp.where(take_lo, i_hi, i_lo)
    s = jnp.stack([new_s_hi, new_s_lo], axis=1).reshape(1, n)
    i = jnp.stack([new_i_hi, new_i_lo], axis=1).reshape(1, n)
    return s, i


def _kernel(sa_ref, ia_ref, sb_ref, ib_ref, so_ref, io_ref, *, length: int):
    scores = jnp.concatenate(
        [sa_ref[...], jnp.flip(sb_ref[...], axis=1)], axis=1)  # (1, 2L) bitonic
    ids = jnp.concatenate(
        [ia_ref[...], jnp.flip(ib_ref[...], axis=1)], axis=1)
    dist = length
    while dist >= 1:
        scores, ids = _compare_exchange(scores, ids, dist)
        dist //= 2
    so_ref[...] = scores[:, :length]
    io_ref[...] = ids[:, :length]


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_merge_pallas(ids_a, scores_a, ids_b, scores_b, interpret: bool = False):
    """Merge two descending-sorted lists; return top len(a) (ids, scores)."""
    L = ids_a.shape[0]
    Lp = max(128, 1 << (L - 1).bit_length())

    def pad(ids, scores):
        ids_p = jnp.full((1, Lp), jnp.iinfo(jnp.int32).max, jnp.int32)
        sc_p = jnp.full((1, Lp), -jnp.inf, jnp.float32)
        return (ids_p.at[0, :L].set(ids.astype(jnp.int32)),
                sc_p.at[0, :L].set(scores.astype(jnp.float32)))

    ia, sa = pad(ids_a, scores_a)
    ib, sb = pad(ids_b, scores_b)
    so, io = pl.pallas_call(
        functools.partial(_kernel, length=Lp),
        out_shape=(
            jax.ShapeDtypeStruct((1, Lp), jnp.float32),
            jax.ShapeDtypeStruct((1, Lp), jnp.int32),
        ),
        interpret=interpret,
    )(sa, ia, sb, ib)
    return io[0, :L], so[0, :L]
