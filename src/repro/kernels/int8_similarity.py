"""Pallas int8 similarity: the compressed-corpus scoring matmul.

The kernel is deliberately *only* the integer part — a tiled int8 x int8
matmul with **int32 accumulation** (``preferred_element_type=jnp.int32``,
so the MXU accumulates exactly). Everything float — scale products,
squared-norm dequantization, the metric transform — happens outside the
``pallas_call`` in ``quant.int8_score_from_dots``, shared verbatim with
the jnp oracle. Since the integer dot is exact on both paths, ref /
interpret / pallas outputs are **bitwise identical** (see
``docs/KERNELS.md``).

Tiling follows ``batch_similarity.py``: zero-padded operands (zero codes
contribute exact zero to every accumulator), grid over (query, corpus)
tiles, full padded depth per tile. int8 minimum tile on TPU is (32, 128),
so query tiles are 32-row-aligned and the depth pad is 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, x_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        q_ref[...], x_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def int8_dot_pallas(q_codes: jnp.ndarray, x_codes: jnp.ndarray,
                    bq: int = 32, bn: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """Exact integer dots ``q_codes[b, d] . x_codes[n, d]^T -> int32[b, n]``.

    Both operands int8; accumulation is int32 and therefore exact (values
    bounded by ``127^2 * d``), which is what makes the quantized ladder's
    bit-parity contract possible.
    """
    b, d = q_codes.shape
    n = x_codes.shape[0]
    bq = min(bq, max(32, -(-b // 32) * 32))
    bn = min(bn, max(128, -(-n // 128) * 128))
    dp = -(-d // 128) * 128
    bp = -(-b // bq) * bq
    np_ = -(-n // bn) * bn
    qp = jnp.zeros((bp, dp), jnp.int8).at[:b, :d].set(q_codes)
    xp = jnp.zeros((np_, dp), jnp.int8).at[:n, :d].set(x_codes)
    out = pl.pallas_call(
        _kernel,
        grid=(bp // bq, np_ // bn),
        in_specs=[pl.BlockSpec((bq, dp), lambda i, j: (i, 0)),
                  pl.BlockSpec((bn, dp), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.int32),
        interpret=interpret,
    )(qp, xp)
    return out[:b, :n]
