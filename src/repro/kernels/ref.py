"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; kernel tests sweep shapes/dtypes
and assert_allclose against these. They are also the CPU fallback used by
``ops.py`` (interpret-mode Pallas is far too slow for the benchmark loop).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.similarity import pairwise_sim, query_sim


def batch_similarity(q: jnp.ndarray, x: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Scores of rows of x[n, d] against a single query q[d] -> f32[n]."""
    return query_sim(q, x, metric)


def batch_similarity_many(qs: jnp.ndarray, x: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Scores of rows of x[n, d] against queries qs[b, d] -> f32[b, n]."""
    return pairwise_sim(qs, x, metric)


def pairwise_adjacency(x: jnp.ndarray, eps: jnp.ndarray, metric: str,
                       valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Diversity-graph adjacency (paper Def. 2): A[i,j] = sim(x_i,x_j) > eps.

    Diagonal is False. ``valid`` masks padding rows (False rows/cols have no
    edges).
    """
    k = x.shape[0]
    sims = pairwise_sim(x, x, metric)
    adj = sims > eps
    adj = adj & ~jnp.eye(k, dtype=bool)
    if valid is not None:
        adj = adj & valid[:, None] & valid[None, :]
    return adj


def topk_merge(ids_a: jnp.ndarray, scores_a: jnp.ndarray,
               ids_b: jnp.ndarray, scores_b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge two descending-sorted (ids, scores) lists, keep top len(a).

    Deterministic tie-break on id (asc). This is the tournament-merge
    primitive used by the sharded search reducer.
    """
    L = ids_a.shape[0]
    ids = jnp.concatenate([ids_a, ids_b])
    scores = jnp.concatenate([scores_a, scores_b])
    order = jnp.lexsort((ids, -scores))
    return ids[order][:L], scores[order][:L]


def greedy_diversify(scores: jnp.ndarray, adj: jnp.ndarray, k: int,
                     valid: jnp.ndarray | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Greedy diverse selection (paper §II-B-2) over a scored candidate tile.

    Candidates need NOT be pre-sorted: at each of k steps pick the highest
    scoring non-banned candidate, then ban its diversity-graph neighbors.
    Returns (sel int32[k] local indices, -1 padded; count).
    """
    n = scores.shape[0]
    banned = jnp.zeros((n,), bool) if valid is None else ~valid

    def step(carry, _):
        banned, sel_count = carry
        avail = jnp.where(banned, -jnp.inf, scores)
        j = jnp.argmax(avail)
        ok = ~banned[j] & jnp.isfinite(avail[j])
        new_banned = jnp.where(ok, banned | adj[j] | (jnp.arange(n) == j), banned)
        pick = jnp.where(ok, j, -1).astype(jnp.int32)
        return (new_banned, sel_count + ok.astype(jnp.int32)), pick

    (banned, count), picks = jax.lax.scan(step, (banned, jnp.int32(0)),
                                          None, length=k)
    return picks, count
