"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; kernel tests sweep shapes/dtypes
and assert_allclose against these. They are also the CPU fallback used by
``ops.py`` (interpret-mode Pallas is far too slow for the benchmark loop).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import quant
from repro.core.similarity import pairwise_sim, query_sim


def batch_similarity(q: jnp.ndarray, x: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Scores of rows of x[n, d] against a single query q[d] -> f32[n]."""
    return query_sim(q, x, metric)


def batch_similarity_many(qs: jnp.ndarray, x: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Scores of rows of x[n, d] against queries qs[b, d] -> f32[b, n]."""
    return pairwise_sim(qs, x, metric)


def int8_similarity_many(qs: jnp.ndarray, corpus, metric: str) -> jnp.ndarray:
    """Quantized scores of an :class:`repro.quant.Int8Corpus` against float
    queries qs[b, d] -> f32[b, n].

    Bit-parity anchor for ``kernels/int8_similarity.py``: the integer dot is
    exact on both paths and the float postprocess
    (``quant.int8_score_from_dots``) is literally shared, so kernel rungs
    match this oracle bitwise.
    """
    q_codes, q_scales = quant.quantize_queries(qs)
    dots = jax.lax.dot_general(
        q_codes.astype(jnp.int32), corpus.codes.astype(jnp.int32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32)
    return quant.int8_score_from_dots(dots, q_codes, q_scales, corpus, metric)


def pq_similarity_many(qs: jnp.ndarray, corpus, metric: str) -> jnp.ndarray:
    """Quantized scores of a :class:`repro.quant.PQCorpus` against float
    queries qs[b, d] -> f32[b, n].

    The LUT gather-sum accumulates subspaces left-to-right
    (``quant.pq_lut_sum``) in the exact order the Pallas one-hot-matmul
    kernel adds its partials, so this oracle is also bitwise ground truth
    for ``kernels/pq_lut_similarity.py``.
    """
    T, S, qn = quant.pq_luts_many(qs, corpus.codebooks, metric)
    sumT = quant.pq_lut_sum(T, corpus.codes)
    sumS = quant.pq_lut_sum(S, corpus.codes)
    return quant.pq_postprocess(sumT, sumS[None, :], qn[:, None], metric)


def pairwise_adjacency(x: jnp.ndarray, eps: jnp.ndarray, metric: str,
                       valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Diversity-graph adjacency (paper Def. 2): A[i,j] = sim(x_i,x_j) > eps.

    Diagonal is False. ``valid`` masks padding rows (False rows/cols have no
    edges).
    """
    k = x.shape[0]
    sims = pairwise_sim(x, x, metric)
    adj = sims > eps
    adj = adj & ~jnp.eye(k, dtype=bool)
    if valid is not None:
        adj = adj & valid[:, None] & valid[None, :]
    return adj


def topk_merge(ids_a: jnp.ndarray, scores_a: jnp.ndarray,
               ids_b: jnp.ndarray, scores_b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge two descending-sorted (ids, scores) lists, keep top len(a).

    Deterministic tie-break on id (asc). This is the tournament-merge
    primitive used by the sharded search reducer.
    """
    L = ids_a.shape[0]
    ids = jnp.concatenate([ids_a, ids_b])
    scores = jnp.concatenate([scores_a, scores_b])
    order = jnp.lexsort((ids, -scores))
    return ids[order][:L], scores[order][:L]


def greedy_diversify(scores: jnp.ndarray, adj: jnp.ndarray, k: int,
                     valid: jnp.ndarray | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Greedy diverse selection (paper §II-B-2) over a scored candidate tile.

    Candidates need NOT be pre-sorted: at each of k steps pick the highest
    scoring non-banned candidate, then ban its diversity-graph neighbors.
    Returns (sel int32[k] local indices, -1 padded; count).
    """
    n = scores.shape[0]
    banned = jnp.zeros((n,), bool) if valid is None else ~valid

    def step(carry, _):
        banned, sel_count = carry
        avail = jnp.where(banned, -jnp.inf, scores)
        j = jnp.argmax(avail)
        ok = ~banned[j] & jnp.isfinite(avail[j])
        new_banned = jnp.where(ok, banned | adj[j] | (jnp.arange(n) == j), banned)
        pick = jnp.where(ok, j, -1).astype(jnp.int32)
        return (new_banned, sel_count + ok.astype(jnp.int32)), pick

    (banned, count), picks = jax.lax.scan(step, (banned, jnp.int32(0)),
                                          None, length=k)
    return picks, count


def fused_round(vectors: jnp.ndarray, ids: jnp.ndarray, scores: jnp.ndarray,
                K: jnp.ndarray, eps: jnp.ndarray, k: int, metric: str):
    """One lane's fused progressive-round stage (semantic ground truth).

    Composes the stages ``ProgressiveEngine._pgs_round`` used to dispatch
    separately — prefix masking, candidate gather, eps-adjacency build,
    greedy diversification, output extraction — exactly, so the fused
    Pallas kernel has a single bit-parity oracle:

    ``ids``/``scores`` are one raw queue prefix row (sorted, -1 / -inf
    sentinels), ``K`` the lane's candidate budget (positions >= K masked
    off), ``eps`` the lane's diversification threshold.

    Returns ``(sel_ids int32[k] global ids -1-padded,
    sel_scores f32[k] zero-padded, count int32,
    cert f32[2] = (total, s_K))`` where ``total`` is the diversified set's
    score sum and ``s_K`` the K-th (worst kept) candidate score — the
    Theorem-2 certificate inputs (``theorem2_holds(minValue, s_K)``).
    """
    W = ids.shape[0]
    keep = jnp.arange(W) < K
    ids_m = jnp.where(keep, ids, -1)
    scores_m = jnp.where(keep, scores, -jnp.inf)
    valid = ids_m >= 0
    x = vectors[jnp.maximum(ids_m, 0)]
    adj = pairwise_adjacency(x, eps, metric, valid)
    sel, count = greedy_diversify(scores_m, adj, k, valid)
    picked = sel >= 0
    gidx = jnp.maximum(sel, 0)
    sel_ids = jnp.where(picked, ids_m[gidx], -1)
    sel_scores = jnp.where(picked, scores_m[gidx], 0.0).astype(jnp.float32)
    total = jnp.sum(sel_scores)
    s_K = jnp.min(jnp.where(valid, scores_m, jnp.inf))
    s_K = jnp.where(jnp.any(valid), s_K, -jnp.inf)
    cert = jnp.stack([total, s_K])
    return sel_ids, sel_scores, count, cert
