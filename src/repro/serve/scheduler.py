"""Continuous-batching lane scheduler — the serving layer over a LaneBackend.

Top layer of the lane-state / backend / scheduler split. A backend
(``core.backend.LaneBackend``) advances a fixed set of lanes one progressive
round per ``step()``; this module decides *which request occupies which lane
when* — and it is backend-neutral: the same scheduler drives the single-host
``core.batch_progressive.ProgressiveEngine`` and the mesh-sharded
``sharded_search.engine.ShardedEngine``.

* **Admission queue** — requests carry their own ``(k, eps, ef, method)``
  (the paper's Definition 1: the query owns its diversification level; no
  index rebuild). ``submit`` enqueues; a bounded queue gives backpressure
  (``SchedulerSaturated``) so callers can shed or defer load, and an
  optional ``shed`` callback lets a latency-SLO policy drop requests at
  submit time before they ever occupy a lane.
* **Continuous batching** — whenever a lane certifies (or exhausts), its
  slot is recycled for the next queued request *between backend steps*,
  while sibling lanes keep their in-flight state. Div-A* trip counts are
  heavy-tailed by design, so under lockstep admission one hard query stalls
  a whole batch; continuous admission keeps every lane busy and cuts p99
  latency and raises throughput on skewed workloads
  (``benchmarks/batch_bench.py --mode skewed`` measures both policies —
  they share this scheduler, differing only in ``admission``; ``--mode
  open`` drives Poisson arrivals against either backend).
* **Compile-signature-aware startup** — backends compile per shape
  signature (lane count x capacity for single-host bursts, group x budget
  for mesh dispatches); the scheduler pre-warms the backend's power-of-two
  ladder at construction so mid-serving growth never pays an XLA trace, and
  exposes the backend's ``SignatureLog`` for recompile auditing.
* **Per-request stats** — wait (submit→admit), service (admit→done), and
  total latency per request, with p50/p99 summaries and Jain's fairness
  index over total latencies.

Parity contract (single-host backend): a request's result is bit-identical
to a fresh per-query driver (``pss``/``pgs``/``pds``) for that query on the
CPU reference path — lane recycling starts from exactly
``beam_search.init_state`` and every engine op is lane-separable, so
admission order cannot leak between requests (``tests/test_scheduler.py``).
The sharded backend's contract is budget-parity: a harvested lane equals
``sharded_diverse_search`` for that query at the lane's final K-budget
(``tests/dist_scripts/sharded_scheduler_check.py``).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.backend import LaneBackend, LaneRequest
from repro.core.batch_progressive import ProgressiveEngine
from repro.core.graph import FlatGraph
from repro.core.pgs import DiverseResult


class SchedulerSaturated(RuntimeError):
    """Admission queue is full — pump the scheduler (or defer) and retry."""


class RequestShed(RuntimeError):
    """The scheduler's SLO-shed policy dropped this request at submit.

    Deliberately *not* a ``SchedulerSaturated``: saturation means "retry
    after pumping", shed means "never retry" — a retry loop catching
    ``SchedulerSaturated`` must not spin on a deterministically-shed
    request."""


@dataclasses.dataclass
class Request(LaneRequest):
    """One diverse-search request: a ``LaneRequest`` plus scheduler-side
    bookkeeping (id, timing trace, lane assignment, result)."""
    rid: int = -1
    t_submit: float = 0.0
    t_admit: float | None = None
    t_done: float | None = None
    lane: int | None = None
    result: DiverseResult | None = None

    @property
    def wait(self) -> float:
        return (self.t_admit or 0.0) - self.t_submit

    @property
    def service(self) -> float:
        return (self.t_done or 0.0) - (self.t_admit or 0.0)

    @property
    def latency(self) -> float:
        return (self.t_done or 0.0) - self.t_submit


def percentile(xs: list[float], p: float) -> float:
    """p-th percentile of a (possibly empty) sample — the summary helper
    shared with benchmarks so reported stats can't drift."""
    return float(np.percentile(np.asarray(xs), p)) if xs else 0.0


_pctl = percentile   # internal alias, kept for existing call sites


def jain_fairness(latencies: list[float]) -> float:
    """Jain's index over per-request latencies: 1.0 = perfectly even."""
    x = np.asarray(latencies, np.float64)
    if x.size == 0 or not np.any(x > 0):
        return 1.0
    return float((x.sum() ** 2) / (x.size * np.sum(x * x)))


class LaneScheduler:
    """Admission queue + lane recycling over any ``LaneBackend``.

    Construct with either a ``graph`` (builds the default single-host
    ``ProgressiveEngine``) or an explicit ``backend=`` (e.g. a mesh-sharded
    ``ShardedEngine``); everything above the backend — admission policies,
    backpressure, shed, stats — is identical.

    ``admission`` picks the batching policy:

    * ``"continuous"`` (default) — refill any freed lane before every step;
      a certified lane's slot goes to the next queued request immediately.
    * ``"lockstep"`` — refill only when *every* lane is free: the classic
      whole-batch regime (each wave waits for its straggler). Kept as the
      controlled baseline for the skewed-workload benchmark; results are
      identical either way, only latency/throughput differ.

    ``shed`` is an optional callback ``(request, scheduler) -> bool`` run at
    submit time; returning True drops the request (``RequestShed``) — the
    hook for latency-SLO admission control (e.g. shed heavy-eps requests
    once the queue's expected wait exceeds the SLO).
    """

    def __init__(self, graph: FlatGraph | None = None, num_lanes: int = 8, *,
                 backend: LaneBackend | None = None,
                 max_k: int = 16, default_ef: int = 40,
                 capacity0: int | None = None,
                 max_capacity: int | None = None,
                 max_pending: int | None = None,
                 max_iters: int = 64, max_expansions: int = 400_000,
                 max_signatures: int | None = 1024,
                 admission: str = "continuous",
                 shed: Callable[[Request, "LaneScheduler"], bool] | None = None,
                 prewarm: bool = True,
                 prewarm_capacity: int | None = None,
                 prewarm_ks: tuple = (), prewarm_widths: tuple = (),
                 history: int = 4096,
                 clock: Callable[[], float] = time.monotonic):
        if admission not in ("continuous", "lockstep"):
            raise ValueError(f"unknown admission policy {admission!r}")
        if backend is None:
            if graph is None:
                raise ValueError("LaneScheduler needs a graph or a backend")
            backend = ProgressiveEngine(
                graph, num_lanes, max_k=max_k, default_ef=default_ef,
                capacity0=capacity0, max_capacity=max_capacity,
                max_iters=max_iters, max_expansions=max_expansions,
                max_signatures=max_signatures)
        else:
            if graph is not None:
                raise ValueError("pass either graph or backend=, not both")
            # known limitation: a value explicitly passed that *equals* the
            # default (e.g. num_lanes=8) is indistinguishable from "not
            # passed" and is silently ignored; only non-default overrides
            # are caught here
            overridden = [name for name, (val, default) in dict(
                num_lanes=(num_lanes, 8), max_k=(max_k, 16),
                default_ef=(default_ef, 40), capacity0=(capacity0, None),
                max_capacity=(max_capacity, None), max_iters=(max_iters, 64),
                max_expansions=(max_expansions, 400_000),
                max_signatures=(max_signatures, 1024)).items()
                if val != default]
            if overridden:
                raise ValueError(
                    f"{overridden} are backend-construction parameters — "
                    "configure them on the backend, not the scheduler")
        self.backend = backend
        self.engine = backend   # legacy alias (PR 2 name)
        self.num_lanes = int(backend.num_lanes)
        self.admission = admission
        self.shed = shed
        self.max_pending = (max_pending if max_pending is not None
                            else 4 * self.num_lanes)
        self.clock = clock
        self.pending: collections.deque[Request] = collections.deque()
        self.inflight: dict[int, Request] = {}
        # bounded history: a long-running server must not grow without
        # bound; stats percentiles cover the retained window, counters
        # cover the lifetime
        self.completed: collections.deque[Request] = collections.deque(
            maxlen=history)
        self.total_completed = 0
        self.total_shed = 0
        self._next_rid = 0
        self.steps = 0
        if prewarm:
            self.backend.prewarm(max_capacity=prewarm_capacity,
                                 ks=prewarm_ks, widths=prewarm_widths)

    # -- admission ----------------------------------------------------------
    def submit(self, q, k: int, eps: float, ef: int | None = None,
               method: str | None = None, max_K: int | None = None) -> Request:
        """Enqueue a request; raises ``SchedulerSaturated`` on backpressure
        or ``RequestShed`` if the shed policy drops it (``try_submit`` is the
        non-raising variant). ``method`` defaults to the backend's native
        method. Invalid parameters are rejected here, not at admission — a
        bad request must never dequeue and then abort serving mid-pump."""
        if method is None:
            method = self.backend.methods[0]
        if method not in self.backend.methods:
            raise ValueError(
                f"method {method!r} not served by this backend "
                f"(supported: {self.backend.methods})")
        if not 1 <= k <= self.backend.max_k:
            raise ValueError(
                f"k={k} outside [1, {self.backend.max_k}] (backend max_k)")
        if len(self.pending) >= self.max_pending:
            raise SchedulerSaturated(
                f"{len(self.pending)} pending >= max_pending="
                f"{self.max_pending}; pump() or shed load")
        req = Request(rid=self._next_rid, q=np.asarray(q, np.float32),
                      k=k, eps=eps, ef=int(ef or self.backend.default_ef),
                      method=method, max_K=max_K, t_submit=self.clock())
        self._next_rid += 1   # shed requests keep their rid (unique traces)
        if self.shed is not None and self.shed(req, self):
            self.total_shed += 1
            raise RequestShed(f"request {req.rid} shed by SLO policy")
        self.pending.append(req)
        return req

    def try_submit(self, q, k: int, eps: float, **kw) -> Request | None:
        """``submit`` returning None instead of raising, for both drop
        reasons (inspect ``total_shed`` to tell them apart)."""
        try:
            return self.submit(q, k, eps, **kw)
        except (SchedulerSaturated, RequestShed):
            return None

    def _refill(self) -> None:
        if self.admission == "lockstep" and self.inflight:
            return  # whole-batch regime: wait for the wave's straggler
        for lane in self.backend.free_lanes():
            if not self.pending:
                break
            req = self.pending.popleft()
            self.backend.admit(int(lane), req)
            req.t_admit = self.clock()
            req.lane = int(lane)
            self.inflight[int(lane)] = req

    # -- serving loop -------------------------------------------------------
    def pump(self) -> list[Request]:
        """Refill freed lanes, advance the backend one step, harvest and
        recycle finished lanes; returns the requests that completed."""
        self._refill()
        done: list[Request] = []
        if self.backend.active_count():
            self.steps += 1
            self.backend.step()
        for lane, result in self.backend.harvest():
            req = self.inflight.pop(lane)
            req.result = result
            req.t_done = self.clock()
            self.backend.recycle(lane)
            self.completed.append(req)
            self.total_completed += 1
            done.append(req)
        return done

    def drain(self) -> list[Request]:
        """Pump until the queue and all lanes are empty."""
        out: list[Request] = []
        while self.pending or self.inflight:
            out.extend(self.pump())
            self._refill()
        return out

    def run(self, qs, ks, epss, efs=None, method: str | None = None
            ) -> list[DiverseResult | None]:
        """Serve a closed batch of requests; results in submission order.

        Per-request parameters may be scalars or per-request sequences.
        Oversubmission is handled by pumping whenever the queue saturates;
        a request dropped by the shed policy yields ``None`` in its slot
        (it is *not* retried — a deterministic policy would shed it again
        forever).
        """
        qs = np.asarray(qs, np.float32)
        B = qs.shape[0]
        ks = np.broadcast_to(np.asarray(ks), (B,))
        epss = np.broadcast_to(np.asarray(epss, np.float64), (B,))
        efs = np.broadcast_to(
            np.asarray(efs if efs is not None else self.backend.default_ef),
            (B,))
        reqs: list[Request | None] = []
        for i in range(B):
            while True:
                try:
                    reqs.append(self.submit(qs[i], int(ks[i]),
                                            float(epss[i]), ef=int(efs[i]),
                                            method=method))
                    break
                except RequestShed:
                    reqs.append(None)
                    break
                except SchedulerSaturated:
                    self.pump()   # backpressure: free a slot and retry
        self.drain()
        return [r.result if r is not None else None for r in reqs]

    # -- reporting ----------------------------------------------------------
    def latency_stats(self) -> dict:
        """p50/p99 wait/service/total latency, Jain fairness, throughput
        (percentiles/throughput over the retained ``history`` window;
        ``completed``/``shed`` count the scheduler's lifetime)."""
        reqs = list(self.completed)
        lats = [r.latency for r in reqs]
        waits = [r.wait for r in reqs]
        svcs = [r.service for r in reqs]
        span = (max(r.t_done for r in reqs) - min(r.t_submit for r in reqs)
                if reqs else 0.0)
        return dict(
            completed=self.total_completed,
            shed=self.total_shed,
            pending=len(self.pending),
            inflight=len(self.inflight),
            steps=self.steps,
            p50_latency=_pctl(lats, 50), p99_latency=_pctl(lats, 99),
            p50_wait=_pctl(waits, 50), p99_wait=_pctl(waits, 99),
            p50_service=_pctl(svcs, 50), p99_service=_pctl(svcs, 99),
            fairness=jain_fairness(lats),
            throughput=len(reqs) / span if span > 0 else 0.0,
            certified_frac=(float(np.mean([r.result.stats.certified
                                           for r in reqs])) if reqs else 0.0),
            signatures=len(self.backend.signature_log),
            unplanned_signatures=len(self.backend.signature_log.unplanned),
        )
