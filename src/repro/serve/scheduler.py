"""Continuous-batching lane scheduler — the serving layer over the engine.

Top layer of the lane-state / engine / scheduler split. The engine
(``core.batch_progressive.ProgressiveEngine``) advances a fixed set of lanes
one progressive round per ``step()``; this module decides *which request
occupies which lane when*:

* **Admission queue** — requests carry their own ``(k, eps, ef, method)``
  (the paper's Definition 1: the query owns its diversification level; no
  index rebuild). ``submit`` enqueues; a bounded queue gives backpressure
  (``SchedulerSaturated``) so callers can shed or defer load.
* **Continuous batching** — whenever a lane certifies (or exhausts), its
  slot is recycled for the next queued request *between engine steps*,
  while sibling lanes keep their in-flight state. Div-A* trip counts are
  heavy-tailed by design, so under lockstep admission one hard query stalls
  a whole batch; continuous admission keeps every lane busy and cuts p99
  latency and raises throughput on skewed workloads
  (``benchmarks/batch_bench.py --mode skewed`` measures both policies —
  they share this scheduler, differing only in ``admission``).
* **Compile-signature-aware startup** — the engine compiles per (lane
  count, physical capacity) for bursts and per (group, width, k) for
  diversify/verify; the scheduler pre-warms the power-of-two capacity
  ladder at construction so mid-serving growth never pays an XLA trace,
  and exposes the engine's ``SignatureLog`` for recompile auditing.
* **Per-request stats** — wait (submit→admit), service (admit→done), and
  total latency per request, with p50/p99 summaries and Jain's fairness
  index over total latencies.

Parity contract: a request's result is bit-identical to a fresh per-query
driver (``pss``/``pgs``/``pds``) for that query on the CPU reference path —
lane recycling starts from exactly ``beam_search.init_state`` and every
engine op is lane-separable, so admission order cannot leak between
requests. ``tests/test_scheduler.py`` enforces this.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.batch_progressive import ProgressiveEngine
from repro.core.graph import FlatGraph
from repro.core.pgs import DiverseResult


class SchedulerSaturated(RuntimeError):
    """Admission queue is full — shed load or pump the scheduler first."""


@dataclasses.dataclass
class Request:
    """One diverse-search request with its own (k, eps) and timing trace."""
    rid: int
    q: np.ndarray
    k: int
    eps: float
    ef: int
    method: str = "pss"
    max_K: int | None = None
    t_submit: float = 0.0
    t_admit: float | None = None
    t_done: float | None = None
    lane: int | None = None
    result: DiverseResult | None = None

    @property
    def wait(self) -> float:
        return (self.t_admit or 0.0) - self.t_submit

    @property
    def service(self) -> float:
        return (self.t_done or 0.0) - (self.t_admit or 0.0)

    @property
    def latency(self) -> float:
        return (self.t_done or 0.0) - self.t_submit


def _pctl(xs: list[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p)) if xs else 0.0


def jain_fairness(latencies: list[float]) -> float:
    """Jain's index over per-request latencies: 1.0 = perfectly even."""
    x = np.asarray(latencies, np.float64)
    if x.size == 0 or not np.any(x > 0):
        return 1.0
    return float((x.sum() ** 2) / (x.size * np.sum(x * x)))


class LaneScheduler:
    """Admission queue + lane recycling over a ``ProgressiveEngine``.

    ``admission`` picks the batching policy:

    * ``"continuous"`` (default) — refill any freed lane before every step;
      a certified lane's slot goes to the next queued request immediately.
    * ``"lockstep"`` — refill only when *every* lane is free: the classic
      whole-batch regime (each wave waits for its straggler). Kept as the
      controlled baseline for the skewed-workload benchmark; results are
      identical either way, only latency/throughput differ.
    """

    def __init__(self, graph: FlatGraph, num_lanes: int = 8, *,
                 max_k: int = 16, default_ef: int = 40,
                 capacity0: int | None = None,
                 max_capacity: int | None = None,
                 max_pending: int | None = None,
                 max_iters: int = 64, max_expansions: int = 400_000,
                 max_signatures: int | None = 1024,
                 admission: str = "continuous",
                 prewarm: bool = True,
                 prewarm_capacity: int | None = None,
                 prewarm_ks: tuple = (), prewarm_widths: tuple = (),
                 history: int = 4096,
                 clock: Callable[[], float] = time.monotonic):
        if admission not in ("continuous", "lockstep"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.engine = ProgressiveEngine(
            graph, num_lanes, max_k=max_k, default_ef=default_ef,
            capacity0=capacity0, max_capacity=max_capacity,
            max_iters=max_iters, max_expansions=max_expansions,
            max_signatures=max_signatures)
        self.num_lanes = num_lanes
        self.admission = admission
        self.max_pending = (max_pending if max_pending is not None
                            else 4 * num_lanes)
        self.clock = clock
        self.pending: collections.deque[Request] = collections.deque()
        self.inflight: dict[int, Request] = {}
        # bounded history: a long-running server must not grow without
        # bound; stats percentiles cover the retained window, counters
        # cover the lifetime
        self.completed: collections.deque[Request] = collections.deque(
            maxlen=history)
        self.total_completed = 0
        self._next_rid = 0
        self.steps = 0
        if prewarm:
            self.engine.prewarm(max_capacity=prewarm_capacity,
                                ks=prewarm_ks, widths=prewarm_widths)

    # -- admission ----------------------------------------------------------
    def submit(self, q, k: int, eps: float, ef: int | None = None,
               method: str = "pss", max_K: int | None = None) -> Request:
        """Enqueue a request; raises ``SchedulerSaturated`` on backpressure
        (``try_submit`` is the non-raising variant). Invalid parameters are
        rejected here, not at admission — a bad request must never dequeue
        and then abort serving mid-pump."""
        if method not in ("pss", "pgs", "pds"):
            raise ValueError(f"unknown progressive method {method!r}")
        if not 1 <= k <= self.engine.max_k:
            raise ValueError(
                f"k={k} outside [1, {self.engine.max_k}] (engine max_k)")
        if len(self.pending) >= self.max_pending:
            raise SchedulerSaturated(
                f"{len(self.pending)} pending >= max_pending="
                f"{self.max_pending}; pump() or shed load")
        req = Request(rid=self._next_rid, q=np.asarray(q, np.float32),
                      k=k, eps=eps, ef=int(ef or self.engine.default_ef),
                      method=method, max_K=max_K, t_submit=self.clock())
        self._next_rid += 1
        self.pending.append(req)
        return req

    def try_submit(self, q, k: int, eps: float, **kw) -> Request | None:
        try:
            return self.submit(q, k, eps, **kw)
        except SchedulerSaturated:
            return None

    def _refill(self) -> None:
        if self.admission == "lockstep" and self.inflight:
            return  # whole-batch regime: wait for the wave's straggler
        for lane in self.engine.free_lanes():
            if not self.pending:
                break
            req = self.pending.popleft()
            self.engine.admit(int(lane), req.q, k=req.k, eps=req.eps,
                              ef=req.ef, method=req.method, max_K=req.max_K)
            req.t_admit = self.clock()
            req.lane = int(lane)
            self.inflight[int(lane)] = req

    # -- serving loop -------------------------------------------------------
    def pump(self) -> list[Request]:
        """Refill freed lanes and advance the engine one step; returns the
        requests that completed during this pump."""
        self._refill()
        done: list[Request] = []
        if self.engine.active_count():
            self.steps += 1
            for lane in self.engine.step():
                req = self.inflight.pop(lane)
                req.result = self.engine.result(lane)
                req.t_done = self.clock()
                self.completed.append(req)
                self.total_completed += 1
                done.append(req)
        return done

    def drain(self) -> list[Request]:
        """Pump until the queue and all lanes are empty."""
        out: list[Request] = []
        while self.pending or self.inflight:
            out.extend(self.pump())
            self._refill()
        return out

    def run(self, qs, ks, epss, efs=None, method: str = "pss"
            ) -> list[DiverseResult]:
        """Serve a closed batch of requests; results in submission order.

        Per-request parameters may be scalars or per-request sequences.
        Oversubmission is handled by pumping whenever the queue saturates.
        """
        qs = np.asarray(qs, np.float32)
        B = qs.shape[0]
        ks = np.broadcast_to(np.asarray(ks), (B,))
        epss = np.broadcast_to(np.asarray(epss, np.float64), (B,))
        efs = np.broadcast_to(
            np.asarray(efs if efs is not None else self.engine.default_ef),
            (B,))
        reqs: list[Request] = []
        for i in range(B):
            while True:
                r = self.try_submit(qs[i], int(ks[i]), float(epss[i]),
                                    ef=int(efs[i]), method=method)
                if r is not None:
                    reqs.append(r)
                    break
                self.pump()
        self.drain()
        return [r.result for r in reqs]

    # -- reporting ----------------------------------------------------------
    def latency_stats(self) -> dict:
        """p50/p99 wait/service/total latency, Jain fairness, throughput
        (percentiles/throughput over the retained ``history`` window;
        ``completed`` counts the scheduler's lifetime)."""
        reqs = list(self.completed)
        lats = [r.latency for r in reqs]
        waits = [r.wait for r in reqs]
        svcs = [r.service for r in reqs]
        span = (max(r.t_done for r in reqs) - min(r.t_submit for r in reqs)
                if reqs else 0.0)
        return dict(
            completed=self.total_completed,
            pending=len(self.pending),
            inflight=len(self.inflight),
            steps=self.steps,
            p50_latency=_pctl(lats, 50), p99_latency=_pctl(lats, 99),
            p50_wait=_pctl(waits, 50), p99_wait=_pctl(waits, 99),
            p50_service=_pctl(svcs, 50), p99_service=_pctl(svcs, 99),
            fairness=jain_fairness(lats),
            throughput=len(reqs) / span if span > 0 else 0.0,
            certified_frac=(float(np.mean([r.result.stats.certified
                                           for r in reqs])) if reqs else 0.0),
            signatures=len(self.engine.signatures),
            unplanned_signatures=len(self.engine.signatures.unplanned),
        )
