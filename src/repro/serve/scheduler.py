"""Continuous-batching lane scheduler — the serving layer over a LaneBackend.

Top layer of the lane-state / backend / scheduler / policy split. A backend
(``core.backend.LaneBackend``) advances a fixed set of lanes one progressive
round per ``step()``; this module decides *which request occupies which lane
when* — and it is backend-neutral: the same scheduler drives the single-host
``core.batch_progressive.ProgressiveEngine`` and the mesh-sharded
``sharded_search.engine.ShardedEngine``.

* **Admission queue** — requests carry their own ``(k, eps, ef, method)``
  (the paper's Definition 1: the query owns its diversification level; no
  index rebuild) plus a ``tenant`` label. ``submit`` enqueues; a bounded
  queue gives backpressure (``SchedulerSaturated``) so callers can shed or
  defer load, and an optional ``shed`` callback lets a custom policy drop
  requests at submit time before they ever occupy a lane.
* **Admission policies** (``serve.policies``) — the queue is drained by a
  pluggable, cost-aware policy: ``"fifo"`` (default — submission order,
  bit-exactly the historical behavior), ``"drr"`` (deficit round-robin
  across tenants, deficit charged in *predicted expansions*), or
  ``"slo_cost"`` (shed / defer / earliest-deadline-first from predicted
  service time vs per-tenant SLO budgets). Policies read an online
  ``ExpansionCostModel`` that the scheduler updates from every harvested
  result's real ``SearchStats`` counters.
* **Continuous batching** — whenever a lane certifies (or exhausts), its
  slot is recycled for the next policy-selected request *between backend
  steps*, while sibling lanes keep their in-flight state. Div-A* trip
  counts are heavy-tailed by design, so under lockstep admission one hard
  query stalls a whole batch; continuous admission keeps every lane busy
  and cuts p99 latency on skewed workloads
  (``benchmarks/batch_bench.py --mode skewed`` measures both policies;
  ``--mode open`` drives Poisson arrivals against either backend with any
  admission policy).
* **Compile-signature-aware startup** — backends compile per shape
  signature (lane count x capacity for single-host bursts, group x budget
  for mesh dispatches); the scheduler pre-warms the backend's power-of-two
  ladder at construction so mid-serving growth never pays an XLA trace, and
  exposes the backend's ``SignatureLog`` for recompile auditing.
* **Per-request stats** — wait (submit→admit), service (admit→done), and
  total latency per request, with p50/p99 summaries, Jain's fairness index,
  and the same broken out per tenant.

Parity contract (single-host backend): a request's result is bit-identical
to a fresh per-query driver (``pss``/``pgs``/``pds``) for that query on the
CPU reference path — lane recycling starts from exactly
``beam_search.init_state`` and every engine op is lane-separable, so
admission order cannot leak between requests (``tests/test_scheduler.py``;
this is also why switching admission *policies* can change latencies but
never results). The sharded backend's contract is budget-parity: a
harvested lane equals ``sharded_diverse_search`` for that query at the
lane's final K-budget (``tests/dist_scripts/sharded_scheduler_check.py``).
See ``docs/ARCHITECTURE.md`` for the full contract map.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.backend import (LaneBackend, LaneRequest,
                                RescalableBackend)
from repro.core.batch_progressive import ProgressiveEngine
from repro.core.graph import FlatGraph
from repro.core.pgs import DiverseResult
from repro.serve import policies as P
from repro.serve.cache import CacheEntry, SemanticResultCache
from repro.serve.policies import ExpansionCostModel, make_policy
from repro.serve.query import Query


class SchedulerSaturated(RuntimeError):
    """Admission queue is full — pump the scheduler (or defer) and retry.

    Raised by ``submit`` when ``len(pending) >= max_pending``. This is
    *backpressure*, not a verdict on the request: the same request is
    expected to succeed after ``pump()`` frees queue slots."""


class RequestShed(RuntimeError):
    """The scheduler's shed policy dropped this request at submit.

    Deliberately *not* a ``SchedulerSaturated``: saturation means "retry
    after pumping", shed means "never retry" — a retry loop catching
    ``SchedulerSaturated`` must not spin on a deterministically-shed
    request. Raised either by the legacy ``shed`` callback or by an
    admission policy returning ``SHED`` (e.g. ``slo_cost`` when a
    request's predicted service time alone exceeds its tenant's SLO
    budget)."""


class RequestDeferred(RuntimeError):
    """The admission policy declined this request *for now*.

    The middle ground between ``SchedulerSaturated`` (queue mechanics —
    retry immediately after a pump) and ``RequestShed`` (never retry):
    ``slo_cost`` defers a request whose predicted queue wait + service
    exceeds its SLO budget but whose service alone fits — once backlog
    drains, a retried submit is expected to admit. The request was *not*
    enqueued; ``total_deferred`` counts these decisions."""


@dataclasses.dataclass(eq=False)
class Request(LaneRequest):
    """One diverse-search request: a ``LaneRequest`` plus scheduler-side
    bookkeeping. Compares by identity (``eq=False``): two requests are
    never "the same request" just because their parameters match, and the
    policies' queue bookkeeping (``deque.remove``) relies on it.

    Fields added over ``LaneRequest`` (all scheduler-owned — backends never
    read them):

    * ``tenant`` — fairness/accounting label; admission policies (``drr``,
      ``slo_cost``) schedule *across* tenants, and ``latency_stats()``
      reports per-tenant percentiles. The default ``"default"`` keeps
      single-tenant callers unchanged.
    * ``rid`` — unique per-scheduler request id, assigned at submit (shed
      and deferred requests consume ids too, so traces stay unambiguous).
    * ``t_submit`` / ``t_admit`` / ``t_done`` — clock readings at submit,
      lane admission, and harvest (``None`` until reached).
    * ``lane`` — the backend lane that served it (``None`` until admitted;
      stays ``None`` for a cache hit, which never occupies one).
    * ``result`` — the harvested ``DiverseResult`` (``None`` until done).
    * ``cache_hit`` / ``cache_entry`` — set when the semantic result cache
      served this request at submit: the entry whose frontier was
      revalidated against this request's live query (kept so audits can
      independently re-run ``theorem2_recheck`` on served hits).
    * ``slo`` — the submitted ``Query``'s latency budget (seconds; None =
      best effort), carried for policies and shed callbacks to read.
    """
    tenant: str = "default"
    slo: float | None = None
    rid: int = -1
    t_submit: float = 0.0
    t_admit: float | None = None
    t_done: float | None = None
    lane: int | None = None
    result: DiverseResult | None = None
    cache_hit: bool = False
    cache_entry: CacheEntry | None = None

    @property
    def wait(self) -> float:
        """Submit-to-admission seconds (0.0 until admitted)."""
        return (self.t_admit or 0.0) - self.t_submit

    @property
    def service(self) -> float:
        """Admission-to-completion seconds (0.0 until done)."""
        return (self.t_done or 0.0) - (self.t_admit or 0.0)

    @property
    def latency(self) -> float:
        """Submit-to-completion seconds (0.0 until done)."""
        return (self.t_done or 0.0) - self.t_submit


@dataclasses.dataclass(eq=False)
class WriteTicket:
    """One admitted corpus write (``upsert`` or ``delete``).

    Writes share the scheduler's front door with reads: ``submit_write``
    enqueues, and the ticket is *applied* — delta append / bitmap flip on
    the backend's ``MutableIndex``, plus semantic-cache invalidation — at
    the top of the next ``pump()``, i.e. between backend rounds. In-flight
    lanes observe the write at harvest (contract 15's live merge), never
    mid-round. ``ids`` holds the assigned (upsert) or affected (delete) ids
    once applied; ``apply_writes()`` forces application without a pump.
    """
    op: str                          # "upsert" | "delete"
    payload: object                  # vectors [m, d] | ids
    wid: int = -1
    t_submit: float = 0.0
    t_applied: float | None = None
    ids: np.ndarray | None = None

    @property
    def applied(self) -> bool:
        return self.t_applied is not None


@dataclasses.dataclass
class ElasticPolicy:
    """When to move a rescalable backend between its prepared meshes.

    The scheduler samples queue depth at every pump boundary (the same
    between-rounds point the epoch swap uses — but the scale event is
    quiesce-free: in-flight lanes migrate, nothing drains). A signal must
    hold for ``sustain`` consecutive pumps before it fires, and after any
    scale event ``cooldown`` pumps pass before the next — both guards keep
    a bursty queue from thrashing the mesh.

    * grow: ``pending >= grow_depth`` (default: the backend's lane count —
      a full extra wave is waiting) sustained ``sustain`` pumps -> rescale
      to the next-larger prepared shard count.
    * shrink: ``pending <= shrink_depth`` sustained ``shrink_sustain``
      pumps -> next-smaller prepared count. In-flight lanes do NOT block a
      shrink; they straddle it and resume on the smaller mesh.
    """
    grow_depth: int | None = None      # None -> backend.num_lanes
    shrink_depth: int = 0
    sustain: int = 2
    shrink_sustain: int = 8
    cooldown: int = 8


def percentile(xs: list[float], p: float) -> float:
    """p-th percentile of a (possibly empty) sample — the summary helper
    shared with benchmarks so reported stats can't drift."""
    return float(np.percentile(np.asarray(xs), p)) if xs else 0.0


_pctl = percentile   # internal alias, kept for existing call sites


def jain_fairness(latencies: list[float]) -> float:
    """Jain's index over per-request latencies: 1.0 = perfectly even."""
    x = np.asarray(latencies, np.float64)
    if x.size == 0 or not np.any(x > 0):
        return 1.0
    return float((x.sum() ** 2) / (x.size * np.sum(x * x)))


class LaneScheduler:
    """Admission queue + lane recycling over any ``LaneBackend``.

    Construct with either a ``graph`` (builds the default single-host
    ``ProgressiveEngine``) or an explicit ``backend=`` (e.g. a mesh-sharded
    ``ShardedEngine``); everything above the backend — admission policies,
    backpressure, shed, stats — is identical.

    ``admission`` picks the batching regime:

    * ``"continuous"`` (default) — refill any freed lane before every step;
      a certified lane's slot goes to the next queued request immediately.
    * ``"lockstep"`` — refill only when *every* lane is free: the classic
      whole-batch regime (each wave waits for its straggler). Kept as the
      controlled baseline for the skewed-workload benchmark; results are
      identical either way, only latency/throughput differ.

    ``policy`` picks the admission-*order* policy draining the queue:
    ``"fifo"`` (default; submission order — bit-exactly the pre-policy
    scheduler), ``"drr"``, ``"slo_cost"``, or any
    ``serve.policies.AdmissionPolicy`` instance. ``cost_model`` optionally
    supplies a pre-calibrated (possibly frozen) ``ExpansionCostModel``; by
    default a fresh model is created and learns online from every
    harvested result regardless of policy, so ``latency_stats()`` always
    reports calibration.

    ``cache`` / ``cache_size`` enable the semantic result cache
    (``serve.cache.SemanticResultCache``; ``cache_size=N`` builds one over
    the backend's own corpus). ``submit`` probes it first: a near-hit whose
    certificate revalidates against the live query completes immediately —
    no lane, no queue slot — and every harvested certified result is
    offered back for admission. Contract 14: a hit is served only after
    its frontier was rescored against the live query and re-passed
    ``theorem2_recheck``; with distinct queries the cache never hits and
    the served results are bit-identical to an uncached scheduler.

    ``shed`` is an optional callback ``(request, scheduler) -> bool`` run at
    submit time; returning True drops the request (``RequestShed``). It
    predates the policy layer and stays supported — it runs *before* the
    policy's own decision, so existing SLO callbacks keep working verbatim
    (``slo_cost`` subsumes the common case with per-tenant budgets).
    """

    def __init__(self, graph: FlatGraph | None = None, num_lanes: int = 8, *,
                 backend: LaneBackend | None = None,
                 max_k: int = 16, default_ef: int = 40,
                 capacity0: int | None = None,
                 max_capacity: int | None = None,
                 max_pending: int | None = None,
                 max_iters: int = 64, max_expansions: int = 400_000,
                 max_signatures: int | None = 1024,
                 admission: str = "continuous",
                 policy: str | P.AdmissionPolicy = "fifo",
                 cost_model: ExpansionCostModel | None = None,
                 cache: SemanticResultCache | None = None,
                 cache_size: int = 0,
                 shed: Callable[[Request, "LaneScheduler"], bool] | None = None,
                 elastic: "ElasticPolicy | bool | None" = None,
                 prewarm: bool = True,
                 prewarm_capacity: int | None = None,
                 prewarm_ks: tuple = (), prewarm_widths: tuple = (),
                 history: int = 4096,
                 clock: Callable[[], float] = time.monotonic):
        if admission not in ("continuous", "lockstep"):
            raise ValueError(f"unknown admission policy {admission!r}")
        if backend is None:
            if graph is None:
                raise ValueError("LaneScheduler needs a graph or a backend")
            backend = ProgressiveEngine(
                graph, num_lanes, max_k=max_k, default_ef=default_ef,
                capacity0=capacity0, max_capacity=max_capacity,
                max_iters=max_iters, max_expansions=max_expansions,
                max_signatures=max_signatures)
        else:
            if graph is not None:
                raise ValueError("pass either graph or backend=, not both")
            # known limitation: a value explicitly passed that *equals* the
            # default (e.g. num_lanes=8) is indistinguishable from "not
            # passed" and is silently ignored; only non-default overrides
            # are caught here
            overridden = [name for name, (val, default) in dict(
                num_lanes=(num_lanes, 8), max_k=(max_k, 16),
                default_ef=(default_ef, 40), capacity0=(capacity0, None),
                max_capacity=(max_capacity, None), max_iters=(max_iters, 64),
                max_expansions=(max_expansions, 400_000),
                max_signatures=(max_signatures, 1024)).items()
                if val != default]
            if overridden:
                raise ValueError(
                    f"{overridden} are backend-construction parameters — "
                    "configure them on the backend, not the scheduler")
        self.backend = backend
        self.engine = backend   # legacy alias (PR 2 name)
        self.num_lanes = int(backend.num_lanes)
        # quantized backends get their own cost-model buckets: compressed
        # rounds have a different expansions/sec and round profile, so
        # pricing them with float traffic would skew fair scheduling
        self.backend_compressed = bool(getattr(backend, "compressed", False))
        self.admission = admission
        self.shed = shed
        self.cost_model = cost_model or ExpansionCostModel()
        self.policy = make_policy(policy).bind(self)
        if cache is not None and cache_size:
            raise ValueError("pass either cache= or cache_size=, not both")
        if cache is None and cache_size:
            cache = SemanticResultCache.for_backend(backend, cache_size)
        self.cache = cache
        if cache is not None and hasattr(backend, "record_candidates"):
            # certificate frontiers must reach harvest for cache admission
            backend.record_candidates = True
        self.max_pending = (max_pending if max_pending is not None
                            else 4 * self.num_lanes)
        self.clock = clock
        self.pending: collections.deque[Request] = collections.deque()
        self.inflight: dict[int, Request] = {}
        # bounded history: a long-running server must not grow without
        # bound; stats percentiles cover the retained window, counters
        # cover the lifetime
        self.completed: collections.deque[Request] = collections.deque(
            maxlen=history)
        self.total_completed = 0
        self.total_shed = 0
        self.total_deferred = 0
        #: lifetime per-tenant counters (mirroring the totals above).
        #: One entry per distinct tenant label, forever — like any labeled
        #: telemetry, keep tenant cardinality bounded (label by tenant,
        #: not by user/request); the policies' own queue state is
        #: proportional to tenants with *pending* work only
        self.tenant_completed: collections.Counter = collections.Counter()
        self.tenant_shed: collections.Counter = collections.Counter()
        self.tenant_deferred: collections.Counter = collections.Counter()
        self.total_cache_hits = 0
        self.tenant_cache_hits: collections.Counter = collections.Counter()
        self.write_queue: collections.deque[WriteTicket] = collections.deque()
        self.total_writes = 0
        self.total_writes_applied = 0
        self.total_cache_invalidations = 0
        self._next_rid = 0
        self._next_wid = 0
        self.steps = 0
        if elastic:
            if not isinstance(backend, RescalableBackend):
                raise ValueError(
                    "elastic= needs a rescalable backend (a ShardedEngine, "
                    "bare or under MutableBackend) with prepared targets — "
                    "the single-host engine has no mesh to scale")
            self.elastic = ElasticPolicy() if elastic is True else elastic
        else:
            self.elastic = None
        #: one dict per scale event: when, from/to shard counts, the
        #: migration pause (seconds the pump boundary spent inside
        #: ``backend.rescale``), and the queue state that triggered it
        self.scale_events: list[dict] = []
        self._elastic_hot = 0
        self._elastic_cold = 0
        self._elastic_cooldown = 0
        if prewarm:
            self.backend.prewarm(max_capacity=prewarm_capacity,
                                 ks=prewarm_ks, widths=prewarm_widths)

    # -- admission ----------------------------------------------------------
    def submit(self, q, k: int | None = None, eps: float | None = None,
               ef: int | None = None, method: str | None = None,
               max_K: int | None = None, tenant: str = "default",
               slo: float | None = None) -> Request:
        """Enqueue one request; returns its ``Request`` handle.

        ``q`` is either a ``serve.query.Query`` (the public parameter
        object — all other arguments must then be left at their defaults)
        or a raw query vector with ``(k, eps)`` the paper's per-request
        diversification parameters. ``ef`` defaults to the backend's
        ``default_ef``; ``method`` defaults to the backend's native method
        (``backend.methods[0]``); ``max_K`` caps the progressive candidate
        budget; ``tenant`` labels the request for fair scheduling and
        per-tenant stats; ``slo`` is an optional latency budget (seconds)
        for policies/shed callbacks. Text queries need an embedder and are
        resolved by ``DiverseVectorDB`` — the scheduler refuses them.

        Raises ``SchedulerSaturated`` on backpressure (retry after
        ``pump()``), ``RequestShed`` if the shed callback or the admission
        policy drops it (never retry), ``RequestDeferred`` if the policy
        declines it for now (retry once load drains), or ``ValueError`` for
        invalid parameters — rejected here, not at admission, because a bad
        request must never dequeue and then abort serving mid-pump.
        ``try_submit`` is the non-raising variant.
        """
        if isinstance(q, Query):
            if (k is not None or eps is not None or ef is not None
                    or method is not None or max_K is not None
                    or tenant != "default" or slo is not None):
                raise ValueError(
                    "submit(Query) takes no overrides — set the fields on "
                    "the Query itself (dataclasses.replace)")
            query = q
        else:
            if k is None or eps is None:
                raise TypeError("submit needs (q, k, eps) or a Query")
            query = Query(q, k=int(k), eps=float(eps), method=method,
                          tenant=tenant, slo=slo, ef=ef, max_K=max_K)
        method = query.method
        if method is None:
            method = self.backend.methods[0]
        if method not in self.backend.methods:
            raise ValueError(
                f"method {method!r} not served by this backend "
                f"(supported: {self.backend.methods})")
        k = int(query.k)
        if not 1 <= k <= self.backend.max_k:
            raise ValueError(
                f"k={k} outside [1, {self.backend.max_k}] (backend max_k)")
        req = None
        if self.cache is not None:
            # probe before backpressure: a revalidated hit completes here —
            # no lane, no queue slot — so even a saturated scheduler serves
            # duplicated traffic (the whole point of the cache)
            req = self._make_request(query, method)
            served = self._cache_probe(req)
            if served is not None:
                return served
        if len(self.pending) >= self.max_pending:
            raise SchedulerSaturated(
                f"{len(self.pending)} pending >= max_pending="
                f"{self.max_pending}; pump() or shed load")
        if req is None:
            req = self._make_request(query, method)
        tenant = req.tenant
        if self.shed is not None and self.shed(req, self):
            self.total_shed += 1
            self.tenant_shed[tenant] += 1
            raise RequestShed(f"request {req.rid} shed by SLO callback")
        decision = self.policy.on_submit(req)
        if decision == P.SHED:
            self.total_shed += 1
            self.tenant_shed[tenant] += 1
            raise RequestShed(
                f"request {req.rid} shed by {self.policy.name} policy")
        if decision == P.DEFER:
            self.total_deferred += 1
            self.tenant_deferred[tenant] += 1
            raise RequestDeferred(
                f"request {req.rid} deferred by {self.policy.name} policy "
                "(retry once backlog drains)")
        self.pending.append(req)
        self.policy.note_enqueued(req)
        return req

    def _make_request(self, query: Query, method: str) -> Request:
        req = Request(rid=self._next_rid, q=query.embedding(),
                      k=int(query.k), eps=float(query.eps),
                      ef=int(query.ef or self.backend.default_ef),
                      method=method, max_K=query.max_K, tenant=query.tenant,
                      slo=query.slo, t_submit=self.clock())
        self._next_rid += 1   # dropped requests keep their rid (unique traces)
        return req

    def _cache_probe(self, req: Request) -> Request | None:
        """Serve ``req`` from the semantic result cache if a near-hit
        revalidates against its live query; None falls through to the
        normal admission path. Hit or miss is folded into the cost model's
        per-bucket hit probability either way."""
        hit = self.cache.lookup(req.q, req.k, req.eps, req.method)
        self.cost_model.observe_cache(req.k, req.eps, req.method,
                                      hit=hit is not None,
                                      compressed=self.backend_compressed)
        if hit is None:
            return None
        result, entry = hit
        now = self.clock()
        req.t_admit = now
        req.t_done = now
        req.result = result
        req.cache_hit = True
        req.cache_entry = entry
        self.completed.append(req)
        self.total_completed += 1
        self.tenant_completed[req.tenant] += 1
        self.total_cache_hits += 1
        self.tenant_cache_hits[req.tenant] += 1
        return req

    def try_submit(self, q, k: int, eps: float, **kw) -> Request | None:
        """``submit`` returning ``None`` instead of raising, for all three
        drop reasons — saturation, shed, and deferral. Callers that need to
        tell them apart compare ``total_shed`` / ``total_deferred`` across
        the call (a saturated submit moves neither counter); parameter
        ``ValueError``s still raise."""
        try:
            return self.submit(q, k, eps, **kw)
        except (SchedulerSaturated, RequestShed, RequestDeferred):
            return None

    # -- write admission -----------------------------------------------------
    def submit_write(self, op: str, payload) -> WriteTicket:
        """Enqueue one corpus write (``op`` = ``"upsert"`` with ``[m, d]``
        vectors, or ``"delete"`` with ids); returns its ``WriteTicket``.

        Writes are *admitted* here and *applied* at the next pump boundary
        (or an explicit ``apply_writes()``) — between backend rounds, never
        mid-round — so reads and writes share one front door and one
        ordering. Requires a write-capable backend (``MutableBackend`` /
        ``DiverseVectorDB``)."""
        if getattr(self.backend, "mutable_index", None) is None:
            raise TypeError(
                "this backend has no write path — serve through "
                "DiverseVectorDB (or wrap the engine in a MutableBackend)")
        if op not in ("upsert", "delete"):
            raise ValueError(f"unknown write op {op!r}")
        ticket = WriteTicket(op=op, payload=payload, wid=self._next_wid,
                             t_submit=self.clock())
        self._next_wid += 1
        self.write_queue.append(ticket)
        self.total_writes += 1
        return ticket

    def apply_writes(self) -> list[WriteTicket]:
        """Apply every queued write to the backend's ``MutableIndex`` (in
        admission order) and invalidate intersecting cache entries; returns
        the applied tickets. Runs automatically at the top of ``pump()``."""
        applied: list[WriteTicket] = []
        index = self.backend.mutable_index
        while self.write_queue:
            t = self.write_queue.popleft()
            if t.op == "upsert":
                t.ids = index.upsert(t.payload)
            else:
                t.ids = np.asarray(t.payload, np.int64).reshape(-1)
                index.delete(t.ids)
            t.t_applied = self.clock()
            if self.cache is not None:
                self.total_cache_invalidations += self.cache.invalidate(t.ids)
            self.total_writes_applied += 1
            applied.append(t)
        return applied

    def _refill(self) -> None:
        if self.admission == "lockstep" and self.inflight:
            return  # whole-batch regime: wait for the wave's straggler
        for lane in self.backend.free_lanes():
            req = self.policy.pop_next()
            if req is None:
                break
            self.backend.admit(int(lane), req)
            req.t_admit = self.clock()
            req.lane = int(lane)
            self.inflight[int(lane)] = req

    def _maybe_rescale(self) -> None:
        """Elastic scale trigger, run at the pump boundary (between backend
        rounds — every lane is paused-but-resumable there, which is what
        makes the quiesce-free migration legal)."""
        pol = self.elastic
        if pol is None:
            return
        if self._elastic_cooldown > 0:
            self._elastic_cooldown -= 1
            return
        depth = len(self.pending)
        grow_depth = (pol.grow_depth if pol.grow_depth is not None
                      else self.num_lanes)
        if depth >= grow_depth:
            self._elastic_hot += 1
            self._elastic_cold = 0
        elif depth <= pol.shrink_depth:
            self._elastic_cold += 1
            self._elastic_hot = 0
        else:
            self._elastic_hot = self._elastic_cold = 0
        cur = int(self.backend.num_shards)
        options = self.backend.rescale_options()
        target = None
        if self._elastic_hot >= pol.sustain:
            bigger = [p for p in options if p > cur]
            target = min(bigger) if bigger else None
        elif self._elastic_cold >= pol.shrink_sustain and not self.inflight:
            # shrink only when fully idle: targets prepared with fewer
            # lanes then always get their clean lane shrink too (the
            # engine never drops an occupied lane)
            smaller = [p for p in options if p < cur]
            target = max(smaller) if smaller else None
        if target is None:
            return
        t0 = self.clock()
        if self.backend.rescale(target):
            self.scale_events.append(dict(
                t=t0, from_shards=cur, to_shards=int(target),
                pause_s=self.clock() - t0, pending=depth,
                inflight=len(self.inflight)))
            # serving capacity may follow the mesh (lane-scaled targets)
            self.num_lanes = int(self.backend.num_lanes)
        self._elastic_hot = self._elastic_cold = 0
        self._elastic_cooldown = pol.cooldown

    # -- serving loop -------------------------------------------------------
    def pump(self) -> list[Request]:
        """Refill freed lanes (in policy order), advance the backend one
        step, harvest and recycle finished lanes; returns the requests that
        completed. Every harvested result's real ``SearchStats`` counters
        (expansions, rounds) and measured service time are folded into the
        cost model before the next refill, so policy predictions track the
        live workload. Queued writes are applied first — the pump boundary
        is the write boundary (contract 15) and, under ``elastic=``, the
        scale boundary (contract 16: in-flight lanes migrate, nothing
        drains)."""
        if self.write_queue:
            self.apply_writes()
        if self.elastic is not None:
            self._maybe_rescale()
        self._refill()
        done: list[Request] = []
        if self.backend.active_count():
            self.steps += 1
            self.backend.step()
        for lane, result in self.backend.harvest():
            req = self.inflight.pop(lane)
            req.result = result
            req.t_done = self.clock()
            if self.cache is not None and result.stats.certified:
                rec = getattr(self.backend, "last_candidates",
                              [None] * self.num_lanes)[lane]
                if rec is not None:
                    cand_ids, cand_scores, *rest = rec
                    self.cache.admit_request(
                        req.q, req.k, req.eps, req.method, result,
                        cand_ids, cand_scores,
                        slack=rest[0] if rest else None)
            self.backend.recycle(lane)
            self.completed.append(req)
            self.total_completed += 1
            self.tenant_completed[req.tenant] += 1
            self.cost_model.observe(
                req.k, req.eps, req.method,
                expansions=result.stats.expansions,
                rounds=result.stats.search_calls,
                service=req.service,
                compressed=self.backend_compressed)
            self.policy.on_complete(req)
            done.append(req)
        return done

    def drain(self) -> list[Request]:
        """Pump until the queues (read and write) and all lanes are empty."""
        out: list[Request] = []
        while self.pending or self.inflight or self.write_queue:
            out.extend(self.pump())
            self._refill()
        return out

    def run(self, qs, ks, epss, efs=None, method: str | None = None,
            tenants=None) -> list[DiverseResult | None]:
        """Serve a closed batch of requests; results in submission order.

        Per-request parameters (``ks``, ``epss``, ``efs``, ``tenants``) may
        be scalars or per-request sequences. Oversubmission is handled by
        pumping whenever the queue saturates, and a policy-deferred request
        is retried after a pump (deferral is load-dependent, so draining
        backlog un-defers it); a request dropped by the shed policy yields
        ``None`` in its slot (it is *not* retried — a deterministic policy
        would shed it again forever).
        """
        qs = np.asarray(qs, np.float32)
        B = qs.shape[0]
        ks = np.broadcast_to(np.asarray(ks), (B,))
        epss = np.broadcast_to(np.asarray(epss, np.float64), (B,))
        efs = np.broadcast_to(
            np.asarray(efs if efs is not None else self.backend.default_ef),
            (B,))
        tenants = np.broadcast_to(
            np.asarray(tenants if tenants is not None else "default"), (B,))
        reqs: list[Request | None] = []
        for i in range(B):
            while True:
                try:
                    reqs.append(self.submit(qs[i], int(ks[i]),
                                            float(epss[i]), ef=int(efs[i]),
                                            method=method,
                                            tenant=str(tenants[i])))
                    break
                except RequestShed:
                    reqs.append(None)
                    break
                except (SchedulerSaturated, RequestDeferred):
                    self.pump()   # free queue slots / drain backlog, retry
        self.drain()
        return [r.result if r is not None else None for r in reqs]

    # -- reporting ----------------------------------------------------------
    def latency_stats(self) -> dict:
        """Serving stats snapshot.

        Percentiles and throughput cover the retained ``history`` window of
        completed requests; ``completed`` / ``shed`` / ``deferred`` count
        the scheduler's lifetime. Keys:

        * ``completed`` / ``shed`` — lifetime request counts: finished,
          dropped-never-retry. ``deferred`` — lifetime count of *defer
          decisions* (a request resubmitted after deferral and deferred
          again counts each time).
        * ``pending`` / ``inflight`` — current queue depth and occupied
          lanes; ``steps`` — lifetime backend steps.
        * ``p50_latency`` / ``p99_latency`` — submit→done seconds over the
          window; ``p50_wait`` / ``p99_wait`` — submit→admit;
          ``p50_service`` / ``p99_service`` — admit→done.
        * ``fairness`` — Jain's index over the window's total latencies
          (all tenants pooled); ``tenant_fairness`` — Jain's index over
          *per-tenant mean* latencies (1.0 = tenants see equal means).
        * ``tenants`` — per-tenant sub-dicts (window percentiles +
          lifetime counters): ``completed``, ``shed``, ``deferred``,
          ``p50_latency``, ``p99_latency``, ``p99_wait``, ``mean_latency``,
          ``fairness`` (within-tenant Jain).
        * ``throughput`` — window completions / window span (req/s).
        * ``certified_frac`` — fraction of window results whose Theorem-2
          certificate fired.
        * ``policy`` — the admission policy name;
          ``cost_calibration_error`` — the cost model's EWMA relative
          expansion-prediction error (see
          ``ExpansionCostModel.calibration_error``).
        * ``cache_hits`` — lifetime requests served by the semantic result
          cache (a subset of ``completed``; hits are real completions and
          their — tiny — latencies are in the pooled percentiles);
          ``cache_hit_rate`` — lifetime hits / cache probes;
          ``hit_p50_latency`` / ``hit_p99_latency`` — percentiles over the
          window's *hit* latencies only (probe + revalidation time);
          ``cache`` — the cache's own counters (``SemanticResultCache
          .stats()``), or None when serving uncached.
        * ``writes`` / ``writes_applied`` / ``writes_pending`` — lifetime
          write tickets admitted / applied, and the current write-queue
          depth; ``cache_invalidations`` — lifetime cache entries evicted
          because a write touched their stored frontier.
        * ``signatures`` / ``unplanned_signatures`` — backend compile
          signatures seen / seen after a freeze (recompile audit).
        * ``shards`` — the rescalable backend's current mesh shard count
          (None on a single-host backend); ``scale_events`` — lifetime
          elastic scale events (grow + shrink; the per-event records,
          including migration pause, are in ``scale_events`` the list
          attribute).
        * ``compressed`` / ``bytes_per_vector`` — the backend's corpus
          representation: whether rounds score a quantized corpus, and the
          stored bytes per vector (the memory-scaling stat).
        """
        reqs = list(self.completed)
        lats = [r.latency for r in reqs]
        hit_lats = [r.latency for r in reqs if r.cache_hit]
        waits = [r.wait for r in reqs]
        svcs = [r.service for r in reqs]
        span = (max(r.t_done for r in reqs) - min(r.t_submit for r in reqs)
                if reqs else 0.0)
        by_tenant: dict[str, list[Request]] = {}
        for r in reqs:
            by_tenant.setdefault(r.tenant, []).append(r)
        tenants = {}
        for name in sorted(set(by_tenant) | set(self.tenant_completed)
                           | set(self.tenant_shed)
                           | set(self.tenant_deferred)):
            trs = by_tenant.get(name, [])
            tl = [r.latency for r in trs]
            tenants[name] = dict(
                completed=self.tenant_completed.get(name, 0),
                shed=self.tenant_shed.get(name, 0),
                deferred=self.tenant_deferred.get(name, 0),
                cache_hits=self.tenant_cache_hits.get(name, 0),
                p50_latency=_pctl(tl, 50), p99_latency=_pctl(tl, 99),
                p99_wait=_pctl([r.wait for r in trs], 99),
                mean_latency=float(np.mean(tl)) if tl else 0.0,
                fairness=jain_fairness(tl),
            )
        # cross-tenant fairness over tenants *in the window* only: a tenant
        # whose completions aged out of `history` would otherwise inject a
        # spurious 0.0 mean and report unfairness on an idle tenant
        tenant_means = [t["mean_latency"] for name, t in tenants.items()
                       if by_tenant.get(name)]
        return dict(
            completed=self.total_completed,
            shed=self.total_shed,
            deferred=self.total_deferred,
            pending=len(self.pending),
            inflight=len(self.inflight),
            steps=self.steps,
            p50_latency=_pctl(lats, 50), p99_latency=_pctl(lats, 99),
            p50_wait=_pctl(waits, 50), p99_wait=_pctl(waits, 99),
            p50_service=_pctl(svcs, 50), p99_service=_pctl(svcs, 99),
            fairness=jain_fairness(lats),
            tenant_fairness=jain_fairness(tenant_means),
            tenants=tenants,
            throughput=len(reqs) / span if span > 0 else 0.0,
            certified_frac=(float(np.mean([r.result.stats.certified
                                           for r in reqs])) if reqs else 0.0),
            policy=self.policy.name,
            cost_calibration_error=self.cost_model.calibration_error(),
            cache_hits=self.total_cache_hits,
            cache_hit_rate=(self.total_cache_hits / self.cache.probes
                            if self.cache is not None and self.cache.probes
                            else 0.0),
            hit_p50_latency=_pctl(hit_lats, 50),
            hit_p99_latency=_pctl(hit_lats, 99),
            cache=self.cache.stats() if self.cache is not None else None,
            writes=self.total_writes,
            writes_applied=self.total_writes_applied,
            writes_pending=len(self.write_queue),
            cache_invalidations=self.total_cache_invalidations,
            compressed=self.backend_compressed,
            bytes_per_vector=float(
                getattr(self.backend, "bytes_per_vector", 0.0)),
            signatures=len(self.backend.signature_log),
            unplanned_signatures=len(self.backend.signature_log.unplanned),
            shards=(int(self.backend.num_shards)
                    if isinstance(self.backend, RescalableBackend)
                    else None),
            scale_events=len(self.scale_events),
        )
