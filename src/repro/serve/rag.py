"""RAG serving pipeline: diverse retrieval (the paper) + LM decode.

The paper's motivating application — a retrieval step whose results are
*diverse* under a user-chosen epsilon feeding a generator. This module wires
the two halves of the framework together:

    pipeline = RagPipeline(cfg, params, graph, k=5, eps=0.8)
    texts = pipeline.generate(query_embeds, prompt_tokens, steps=32)

Retrieval defaults to the continuous-batching lane scheduler
(``serve.scheduler.LaneScheduler``): requests are submitted with their own
``(k, eps)``, lanes freed by Theorem-2-certified queries are recycled for
queued requests, and each request's result is bit-identical to a fresh
per-query PSS driver. ``engine="lockstep"`` runs the same engine with
whole-batch admission (PR 1's regime); ``engine="fixed_k"`` keeps the older
static-K hybrid (batched div-A* + per-query PSS repair) for comparison.

Retrieval wiring goes through ``repro.db.DiverseVectorDB`` (pass ``db=``):
the facade owns index/backend/scheduler/cache assembly, adds the write
path (``db.upsert``/``db.delete`` are visible to this pipeline's next
``retrieve``), and serves sharded/quantized corpora through the same
constructor. The pre-facade wirings — ``graph=`` (build a single-host
scheduler here) and ``backend=`` (wrap a hand-built engine) — still work
but are **deprecated shims**: they emit ``DeprecationWarning`` and will be
removed one release after ``DiverseVectorDB`` (results are bit-exact in
the meantime). Multi-tenant
serving rides the same path: ``policy=`` picks the scheduler's admission
policy (``"fifo"`` / ``"drr"`` / ``"slo_cost"`` or a configured
``serve.policies.AdmissionPolicy``) and ``retrieve(..., tenants=...)``
labels each query's tenant, so one pipeline can serve several tenants'
retrieval traffic under cost-fair scheduling (``launch/serve.py
--policy/--tenants``). ``cache_size=`` enables the semantic result cache
(``serve.cache``): repeated or near-duplicate queries are answered from a
certified cached result set after a fresh Theorem-2 recheck against the new
query, without occupying a lane (``launch/serve.py --cache-size``).
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batch import batch_optimal_diverse
from repro.core.batch_progressive import batch_pss
from repro.core.graph import FlatGraph
from repro.core.pss import pss
from repro.models import model as M
from repro.serve.query import Query
from repro.serve.scheduler import (LaneScheduler, RequestDeferred,
                                   RequestShed, SchedulerSaturated)


@dataclasses.dataclass
class RagPipeline:
    cfg: ModelConfig
    params: dict
    graph: FlatGraph | None = None   # deprecated shim — pass db= instead
    k: int = 5
    eps: float = 0.8
    K_budget: int = 64
    ef: int = 8
    engine: str = "scheduler"   # "scheduler" | "lockstep" | "fixed_k"
    num_lanes: int = 8
    prewarm: bool = False
    backend: object | None = None   # deprecated shim — pass db= instead
    policy: object = "fifo"     # admission policy name or AdmissionPolicy
    cache_size: int = 0         # semantic result cache capacity (0 = off)
    cost_model: object | None = None   # warm ExpansionCostModel (else fresh)
    db: object | None = None    # repro.db.DiverseVectorDB — the front door
    _scheduler: LaneScheduler | None = dataclasses.field(
        default=None, repr=False)

    @property
    def scheduler(self) -> LaneScheduler:
        """The pipeline's lane scheduler (the ``db``'s when one was given;
        otherwise built lazily through a deprecated wiring shim, reused
        across calls so the backend's compile cache, lane state, and the
        admission policy's cost model persist)."""
        if self.db is not None:
            return self.db.scheduler
        if self._scheduler is None:
            if self.backend is not None:
                warnings.warn(
                    "RagPipeline(backend=...) is a deprecated wiring shim — "
                    "construct a repro.db.DiverseVectorDB and pass db=; the "
                    "shim is removed one release after DiverseVectorDB "
                    "(results are bit-exact either way)",
                    DeprecationWarning, stacklevel=3)
                self._scheduler = LaneScheduler(
                    backend=self.backend, prewarm=self.prewarm,
                    policy=self.policy, cache_size=self.cache_size,
                    cost_model=self.cost_model)
            else:
                warnings.warn(
                    "RagPipeline(graph=...) is a deprecated wiring shim — "
                    "construct repro.db.DiverseVectorDB(index=graph, ...) "
                    "and pass db=; the shim is removed one release after "
                    "DiverseVectorDB (results are bit-exact either way)",
                    DeprecationWarning, stacklevel=3)
                self._scheduler = LaneScheduler(
                    self.graph, num_lanes=self.num_lanes,
                    max_k=max(self.k, 16), default_ef=self.ef,
                    prewarm=self.prewarm, policy=self.policy,
                    cache_size=self.cache_size,
                    cost_model=self.cost_model)
        return self._scheduler

    def _graph(self) -> FlatGraph:
        if self.graph is not None:
            return self.graph
        if self.db is not None and self.db.index.graph is not None:
            return self.db.index.graph
        raise ValueError("this engine mode needs a single-host graph "
                         "(pass graph= or a single-host db=)")

    def _retrieve_queries(self, queries: list[Query]
                          ) -> tuple[np.ndarray, np.ndarray]:
        """Serve a closed batch of ``Query`` objects through the scheduler
        (the ``Query``-native path ``retrieve`` dispatches to)."""
        sched = self.scheduler
        embed = self.db.embed if self.db is not None else None
        reqs = []
        for q in queries:
            q = q.resolve(embed)
            while True:
                try:
                    reqs.append(sched.submit(q))
                    break
                except RequestShed:
                    reqs.append(None)
                    break
                except (SchedulerSaturated, RequestDeferred):
                    sched.pump()
        sched.drain()
        k_max = max(int(q.k) for q in queries)
        ids = np.full((len(queries), k_max), -1, np.int32)
        cert = np.zeros(len(queries), bool)
        for i, r in enumerate(reqs):
            if r is None or r.result is None:
                continue
            ids[i, :r.result.ids.shape[0]] = r.result.ids
            cert[i] = r.result.stats.certified
        return ids, cert

    def retrieve(self, query_embeds, ks=None, epss=None, tenants=None
                 ) -> tuple[np.ndarray, np.ndarray]:
        """Diverse document ids per query + per-lane certificate flags.

        ``query_embeds`` is an ``[m, d]`` embedding batch — or a list of
        ``serve.query.Query`` objects, each carrying its own
        ``k``/``eps``/``tenant``/``slo`` (``ks``/``epss``/``tenants`` must
        then be omitted). With raw embeddings, ``ks``/``epss`` optionally
        override the pipeline defaults per request and ``tenants`` labels
        each request's tenant for the admission policy and per-tenant
        stats (scheduler engine only) — the paper's query-owned
        diversification level, end to end, now with per-tenant fair
        scheduling on top. A request shed by the policy yields an all
        ``-1`` id row with ``certified=False``.
        """
        if (isinstance(query_embeds, (list, tuple)) and query_embeds
                and all(isinstance(q, Query) for q in query_embeds)):
            if ks is not None or epss is not None or tenants is not None:
                raise ValueError("per-Query parameters are set on each "
                                 "Query, not as retrieve() overrides")
            if self.engine != "scheduler":
                raise ValueError("Query batches are served by the "
                                 "scheduler engine only")
            return self._retrieve_queries(list(query_embeds))
        qs = jnp.asarray(query_embeds, jnp.float32)
        if self.engine == "scheduler":
            results = self.scheduler.run(
                np.asarray(qs), ks if ks is not None else self.k,
                epss if epss is not None else self.eps, efs=self.ef,
                tenants=tenants)
            k_max = int(np.max(np.broadcast_to(
                np.asarray(ks if ks is not None else self.k),
                (qs.shape[0],))))
            ids = np.full((qs.shape[0], k_max), -1, np.int32)
            cert = np.zeros(qs.shape[0], bool)
            for i, r in enumerate(results):
                if r is None:   # shed by the admission policy
                    continue
                ids[i, :r.ids.shape[0]] = r.ids
                cert[i] = r.stats.certified
            return ids, cert
        if self.engine in ("lockstep", "progressive"):   # PR 1 name kept
            res = batch_pss(self._graph(), qs, self.k, self.eps, ef=self.ef)
            return res.ids.copy(), res.stats.certified.copy()
        # legacy hybrid: static-K batched div-A* + per-query PSS repair
        ids, scores, total, certified = batch_optimal_diverse(
            self._graph(), qs, self.k, self.eps, self.K_budget, self.ef)
        ids = np.array(ids)  # writable copy for PSS repair
        cert = np.asarray(certified)
        for i in np.flatnonzero(~cert):
            res = pss(self._graph(), np.asarray(qs[i]), self.k, self.eps,
                      ef=self.ef * 4)
            ids[i] = res.ids
        return ids, cert

    def generate(self, query_embeds, prompt_tokens, steps: int = 16,
                 max_seq: int | None = None, tenants=None):
        """Retrieve diverse context, prepend retrieved ids as context tokens
        (toy fusion — document tokens would be spliced here), decode.
        ``tenants`` flows through to ``retrieve`` (per-tenant scheduling)."""
        ids, cert = self.retrieve(query_embeds, tenants=tenants)
        b, p = prompt_tokens.shape
        max_seq = max_seq or (p + steps + self.k)
        ctx = jnp.asarray(ids % self.cfg.vocab_size, jnp.int32)
        toks = jnp.concatenate([ctx, jnp.asarray(prompt_tokens)], axis=1)
        cache = M.init_cache(self.cfg, b, max_seq)
        # teacher-forced prefill via repeated decode (keeps one code path)
        out = []
        step_fn = jax.jit(lambda pr, c, t: M.decode_step(self.cfg, pr, c, t))
        for t in range(toks.shape[1]):
            logits, cache = step_fn(self.params, cache, toks[:, t:t + 1])
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for _ in range(steps):
            out.append(tok)
            logits, cache = step_fn(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return np.concatenate([np.asarray(t) for t in out], axis=1), ids, cert
