"""RAG serving pipeline: diverse retrieval (the paper) + LM decode.

The paper's motivating application — a retrieval step whose results are
*diverse* under a user-chosen epsilon feeding a generator. This module wires
the two halves of the framework together:

    pipeline = RagPipeline(cfg, params, graph, k=5, eps=0.8)
    texts = pipeline.generate(query_embeds, prompt_tokens, steps=32)

Retrieval defaults to the batched progressive engine
(``core.batch_progressive``): the whole request batch runs the paper's
pause/inspect/resume loop in lockstep device bursts, each lane growing its
own candidate set until its Theorem-2 certificate fires — no per-query
repair loop needed. ``engine="fixed_k"`` keeps the previous hybrid (static-K
batched div-A* + per-query PSS repair of uncertified lanes) for comparison.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batch import batch_optimal_diverse
from repro.core.batch_progressive import batch_pss
from repro.core.graph import FlatGraph
from repro.core.pss import pss
from repro.models import model as M


@dataclasses.dataclass
class RagPipeline:
    cfg: ModelConfig
    params: dict
    graph: FlatGraph
    k: int = 5
    eps: float = 0.8
    K_budget: int = 64
    ef: int = 8
    engine: str = "progressive"   # "progressive" | "fixed_k"

    def retrieve(self, query_embeds) -> tuple[np.ndarray, np.ndarray]:
        """Diverse document ids per query + per-lane certificate flags."""
        qs = jnp.asarray(query_embeds, jnp.float32)
        if self.engine == "progressive":
            res = batch_pss(self.graph, qs, self.k, self.eps, ef=self.ef)
            return res.ids.copy(), res.stats.certified.copy()
        # legacy hybrid: static-K batched div-A* + per-query PSS repair
        ids, scores, total, certified = batch_optimal_diverse(
            self.graph, qs, self.k, self.eps, self.K_budget, self.ef)
        ids = np.array(ids)  # writable copy for PSS repair
        cert = np.asarray(certified)
        for i in np.flatnonzero(~cert):
            res = pss(self.graph, np.asarray(qs[i]), self.k, self.eps,
                      ef=self.ef * 4)
            ids[i] = res.ids
        return ids, cert

    def generate(self, query_embeds, prompt_tokens, steps: int = 16,
                 max_seq: int | None = None):
        """Retrieve diverse context, prepend retrieved ids as context tokens
        (toy fusion — document tokens would be spliced here), decode."""
        ids, cert = self.retrieve(query_embeds)
        b, p = prompt_tokens.shape
        max_seq = max_seq or (p + steps + self.k)
        ctx = jnp.asarray(ids % self.cfg.vocab_size, jnp.int32)
        toks = jnp.concatenate([ctx, jnp.asarray(prompt_tokens)], axis=1)
        cache = M.init_cache(self.cfg, b, max_seq)
        # teacher-forced prefill via repeated decode (keeps one code path)
        out = []
        step_fn = jax.jit(lambda pr, c, t: M.decode_step(self.cfg, pr, c, t))
        for t in range(toks.shape[1]):
            logits, cache = step_fn(self.params, cache, toks[:, t:t + 1])
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for _ in range(steps):
            out.append(tok)
            logits, cache = step_fn(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return np.concatenate([np.asarray(t) for t in out], axis=1), ids, cert
