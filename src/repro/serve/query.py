"""The one per-request parameter object: a frozen ``Query``.

Before this module, per-request knobs drifted across three kwarg lists
(``LaneScheduler.submit``, ``RagPipeline.retrieve``, ``launch/serve.py``),
and every new knob (tenant, SLO, method) had to be threaded through each.
``Query`` consolidates them: one frozen dataclass carried from the public
``DiverseVectorDB.search`` front door down to the scheduler's admission
queue. The backend-facing ``core.backend.LaneRequest`` and the scheduler's
``Request`` stay *internal* — callers construct ``Query``, never those.

``text_or_embedding`` is either the query embedding (anything
``np.asarray`` accepts) or raw text; text is resolved by the owner of an
embedder (``DiverseVectorDB(embed=...)``) — layers without one refuse it
rather than guess.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True, eq=False)
class Query:
    """One diverse-search request (the paper's Definition 1: the query owns
    its diversification level — ``k``/``eps`` ride the request, never an
    index rebuild).

    * ``text_or_embedding`` — query embedding, or raw text for callers
      constructed with an embedder.
    * ``k`` / ``eps`` — result size and the diversity threshold.
    * ``method`` — backend search method (``None`` = the backend's native
      default, e.g. ``"pss"`` single-host / ``"sharded"`` on a mesh).
    * ``tenant`` — fairness/accounting label for the admission policies.
    * ``slo`` — optional latency budget in seconds; admission policies and
      shed callbacks may read it (``None`` = best effort).
    * ``ef`` / ``max_K`` — optional expansion-factor and candidate-budget
      overrides (backend defaults when ``None``).
    """
    text_or_embedding: Any
    k: int = 10
    eps: float = 0.0
    method: str | None = None
    tenant: str = "default"
    slo: float | None = None
    ef: int | None = None
    max_K: int | None = None

    @property
    def is_text(self) -> bool:
        return isinstance(self.text_or_embedding, str)

    def embedding(self, embed=None) -> np.ndarray:
        """The query as a float32 embedding vector.

        Text queries need ``embed`` (a ``str -> vector`` callable); an
        embedding passes through unchanged. Raises ``TypeError`` for text
        without an embedder — resolving text is the *caller's* capability,
        not something lower layers guess at.
        """
        if self.is_text:
            if embed is None:
                raise TypeError(
                    "text query needs an embedder — construct "
                    "DiverseVectorDB(embed=...) or pass an embedding")
            return np.asarray(embed(self.text_or_embedding), np.float32)
        return np.asarray(self.text_or_embedding, np.float32)

    def resolve(self, embed=None) -> "Query":
        """A copy whose ``text_or_embedding`` is the resolved embedding."""
        if not self.is_text:
            return self
        return dataclasses.replace(
            self, text_or_embedding=self.embedding(embed))
