"""Cost-aware admission policies for the lane scheduler.

The paper's progressive framework makes per-request cost structurally
skewed: latency grows sharply with ``k`` and with the diversification level
``eps`` (denser G^eps graphs expand far more candidates per round before
Theorem 2 certifies). A FIFO queue therefore lets one tenant's heavy-eps
traffic starve everyone else, and a boolean shed callback can only drop
load, not schedule it. This module is the scheduling-policy layer that
replaces both with decisions driven by *measured search cost*:

* ``ExpansionCostModel`` — an online per-``(k, eps, method)`` cost model
  learning expansions-per-round and rounds-to-finish from the real
  ``SearchStats`` counters every harvested result carries (EWMA per bucket;
  ``k`` is power-of-two bucketed, ``eps`` is banded). Cold buckets fall
  back to a static Theorem-1 prior, so estimates exist before any traffic.
  A global seconds-per-expansion EWMA converts predicted expansions into
  predicted service time once at least one request has been timed.
* ``FifoPolicy`` — the scheduler's historical behavior, bit-exactly: the
  admission queue is served in submission order and nothing is ever shed
  or deferred by the policy (the legacy ``shed`` callback still applies).
* ``DrrPolicy`` — deficit round-robin over the per-request ``tenant``
  field, with the deficit charged in *predicted expansions* rather than
  request count: tenants get equal shares of search work, so a tenant
  flooding cheap requests cannot starve a sparse tenant's occasional
  heavy-eps request.
* ``SloCostPolicy`` — admission control from predicted service time vs a
  per-tenant SLO budget: requests that cannot meet their budget even on an
  idle system are shed outright, requests that merely face too much
  backlog are deferred (the caller may retry once load drains), and the
  queue is drained earliest-deadline-first.

Determinism contract (pinned by ``tests/test_policies.py``): every policy
decision is a pure function of the submit/harvest sequence, the scheduler's
injectable clock, and the cost model's state. With a fixed request trace
and a deterministic clock, the admission order is reproducible run-to-run
and — with a frozen cost model — identical over any ``LaneBackend``
(admission order is scheduler-level state; per-request *results* never
depend on it, by the backends' lane-separability contracts).
"""
from __future__ import annotations

import collections
import json
import math

from repro.core.bucketing import next_pow2

#: decisions a policy may return from ``on_submit``
ADMIT, SHED, DEFER = "admit", "shed", "defer"


def theorem1_prior(k: int, K0: int = 32, prior_degree: float = 3.0,
                   round_cost: float = 4.0) -> tuple[float, float]:
    """Static cold-start prior ``(expansions_per_round, rounds)``.

    Theorem 1 bounds the sufficient candidate count by the degrees of
    G^eps: K >= sum over the k-1 highest-degree candidates of (phi_v + 1),
    plus one. With an assumed mean G^eps degree ``prior_degree`` that gives
    a prior final budget ``K ~= (k - 1) * (prior_degree + 1) + 1``; the
    progressive ladder doubles from ``K0``, so the prior round count is the
    number of doublings to reach it, and the prior per-round expansion cost
    is ``round_cost`` beam steps per candidate. The prior is deliberately
    coarse — its only job is to give cold buckets a finite, k-monotone
    estimate so policies can order requests before any traffic; the
    eps-specific cost is learned, not assumed (eps scales are
    metric-dependent and not comparable across corpora).
    """
    K_prior = max((k - 1) * (prior_degree + 1.0) + 1.0, float(K0))
    rounds = 1.0 + max(0.0, math.ceil(math.log2(K_prior / K0)))
    return round_cost * K_prior, rounds


class ExpansionCostModel:
    """Online per-``(k, eps, method)`` cost model over harvested SearchStats.

    Buckets are ``(next_pow2(k), eps_band, method)``: power-of-two ``k``
    bucketing mirrors the engines' own budget ladders (requests sharing a
    pow2 rung share compile signatures *and* cost character), and ``eps``
    banding defaults to the exact (rounded) eps value — serving workloads
    use a handful of calibrated diversification levels, so each level gets
    its own band; pass ``eps_bands`` (sorted band edges) to coarsen.

    Per bucket the model keeps EWMAs of expansions-per-round and
    rounds-to-finish (updated from ``SearchStats.expansions`` /
    ``search_calls`` — the *real* counters the backends report); a global
    EWMA of seconds-per-expansion turns predicted expansions into predicted
    service seconds. Cold buckets fall back to :func:`theorem1_prior`, so
    ``predict_expansions`` is total before the first observation; predicted
    *service* is 0.0 until one timed request has been observed (no
    defensible static prior exists for wall-clock cost).

    ``freeze()`` stops all updates — deploy a calibrated model read-only,
    or pin cross-backend admission-order parity in tests.
    """

    def __init__(self, *, K0: int = 32, prior_degree: float = 3.0,
                 prior_round_cost: float = 4.0, alpha: float = 0.25,
                 eps_bands: tuple = (), max_buckets: int = 4096):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha={alpha} outside (0, 1]")
        self.K0 = int(K0)
        self.prior_degree = float(prior_degree)
        self.prior_round_cost = float(prior_round_cost)
        self.alpha = float(alpha)
        self.eps_bands = tuple(float(e) for e in eps_bands)
        self.max_buckets = int(max_buckets)
        #: bucket -> [ewma_expansions_per_round, ewma_rounds, count]
        self._buckets: dict[tuple, list] = {}
        #: bucket -> [ewma_hit_probability, count] — learned from the
        #: semantic result cache's probe outcomes (observe_cache)
        self._hit: dict[tuple, list] = {}
        self._sec_per_exp = 0.0
        self._sec_obs = 0
        self._calib_err = 0.0
        self._calib_obs = 0
        self.frozen = False

    # -- bucketing -----------------------------------------------------------
    def _eps_band(self, eps: float):
        if self.eps_bands:
            lo = 0
            for i, edge in enumerate(self.eps_bands):
                if eps >= edge:
                    lo = i + 1
            return lo
        return round(float(eps), 6)

    def bucket(self, k: int, eps: float, method: str,
               compressed: bool = False) -> tuple:
        """The model's bucket key for a request shape.

        ``compressed`` marks requests served against a quantized corpus
        (``LaneBackend.compressed``): quantized rounds score int8/PQ codes
        and pay an exact-rerank stage, so their expansions-per-second and
        round counts are not exchangeable with float traffic — pricing them
        in the same bucket would mis-bill both tenants. Cold compressed
        buckets still fall back to :func:`theorem1_prior` (Theorem 1 bounds
        the *candidate count*, which quantization does not change — contract
        13: quantization is a memory knob, never a certificate knob).
        """
        return (next_pow2(max(int(k), 1)), self._eps_band(eps), str(method),
                bool(compressed))

    # -- prediction ----------------------------------------------------------
    def predict_rounds(self, k: int, eps: float, method: str,
                       compressed: bool = False) -> float:
        cell = self._buckets.get(self.bucket(k, eps, method, compressed))
        if cell is not None:
            return cell[1]
        return theorem1_prior(int(k), self.K0, self.prior_degree,
                              self.prior_round_cost)[1]

    def predict_expansions(self, k: int, eps: float, method: str,
                           compressed: bool = False, *,
                           offered: bool = False) -> float:
        """Predicted total expansions for one request of this shape.

        ``offered=True`` prices an *offered* request rather than an
        admitted one: the prediction is discounted by the bucket's learned
        cache-hit probability (a hit costs the system no expansions), so a
        tenant whose traffic the semantic cache absorbs is billed only for
        the work its stream actually induces. With no cache observations
        the hit rate is 0.0 and both modes agree exactly.
        """
        cell = self._buckets.get(self.bucket(k, eps, method, compressed))
        if cell is not None:
            exp = max(cell[0] * cell[1], 1.0)
        else:
            epr, rounds = theorem1_prior(int(k), self.K0, self.prior_degree,
                                         self.prior_round_cost)
            exp = max(epr * rounds, 1.0)
        if offered:
            exp *= 1.0 - self.predict_hit_rate(k, eps, method, compressed)
        return exp

    def predict_hit_rate(self, k: int, eps: float, method: str,
                         compressed: bool = False) -> float:
        """Learned semantic-cache hit probability for this bucket (EWMA of
        probe outcomes; 0.0 until the first ``observe_cache``)."""
        cell = self._hit.get(self.bucket(k, eps, method, compressed))
        return cell[0] if cell is not None else 0.0

    @property
    def sec_per_expansion(self) -> float:
        """Learned seconds per expansion (0.0 before any timed request)."""
        return self._sec_per_exp

    def predict_service(self, k: int, eps: float, method: str,
                        compressed: bool = False, *,
                        offered: bool = False) -> float:
        """Predicted service seconds; 0.0 until a timed request was seen.
        ``offered=True`` applies the cache-hit discount (see
        ``predict_expansions``)."""
        return (self.predict_expansions(k, eps, method, compressed,
                                        offered=offered)
                * self._sec_per_exp)

    # -- updates -------------------------------------------------------------
    def observe(self, k: int, eps: float, method: str, *,
                expansions: int, rounds: int,
                service: float | None = None,
                compressed: bool = False) -> None:
        """Fold one harvested request into the model.

        ``expansions``/``rounds`` are the result's real ``SearchStats``
        counters (``expansions`` / ``search_calls``); ``service`` is the
        measured admit-to-done wall time (optional — untimed observations
        still update the expansion EWMAs). The pre-update prediction error
        feeds the calibration EWMA, so ``calibration_error()`` reflects how
        well the model *would have* predicted each request before seeing it.
        No-op when frozen.
        """
        if self.frozen:
            return
        actual = float(max(int(expansions), 1))
        rel_err = abs(self.predict_expansions(k, eps, method, compressed)
                      - actual) / actual
        self._calib_obs += 1
        a = self.alpha if self._calib_obs > 1 else 1.0
        self._calib_err += a * (rel_err - self._calib_err)
        r = float(max(int(rounds), 1))
        epr = actual / r
        key = self.bucket(k, eps, method, compressed)
        cell = self._buckets.get(key)
        if cell is None:
            # bounded model state for long-running servers: past the cap,
            # stop adding bands (existing buckets and the prior still
            # serve) — but the global time-rate EWMA below must keep
            # tracking drift regardless
            if len(self._buckets) < self.max_buckets:
                self._buckets[key] = [epr, r, 1]
        else:
            cell[0] += self.alpha * (epr - cell[0])
            cell[1] += self.alpha * (r - cell[1])
            cell[2] += 1
        if service is not None and service > 0:
            self._sec_obs += 1
            a = self.alpha if self._sec_obs > 1 else 1.0
            self._sec_per_exp += a * (service / actual - self._sec_per_exp)

    def observe_cache(self, k: int, eps: float, method: str, *,
                      hit: bool, compressed: bool = False) -> None:
        """Fold one semantic-cache probe outcome into the bucket's hit
        probability EWMA (the scheduler calls this on every probed submit,
        hit or miss). No-op when frozen."""
        if self.frozen:
            return
        key = self.bucket(k, eps, method, compressed)
        cell = self._hit.get(key)
        x = 1.0 if hit else 0.0
        if cell is None:
            if (len(self._buckets) + len(self._hit)) < 2 * self.max_buckets:
                self._hit[key] = [x, 1]
        else:
            cell[0] += self.alpha * (x - cell[0])
            cell[1] += 1

    def freeze(self) -> "ExpansionCostModel":
        """Stop updating (predictions keep working); returns self."""
        self.frozen = True
        return self

    # -- persistence ---------------------------------------------------------
    _STATE_VERSION = 1

    def save(self, path) -> None:
        """Write the model's full state as JSON — config, every bucket EWMA
        (cost and cache-hit), the time-rate and calibration EWMAs, and the
        frozen flag — so a restarted server resumes with a warm model
        (``load`` round-trips it exactly; bucket keys serialize as
        ``[k_pow2, eps_band, method, compressed]`` lists)."""
        doc = dict(
            version=self._STATE_VERSION,
            K0=self.K0, prior_degree=self.prior_degree,
            prior_round_cost=self.prior_round_cost, alpha=self.alpha,
            eps_bands=list(self.eps_bands), max_buckets=self.max_buckets,
            buckets=[[list(k), list(v)] for k, v in self._buckets.items()],
            hit_buckets=[[list(k), list(v)] for k, v in self._hit.items()],
            sec_per_exp=self._sec_per_exp, sec_obs=self._sec_obs,
            calib_err=self._calib_err, calib_obs=self._calib_obs,
            frozen=self.frozen,
        )
        with open(path, "w") as f:
            json.dump(doc, f)

    @classmethod
    def load(cls, path) -> "ExpansionCostModel":
        """Reconstruct a model from ``save`` output, bit-exactly (floats
        round-trip through JSON's shortest-repr encoding)."""
        with open(path) as f:
            doc = json.load(f)
        if doc.get("version") != cls._STATE_VERSION:
            raise ValueError(
                f"cost-model state version {doc.get('version')!r} != "
                f"{cls._STATE_VERSION} (refusing a half-compatible load)")
        m = cls(K0=doc["K0"], prior_degree=doc["prior_degree"],
                prior_round_cost=doc["prior_round_cost"],
                alpha=doc["alpha"], eps_bands=tuple(doc["eps_bands"]),
                max_buckets=doc["max_buckets"])
        m._buckets = {tuple(k): list(v) for k, v in doc["buckets"]}
        m._hit = {tuple(k): list(v) for k, v in doc["hit_buckets"]}
        m._sec_per_exp = doc["sec_per_exp"]
        m._sec_obs = doc["sec_obs"]
        m._calib_err = doc["calib_err"]
        m._calib_obs = doc["calib_obs"]
        m.frozen = doc["frozen"]
        return m

    # -- reporting -----------------------------------------------------------
    def calibration_error(self) -> float:
        """EWMA of the relative |predicted - actual| expansion error,
        each prediction taken *before* its observation was folded in
        (0.0 until the first observation)."""
        return self._calib_err if self._calib_obs else 0.0

    def stats(self) -> dict:
        """Model summary: bucket count, observation count, calibration."""
        return dict(
            buckets=len(self._buckets),
            observations=sum(c[2] for c in self._buckets.values()),
            hit_buckets=len(self._hit),
            cache_observations=sum(c[1] for c in self._hit.values()),
            calibration_error=self.calibration_error(),
            sec_per_expansion=self._sec_per_exp,
            frozen=self.frozen,
        )


class AdmissionPolicy:
    """Base class for pluggable admission policies.

    A policy is bound to exactly one ``LaneScheduler`` (``bind``); the
    scheduler consults it at two points:

    * ``on_submit(req)`` — returns ``ADMIT`` (enqueue), ``SHED`` (drop,
      never retry) or ``DEFER`` (drop, caller may retry once load drains).
      Runs *after* the legacy ``shed`` callback, which stays supported.
    * ``pop_next()`` — called once per free lane per pump: remove and
      return the next pending request to admit (or None to leave lanes
      idle). The scheduler's ``pending`` deque is the source of truth; a
      policy that keeps its own structures must keep them consistent via
      ``note_enqueued``.

    Subclasses must be deterministic given the submit/pop/complete sequence
    and the scheduler clock (see the module docstring).
    """

    name = "base"

    def __init__(self):
        self.sched = None

    def bind(self, sched) -> "AdmissionPolicy":
        if self.sched is not None and self.sched is not sched:
            raise RuntimeError(
                f"policy {self.name!r} is already bound to another "
                "scheduler; policies hold per-scheduler queue state")
        self.sched = sched
        return self

    @property
    def model(self) -> ExpansionCostModel:
        return self.sched.cost_model

    @property
    def compressed(self) -> bool:
        """Whether the bound scheduler's backend scores a quantized corpus
        (``LaneBackend.compressed``) — forwarded into every cost-model
        lookup so quantized traffic is priced in its own buckets."""
        return bool(getattr(self.sched, "backend_compressed", False))

    def on_submit(self, req) -> str:
        return ADMIT

    def note_enqueued(self, req) -> None:
        """Called after the scheduler appended an admitted ``req`` to its
        pending deque."""

    def pop_next(self):
        """Remove and return the next request to admit, or None."""
        raise NotImplementedError

    def on_complete(self, req) -> None:
        """Called after a request finished (the scheduler has already fed
        the cost model); policies rarely need it."""


class FifoPolicy(AdmissionPolicy):
    """Submission-order admission — the scheduler's historical behavior,
    bit-exactly (``tests/test_policies.py::test_fifo_admission_order_is_
    submission_order`` pins admission order; the PR 2/3 parity suites pin
    results)."""

    name = "fifo"

    def pop_next(self):
        sched = self.sched
        return sched.pending.popleft() if sched.pending else None


class DrrPolicy(AdmissionPolicy):
    """Deficit round-robin over tenants, charged in predicted expansions.

    Classic DRR (Shreedhar & Varghese) with the packet length replaced by
    the cost model's predicted expansion count for the head request: each
    active tenant holds a deficit counter; a visit adds ``quantum``
    (expansions) and serves the tenant's queue head while the deficit
    covers its predicted cost; an emptied tenant leaves the active list
    and forfeits its deficit (no banking). Equal *work* shares mean a
    tenant flooding cheap low-eps requests cannot starve another tenant's
    sparse heavy-eps traffic — the failure mode FIFO has on exactly the
    skewed mixes the paper's cost asymmetry produces.

    ``quantum`` trades fairness granularity against scheduling overhead
    (any positive value is work-conserving; smaller values interleave
    tenants at finer expansion granularity). ``quanta`` overrides the
    quantum per tenant — classic weighted DRR: a tenant with twice the
    quantum earns deficit twice as fast and receives twice the share of
    served search work under contention (tenants not listed keep the
    uniform default).

    Head costs are priced at the *offered* rate
    (``predict_expansions(..., offered=True)``): once the semantic result
    cache has absorbed part of a tenant's stream, that tenant's remaining
    misses are billed net of the hit probability, so its fair share is of
    offered traffic, not of cache-miss traffic — the cache's savings are
    not charged to the tenant that earned them. With no cache (or no
    observations yet) the discount is exactly zero and the pre-cache
    admission order is reproduced bit-for-bit.
    """

    name = "drr"

    def __init__(self, quantum: float = 256.0,
                 quanta: dict | None = None):
        super().__init__()
        if quantum <= 0:
            raise ValueError(f"quantum={quantum} must be positive")
        self.quantum = float(quantum)
        self.quanta = {str(t): float(q) for t, q in (quanta or {}).items()}
        for t, q in self.quanta.items():
            if q <= 0:
                raise ValueError(f"quanta[{t!r}]={q} must be positive")
        self._queues: dict[str, collections.deque] = {}
        self._active: list[str] = []
        self._deficit: dict[str, float] = {}
        self._ptr = 0
        self._fresh_visit = True

    def quantum_for(self, tenant: str) -> float:
        return self.quanta.get(tenant, self.quantum)

    def note_enqueued(self, req) -> None:
        q = self._queues.setdefault(req.tenant, collections.deque())
        if not q and req.tenant not in self._active:
            self._active.append(req.tenant)
            self._deficit[req.tenant] = 0.0
        q.append(req)

    def _deactivate(self, tenant: str) -> None:
        # an emptied tenant forfeits its deficit AND its dict entries —
        # policy state stays proportional to tenants with queued work, not
        # to every label ever seen (high-cardinality tenants must not leak)
        i = self._active.index(tenant)
        del self._active[i]
        del self._deficit[tenant]
        del self._queues[tenant]
        if self._active:
            if i < self._ptr:
                self._ptr -= 1
            self._ptr %= len(self._active)
        else:
            self._ptr = 0
        self._fresh_visit = True

    def pop_next(self):
        sched = self.sched
        if not sched.pending:
            return None
        # terminates: every full cycle adds `quantum` to each surviving
        # tenant's deficit, so some head cost is covered after at most
        # ceil(max_cost / quantum) cycles (quantum > 0 by construction)
        while True:
            if not self._active:
                # defensive (note_enqueued tracks every append, so pending
                # and the tenant queues can only disagree if a caller
                # mutated `pending` directly): drain work-conserving FIFO
                # rather than idle a lane forever
                return sched.pending.popleft()
            tenant = self._active[self._ptr]
            queue = self._queues[tenant]
            if not queue:
                self._deactivate(tenant)
                continue
            if self._fresh_visit:
                self._deficit[tenant] += self.quantum_for(tenant)
                self._fresh_visit = False
            head = queue[0]
            cost = self.model.predict_expansions(head.k, head.eps,
                                                 head.method,
                                                 self.compressed,
                                                 offered=True)
            if cost <= self._deficit[tenant]:
                queue.popleft()
                self._deficit[tenant] -= cost
                sched.pending.remove(head)
                if not queue:
                    self._deactivate(tenant)
                # else: stay on this tenant — its deficit may cover more
                return head
            self._ptr = (self._ptr + 1) % len(self._active)
            self._fresh_visit = True


class SloCostPolicy(AdmissionPolicy):
    """Shed / defer / order admission from predicted service vs SLO budget.

    Each tenant has a latency budget (``budgets`` overrides ``budget``; a
    ``None`` budget means best-effort: never shed or deferred by this
    policy, drained after all budgeted traffic). At submit:

    * predicted service alone exceeds the budget -> ``SHED`` — the request
      cannot meet its SLO even on an idle system, so retrying is pointless
      (this subsumes the legacy boolean ``shed`` callback, which remains
      supported and runs first).
    * predicted queue wait + service exceeds the budget -> ``DEFER`` — the
      request *would* fit on a drained system; the caller may retry later
      (``defer=False`` converts these to sheds).

    The queue drains earliest-deadline-first (deadline = submit time +
    budget; ties and best-effort traffic fall back to submission order),
    so tight-budget requests jump the queue instead of missing their SLO
    behind lax ones.

    Until the cost model has timed one request, predicted service is 0.0
    and everything admits — cold-start admission errs open by design (the
    scheduler's prewarm/warmup traffic calibrates seconds-per-expansion
    before real load arrives).

    Cache pricing note: unlike ``drr`` (which bills *offered* traffic and
    so discounts by the learned cache-hit probability), this policy prices
    at the admitted rate deliberately — a request consulted here has
    *already missed* the semantic cache (the scheduler probes before the
    policy), so its service cost is the full one, and every queued or
    in-flight request in the backlog estimate is likewise a miss.
    Discounting would admit requests that then blow their SLO.
    """

    name = "slo_cost"

    def __init__(self, budget: float | None = None,
                 budgets: dict | None = None, *, defer: bool = True,
                 headroom: float = 1.0):
        super().__init__()
        self.budget = budget
        self.budgets = dict(budgets or {})
        self.defer = defer
        if headroom <= 0:
            raise ValueError(f"headroom={headroom} must be positive")
        self.headroom = float(headroom)

    def budget_for(self, tenant: str) -> float | None:
        return self.budgets.get(tenant, self.budget)

    def _predicted_wait(self) -> float:
        """Expected queue wait: backlog (pending + in-flight) in predicted
        expansions, spread over the lanes, at the learned time rate."""
        model = self.model
        if model.sec_per_expansion <= 0:
            return 0.0
        compressed = self.compressed
        backlog = sum(model.predict_expansions(r.k, r.eps, r.method,
                                               compressed)
                      for r in self.sched.pending)
        backlog += sum(model.predict_expansions(r.k, r.eps, r.method,
                                                compressed)
                       for r in self.sched.inflight.values())
        return backlog * model.sec_per_expansion / self.sched.num_lanes

    def on_submit(self, req) -> str:
        budget = self.budget_for(req.tenant)
        if budget is None:
            return ADMIT
        budget *= self.headroom
        service = self.model.predict_service(req.k, req.eps, req.method,
                                             self.compressed)
        if service > budget:
            return SHED
        if self._predicted_wait() + service > budget:
            return DEFER if self.defer else SHED
        return ADMIT

    def _deadline(self, req) -> tuple:
        budget = self.budget_for(req.tenant)
        deadline = math.inf if budget is None else req.t_submit + budget
        return (deadline, req.rid)   # rid tiebreak = submission order

    def pop_next(self):
        sched = self.sched
        if not sched.pending:
            return None
        req = min(sched.pending, key=self._deadline)
        sched.pending.remove(req)
        return req


_POLICIES = {p.name: p for p in (FifoPolicy, DrrPolicy, SloCostPolicy)}


def make_policy(policy) -> AdmissionPolicy:
    """Resolve a policy spec: an ``AdmissionPolicy`` instance passes
    through; a name (``"fifo"`` / ``"drr"`` / ``"slo_cost"``) constructs
    that policy with defaults."""
    if isinstance(policy, AdmissionPolicy):
        return policy
    try:
        return _POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown admission policy {policy!r} "
            f"(known: {sorted(_POLICIES)}, or pass an AdmissionPolicy)"
        ) from None
