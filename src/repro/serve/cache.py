"""Semantic result cache with Theorem-2 certificate revalidation.

Production traffic is heavily duplicated: near-identical queries arrive
seconds apart, and each one pays a full progressive search even though a
certified diverse set for "the same" query was just computed. This module
caches certified result sets keyed by query embedding and serves a
*revalidated* copy on a near-hit — the latency lever the scheduler pulls
before a request ever occupies a lane.

Soundness (contract 14, ``docs/ARCHITECTURE.md``): the cache is a latency
knob, never a results-soundness knob. Every entry stores the candidate
frontier its Theorem-2 certificate was computed over, and a hit is served
only after that frontier is **rescored in exact float against the live
query** and passes :func:`repro.core.theorems.theorem2_recheck` — the same
engine-free audit a fresh search's certificate answers to. The probe
threshold (``theorem2_slack_threshold``: certificate slack / (2k·L), with
L the metric's score-Lipschitz constant per unit query drift) is a *probe
filter* that predicts which entries can survive revalidation; it is never
the soundness argument, because the recheck runs on every served hit.

Probe path: one batched similarity of the live query against every cached
query embedding via ``kops.batch_similarity`` — the same
auto/ref/interpret/pallas kernel ladder the engines score with, so the
cache probe rides whatever impl the host resolved.

Eviction is LRU gated by slack-aware admission: a new entry may only
displace the least-recently-used entry among residents whose revalidation
threshold does not exceed its own — a cache full of strictly
more-reusable entries declines the newcomer rather than churn.
"""
from __future__ import annotations

import collections
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import theorems
from repro.core.pgs import DiverseResult
from repro.core.progressive import SearchStats
from repro.kernels import ops as kops


@dataclasses.dataclass(eq=False)
class CacheEntry:
    """One cached certified result: the served set, the frontier its
    certificate was computed over, and the reuse budget derived from it."""
    q_probe: np.ndarray        # probe-space query (unit-normalized for cos)
    q: np.ndarray              # the original query embedding
    k: int
    eps: float
    method: str
    ids: np.ndarray            # served diverse set (global ids)
    scores: np.ndarray
    cand_ids: np.ndarray       # certificate frontier (global ids, -1 pad)
    cand_scores: np.ndarray    # frontier scores for the original query
    slack: float               # minValue - s_K at admission
    threshold: float           # max probe-space drift worth rechecking
    hits: int = 0

    @property
    def key(self) -> tuple:
        """Compatibility key — a hit must share the request's exact
        diversification parameters (Definition 1: the query owns them)."""
        return (int(self.k), float(self.eps), str(self.method))


class SemanticResultCache:
    """Certified diverse result sets keyed by query embedding.

    ``vectors`` must be the **exact float corpus** (revalidation rescores
    frontiers with it; handing it a quantized corpus would launder
    quantization error into certificates — contract 13 forbids that).
    ``capacity`` bounds resident entries; ``max_drift`` optionally caps the
    probe threshold (useful for ``k == 1``, whose Theorem-2 slack is
    infinite); ``impl`` pins the kernel ladder rung for probes and
    rescoring (None = the ambient default). ``safety`` in ``(0, 1]``
    shrinks thresholds below the proven bound.

    ``guard`` is a numerical guard band (score units): admission rejects
    certificates whose slack is within it, and revalidation requires the
    live recheck's margin ``min_value - s_K`` to clear it. The slack
    threshold's soundness argument assumes exact arithmetic; a knife-edge
    certificate (slack ~ float noise) can flip verdict under a different
    but equally exact summation order — e.g. an auditor rescoring the
    frontier through another kernel rung. The guard keeps every served
    hit's certificate far enough from the boundary that *any* independent
    float path reaches the same verdict.

    ``live`` binds the cache to a mutable corpus (an
    ``index.mutable.MutableIndex``; pass ``vectors=None``): revalidation
    then runs through ``live.audit_frontier`` — deletion-bitmap filter,
    delta-segment merge, Theorem-2 re-audit against the live float view —
    so a hit after a write is either served valid-against-the-live-corpus
    or refused (contract 15 extends contract 14). The ``ip`` Lipschitz
    constant tracks the live corpus per write version. ``invalidate`` is
    the eager companion: the scheduler's write path evicts entries whose
    stored frontier a write touched, independent of live binding.
    """

    def __init__(self, vectors, metric: str | None = None,
                 capacity: int = 256, *,
                 live=None, impl: str | None = None, safety: float = 1.0,
                 max_drift: float | None = None, guard: float = 1e-4):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} must be >= 1")
        if not 0.0 < safety <= 1.0:
            raise ValueError(f"safety={safety} outside (0, 1] — above 1 the "
                             "threshold would exceed the proven drift bound")
        if guard < 0.0:
            raise ValueError(f"guard={guard} must be >= 0")
        self.live = live
        if live is not None:
            if vectors is not None:
                raise ValueError("pass either vectors or live=, not both")
            metric = live.metric
            self._vectors = None
        else:
            self._vectors = np.asarray(vectors, np.float32)
            if self._vectors.ndim != 2:
                raise ValueError("vectors must be the float [n, d] corpus")
            if metric is None:
                raise ValueError("metric is required with a static corpus")
        self.metric = str(metric)
        self.capacity = int(capacity)
        self.impl = impl
        self.safety = float(safety)
        self.max_drift = None if max_drift is None else float(max_drift)
        self.guard = float(guard)
        self._lip_cache: tuple[int, float] | None = None
        #: eid -> entry, ordered oldest-touched first (LRU at the front)
        self._entries: collections.OrderedDict[int, CacheEntry] = \
            collections.OrderedDict()
        self._next_eid = 0
        self._qmat: np.ndarray | None = None   # (m, d) probe-space rows
        self._eids: list[int] = []
        self.probes = 0
        self.hits = 0
        self.misses = 0
        self.revalidation_failures = 0
        self.admitted = 0
        self.rejected = 0
        self.evicted = 0
        self.invalidated = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def vectors(self) -> np.ndarray:
        """The exact float corpus revalidation rescores against — the live
        view when bound to a mutable index, else the static corpus."""
        return (self.live.float_view() if self.live is not None
                else self._vectors)

    @property
    def lipschitz(self) -> float:
        """Score-shift per unit probe drift (see
        ``theorem2_slack_threshold``): l2 and cos are 1-Lipschitz in probe
        space; ip is bounded by the largest corpus norm — recomputed per
        write version on a live corpus (an upsert can raise it)."""
        if self.metric != "ip":
            return 1.0
        version = self.live.version if self.live is not None else 0
        if self._lip_cache is None or self._lip_cache[0] != version:
            norms = np.linalg.norm(self.vectors, axis=1)
            self._lip_cache = (version,
                               float(norms.max()) if norms.size else 1.0)
        return self._lip_cache[1]

    # -- probe space ---------------------------------------------------------
    def _probe_vec(self, q) -> np.ndarray:
        q = np.asarray(q, np.float32).reshape(-1)
        if self.metric == "cos":
            n = float(np.linalg.norm(q))
            if n > 0.0:
                q = q / n
        return q

    def _rebuild_qmat(self) -> None:
        self._eids = list(self._entries)
        self._qmat = (np.stack([self._entries[e].q_probe
                                for e in self._eids])
                      if self._eids else None)

    # -- lookup --------------------------------------------------------------
    def lookup(self, q, k: int, eps: float, method: str):
        """Probe + revalidate: returns ``(DiverseResult, CacheEntry)`` for a
        revalidated near-hit, or ``None`` (miss, or revalidation failed).
        The returned result's scores are the *live query's* exact float
        scores over the entry's frontier, and its certificate was re-audited
        against the live query — never the cached one."""
        self.probes += 1
        eid = self._probe(q, k, eps, method)
        if eid is None:
            self.misses += 1
            return None
        entry = self._entries[eid]
        result = self.revalidate(entry, q)
        if result is None:
            self.revalidation_failures += 1
            self.misses += 1
            return None
        self.hits += 1
        entry.hits += 1
        self._entries.move_to_end(eid)
        return result, entry

    def _probe(self, q, k: int, eps: float, method: str) -> int | None:
        if not self._entries:
            return None
        if self._qmat is None:
            self._rebuild_qmat()
        key = (int(k), float(eps), str(method))
        qp = self._probe_vec(q)
        # one batched kernel dispatch against every cached embedding; the
        # l2 similarity is 1 - ||qp - qi||, so drift falls straight out
        sims = np.asarray(kops.batch_similarity(
            jnp.asarray(qp), jnp.asarray(self._qmat), "l2", impl=self.impl))
        drifts = np.maximum(1.0 - sims.astype(np.float64), 0.0)
        best: tuple | None = None
        for row, eid in enumerate(self._eids):
            entry = self._entries[eid]
            if entry.key != key:
                continue
            limit = entry.threshold
            if self.max_drift is not None:
                limit = min(limit, self.max_drift)
            drift = float(drifts[row])
            if drift > limit:
                continue
            cand = (drift, eid)         # nearest first; oldest eid breaks ties
            if best is None or cand < best:
                best = cand
        return best[1] if best is not None else None

    # -- revalidation --------------------------------------------------------
    def revalidate(self, entry: CacheEntry, q) -> DiverseResult | None:
        """Rescore the entry's frontier against ``q`` in exact float and
        re-run the Theorem-2 recheck; a pass returns a ``DiverseResult``
        carrying the live query's scores and a live certificate. The
        recheck's margin must clear ``guard``, so the certificate survives
        an independent auditor's float path too (not just this one).

        On a live (mutable) corpus with writes applied, the recheck runs
        through ``live.audit_frontier`` instead: tombstoned ids are dropped
        from the frontier, the delta segment's live points are merged in
        (a fresh better point must *join* the served set, not silently
        lose to a stale one), and the certificate is audited against the
        live float view with the engine's unexplored-point bound kept."""
        if self.live is not None and self.live.mutated:
            certified, sel_ids, sel_sc, m_ids, _, slack = \
                self.live.audit_frontier(q, entry.k, entry.eps,
                                         entry.cand_ids, None,
                                         impl=self.impl)
            if not certified or not slack > self.guard:
                return None
            stats = SearchStats(expansions=0, growths=0, search_calls=0,
                                div_calls=1, certified=True, exhausted=False,
                                K_final=int(m_ids.size))
            return DiverseResult(sel_ids.astype(np.int32), sel_sc,
                                 float(sel_sc.sum()), stats)
        valid = entry.cand_ids >= 0
        vecs = self.vectors[np.maximum(entry.cand_ids, 0)]
        q32 = np.asarray(q, np.float32).reshape(-1)
        sc = np.asarray(kops.batch_similarity(
            jnp.asarray(q32), jnp.asarray(vecs), self.metric,
            impl=self.impl), np.float32)
        sc = np.where(valid, sc, -np.inf).astype(np.float32)
        order = np.argsort(-sc, kind="stable")
        new_ids = entry.cand_ids[order]
        new_sc = sc[order]
        certified, sel_ids, min_value, s_K = theorems.theorem2_audit(
            self.vectors, self.metric, new_ids, new_sc, entry.eps, entry.k)
        if not certified or not (min_value - s_K) > self.guard:
            return None
        score_of = {int(i): float(s) for i, s in zip(new_ids, new_sc)
                    if i >= 0}
        sel_sc = np.asarray([score_of.get(int(i), 0.0) if i >= 0 else 0.0
                             for i in sel_ids], np.float32)
        stats = SearchStats(expansions=0, growths=0, search_calls=0,
                            div_calls=1, certified=True, exhausted=False,
                            K_final=int(valid.sum()))
        return DiverseResult(sel_ids.astype(np.int32), sel_sc,
                             float(sel_sc.sum()), stats)

    # -- admission -----------------------------------------------------------
    def admit_request(self, q, k: int, eps: float, method: str,
                      result: DiverseResult, cand_ids, cand_scores,
                      slack: float | None = None) -> bool:
        """Offer a harvested result for caching; returns True if admitted.

        Only certified results with a recorded frontier and positive
        Theorem-2 slack are cacheable. ``slack`` may be supplied by the
        engine (it computed ``minValue - s_K`` in its final round); when
        absent it is re-derived by an independent ``theorem2_audit`` of the
        frontier — which also refuses frontiers whose certificate was not
        Theorem-2-shaped (e.g. ``pds``'s Theorem-1 budget certificates).
        """
        if result is None or not getattr(result.stats, "certified", False):
            self.rejected += 1
            return False
        if cand_ids is None or cand_scores is None:
            self.rejected += 1
            return False
        cand_ids = np.asarray(cand_ids, np.int32)
        cand_scores = np.asarray(cand_scores, np.float32)
        if cand_ids.size == 0 or not (cand_ids >= 0).any():
            self.rejected += 1
            return False
        if slack is None:
            certified, _, min_value, s_K = theorems.theorem2_audit(
                self.vectors, self.metric, cand_ids, cand_scores, eps, k)
            if not certified:
                self.rejected += 1
                return False
            slack = min_value - s_K
        slack = float(slack)
        if not slack > self.guard:      # knife-edge certificate: not worth
            self.rejected += 1          # caching, and an independent float
            return False                # path could flip its verdict
        threshold = self.safety * theorems.theorem2_slack_threshold(
            slack, k, self.lipschitz)
        if not threshold > 0.0:
            self.rejected += 1
            return False
        entry = CacheEntry(
            q_probe=self._probe_vec(q),
            q=np.asarray(q, np.float32).reshape(-1).copy(),
            k=int(k), eps=float(eps), method=str(method),
            ids=np.asarray(result.ids, np.int32).copy(),
            scores=np.asarray(result.scores, np.float32).copy(),
            cand_ids=cand_ids.copy(), cand_scores=cand_scores.copy(),
            slack=slack, threshold=float(threshold))
        if len(self._entries) >= self.capacity:
            # LRU among residents no more reusable than the newcomer; a
            # cache full of strictly larger thresholds declines instead
            victim = next((eid for eid in self._entries
                           if self._entries[eid].threshold
                           <= entry.threshold), None)
            if victim is None:
                self.rejected += 1
                return False
            del self._entries[victim]
            self.evicted += 1
        self._entries[self._next_eid] = entry
        self._next_eid += 1
        self.admitted += 1
        self._qmat = None   # rebuilt lazily on the next probe
        return True

    # -- write invalidation --------------------------------------------------
    def invalidate(self, ids) -> int:
        """Evict every entry whose stored certificate frontier intersects
        ``ids``; returns the eviction count. The scheduler's write path
        calls this on each applied write (PR 8's carry-over): a deleted id
        in a frontier voids both the served set and the slack the probe
        threshold was derived from. Upserted ids are fresh — no stored
        frontier can contain them — so upserts are covered by live-corpus
        revalidation (delta merge at hit time) rather than eager eviction.
        """
        ids = np.unique(np.asarray(ids, np.int64).reshape(-1))
        if ids.size == 0 or not self._entries:
            return 0
        victims = [eid for eid, e in self._entries.items()
                   if np.isin(e.cand_ids[e.cand_ids >= 0], ids).any()]
        for eid in victims:
            del self._entries[eid]
        if victims:
            self._qmat = None
            self.invalidated += len(victims)
        return len(victims)

    # -- reporting -----------------------------------------------------------
    def stats(self) -> dict:
        """Counters snapshot (all lifetime): probes/hits/misses,
        revalidation failures (near-hits whose live-query recheck failed),
        admissions/rejections/evictions, write invalidations, and resident
        size."""
        return dict(
            size=len(self._entries), capacity=self.capacity,
            probes=self.probes, hits=self.hits, misses=self.misses,
            hit_rate=self.hits / self.probes if self.probes else 0.0,
            revalidation_failures=self.revalidation_failures,
            admitted=self.admitted, rejected=self.rejected,
            evicted=self.evicted, invalidated=self.invalidated,
        )

    @classmethod
    def for_backend(cls, backend, capacity: int = 256,
                    **kw) -> "SemanticResultCache":
        """Build a cache over a ``LaneBackend``'s own corpus.

        Works for any backend exposing a float corpus: a write-capable
        backend's ``mutable_index`` (the cache binds ``live=`` to it, so
        revalidation tracks writes), the single-host engine's ``graph``
        (``vectors``/``metric``), or the sharded engine's ``all_vectors``
        + ``index.metric``. Refuses quantized corpora — the cache must
        rescore in exact float (contract 13/14)."""
        mutable = getattr(backend, "mutable_index", None)
        if mutable is not None:
            return cls(None, capacity=capacity, live=mutable, **kw)
        graph = getattr(backend, "graph", None)
        if graph is not None and not getattr(backend, "compressed", False):
            return cls(np.asarray(graph.vectors), graph.metric, capacity,
                       **kw)
        all_vectors = getattr(backend, "all_vectors", None)
        index = getattr(backend, "index", None)
        if all_vectors is not None and index is not None:
            return cls(np.asarray(all_vectors), index.metric, capacity, **kw)
        raise ValueError(
            "backend exposes no exact float corpus to revalidate against "
            "(quantized single-host corpora are refused: contract 13)")
