"""Mixture-of-Experts FFN: token-choice top-k routing with capacity.

Sort-based dispatch (DESIGN.md §5): instead of the GShard one-hot dispatch
tensor [T, E, C] (which at llama4 scale is tens of GB per device), tokens are
argsorted by expert id and scattered into an [E, C, D] buffer — O(T·D + E·C·D)
memory, fixed shapes, fully shardable. Overflowing tokens are dropped
(standard capacity-factor semantics); the residual path carries them.

Expert weights live as [E, D, F]/[E, F, D] stacks so the expert axis shards
over the mesh's model axis (expert parallelism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def moe_ffn(params, x, *, num_experts: int, experts_per_token: int,
            capacity_factor: float = 1.25, act: str = "silu",
            impl: str = "sort", shard_experts: bool = False):
    """x [B, S, D] -> [B, S, D].

    params: wr [D, E] router; wg/wu [E, D, F]; wd [E, F, D].

    impl="sort": argsort dispatch (least memory, but its dynamic scatter
    indices defeat GSPMD sharding propagation — expert grads come back
    replicated+all-reduced at terabyte scale; see EXPERIMENTS §Perf).
    impl="einsum": GShard-style one-hot dispatch einsums — more dispatch
    FLOPs and a [T, E, C] mask, but every contraction carries a clean
    sharding (tokens on batch axes, experts on model), which is what the
    collective-bound hillclimb iteration needed.
    """
    b, s, d = x.shape
    e = num_experts
    topk = experts_per_token
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.dot(xt.astype(F32), params["wr"].astype(F32))   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, topk)                    # [T, topk]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(t * topk * capacity_factor / e))

    if impl == "einsum":
        return _moe_einsum(params, x, xt, probs, gate, expert, e, topk,
                           capacity_factor, act, shard_experts)

    flat_expert = expert.reshape(-1)                             # [T*topk]
    flat_gate = gate.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), topk)

    # rank of each (token, slot) within its expert, in token order
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    # position within expert = index - start offset of that expert
    counts = jnp.bincount(flat_expert, length=e)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(t * topk) - starts[sorted_expert]
    pos = jnp.zeros(t * topk, jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))

    keep = pos < capacity
    dest = jnp.where(keep, flat_expert * capacity + pos, e * capacity)

    buf = jnp.zeros((e * capacity + 1, d), x.dtype)
    buf = buf.at[dest].set(xt[flat_tok])
    xe = buf[: e * capacity].reshape(e, capacity, d)

    gdt = jnp.einsum("ecd,edf->ecf", xe.astype(F32), params["wg"].astype(F32))
    udt = jnp.einsum("ecd,edf->ecf", xe.astype(F32), params["wu"].astype(F32))
    actf = dict(silu=jax.nn.silu, gelu=jax.nn.gelu)[act]
    h = (actf(gdt) * udt).astype(x.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h.astype(F32), params["wd"].astype(F32))
    ye = ye.reshape(e * capacity, d)

    contrib = jnp.where(keep[:, None],
                        ye[jnp.minimum(dest, e * capacity - 1)]
                        * flat_gate[:, None], 0.0)
    yt = jnp.zeros((t, d), F32).at[flat_tok].add(contrib)

    # auxiliary load-balance loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        (jax.nn.one_hot(expert[:, 0], e)), axis=0)
    aux = e * jnp.sum(me * ce)
    return yt.reshape(b, s, d).astype(x.dtype), aux


def _moe_einsum(params, x, xt, probs, gate, expert, e, topk, cf, act,
                shard_experts: bool = False):
    """GShard dispatch WITH a group axis (= batch): xe [G, E, C, D].

    The group dim shards over the data axes while experts shard over
    "model", so the expert FFN einsum parallelizes over BOTH — collapsing
    all tokens into one global group leaves expert compute only model-way
    parallel (observed 5x compute inflation on llama4; EXPERIMENTS §Perf).
    Dispatch masks are built per top-k slot to avoid a [T*topk, E, C]
    monolith. Capacity is per group: C = S * topk * cf / E.
    """
    from jax.sharding import PartitionSpec as P

    def pin(a, lead):
        if not shard_experts:
            return a
        U = P.UNCONSTRAINED
        spec = [U] * a.ndim
        spec[lead] = "model"
        return jax.lax.with_sharding_constraint(a, P(*spec))

    g, s, d = x.shape
    cap = max(1, int(s * topk * cf / e))
    expert_g = expert.reshape(g, s, topk)
    gate_g = gate.reshape(g, s, topk).astype(x.dtype)

    # rank of each (s, k) slot within its (group, expert), token order
    oh = jax.nn.one_hot(expert_g, e, dtype=jnp.int32)       # [G, S, K, E]
    flat = oh.reshape(g, s * topk, e)
    rank_flat = jnp.cumsum(flat, axis=1) - flat
    rank = jnp.sum(rank_flat * flat, axis=-1).reshape(g, s, topk)
    keep = rank < cap

    xe = jnp.zeros((g, e, cap, d), F32)
    combine = []
    for k in range(topk):
        pos_oh = jax.nn.one_hot(jnp.where(keep[..., k], rank[..., k], cap),
                                cap + 1, dtype=x.dtype)[..., :cap]  # [G,S,C]
        disp_k = oh[..., k, :].astype(x.dtype)[..., :, None] \
            * pos_oh[..., None, :]                           # [G, S, E, C]
        xe = xe + jnp.einsum("gsec,gsd->gecd", disp_k, x,
                             preferred_element_type=F32)
        combine.append(disp_k * gate_g[..., k][..., None, None])
    xe = pin(xe.astype(x.dtype), 1)

    gdt = jnp.einsum("gecd,edf->gecf", xe.astype(F32),
                     params["wg"].astype(F32))
    udt = jnp.einsum("gecd,edf->gecf", xe.astype(F32),
                     params["wu"].astype(F32))
    actf = dict(silu=jax.nn.silu, gelu=jax.nn.gelu)[act]
    h = pin((actf(gdt) * udt).astype(x.dtype), 1)
    ye = pin(jnp.einsum("gecf,efd->gecd", h.astype(F32),
                        params["wd"].astype(F32)).astype(x.dtype), 1)

    yt = jnp.zeros((g, s, d), F32)
    for k in range(topk):
        yt = yt + jnp.einsum("gsec,gecd->gsd", combine[k], ye,
                             preferred_element_type=F32)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert[:, 0], e), axis=0)
    aux = e * jnp.sum(me * ce)
    return yt.astype(x.dtype), aux
