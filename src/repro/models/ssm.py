"""Mamba-2 (SSD, state-space duality) block — arXiv:2405.21060.

Chunked SSD algorithm: within-chunk attention-like term (quadratic in the
chunk) + across-chunk recurrence on the [H, P, N] state. Matches the
sequential scan reference (tests/test_models.py) and supports O(1)-state
single-token decode for serving.

Layout: d_inner = expand * d_model, H = d_inner / headdim heads, state N.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _causal_conv(x, w, b):
    """Depthwise causal conv1d. x [B,S,C], w [K,C], b [C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(F32), w[:, None, :].astype(F32),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return (out + b).astype(x.dtype)


def ssd_chunked(xh, dt, A, B_, C_, chunk: int = 128, h0=None):
    """SSD forward. xh [B,S,H,P], dt [B,S,H], A [H] (negative),
    B_/C_ [B,S,N]. Returns (y [B,S,H,P], h_last [B,H,P,N])."""
    b, s, h, p = xh.shape
    n = B_.shape[-1]
    c = min(chunk, s)
    nc = -(-s // c)
    pad = nc * c - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    xs = xh.reshape(b, nc, c, h, p).astype(F32)
    dts = dt.reshape(b, nc, c, h).astype(F32)
    Bs = B_.reshape(b, nc, c, n).astype(F32)
    Cs = C_.reshape(b, nc, c, n).astype(F32)

    dA = dts * A[None, None, None, :]           # [B,NC,c,H]  (<= 0)
    cumA = jnp.cumsum(dA, axis=2)               # within-chunk cumulative
    seg = cumA[:, :, :, None, :] - cumA[:, :, None, :, :]  # [B,NC,c(q),c(k),H]
    causal = jnp.tril(jnp.ones((c, c), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # within-chunk: y_diag[q] = sum_k C_q.B_k decay(q,k) dt_k x_k
    cb = jnp.einsum("bzqn,bzkn->bzqk", Cs, Bs)               # [B,NC,c,c]
    y_diag = jnp.einsum("bzqk,bzqkh,bzkh,bzkhp->bzqhp",
                        cb, decay, dts, xs)

    # chunk-level state contributions
    chunk_decay = jnp.exp(cumA[:, :, -1, :])                  # [B,NC,H]
    rem = jnp.exp(cumA[:, :, -1, None, :] - cumA)             # decay to end
    state_in = jnp.einsum("bzkn,bzkh,bzkh,bzkhp->bzhpn",
                          Bs, rem, dts, xs)                   # [B,NC,H,P,N]

    def scan_state(hprev, inp):
        dec, s_in = inp                                        # [B,H], [B,H,P,N]
        hnew = hprev * dec[..., None, None] + s_in
        return hnew, hprev

    h_init = jnp.zeros((b, h, p, n), F32) if h0 is None else h0.astype(F32)
    h_last, h_prevs = jax.lax.scan(
        scan_state, h_init,
        (chunk_decay.transpose(1, 0, 2), state_in.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                 # [B,NC,H,P,N]

    # across-chunk: y_off[q] = C_q . (decay_to_start(q) * h_prev)
    into = jnp.exp(cumA)                                       # decay start->q
    y_off = jnp.einsum("bzqn,bzqh,bzhpn->bzqhp", Cs, into, h_prevs)

    y = (y_diag + y_off).reshape(b, nc * c, h, p)[:, :s]
    return y, h_last


def ssd_step(xh, dt, A, B_, C_, h):
    """Single-token SSD update. xh [B,1,H,P] dt [B,1,H] B_/C_ [B,1,N],
    h [B,H,P,N] -> (y [B,1,H,P], h_new)."""
    dA = jnp.exp(dt[:, 0, :, None, None].astype(F32)
                 * A[None, :, None, None])                     # [B,H,1,1]
    upd = jnp.einsum("bn,bh,bhp->bhpn", B_[:, 0].astype(F32),
                     dt[:, 0].astype(F32), xh[:, 0].astype(F32))
    h_new = h.astype(F32) * dA + upd
    y = jnp.einsum("bn,bhpn->bhp", C_[:, 0].astype(F32), h_new)
    return y[:, None].astype(xh.dtype), h_new


def mamba2_block(params, x, *, headdim: int, d_state: int, chunk: int = 128,
                 decode_state=None):
    """Full Mamba-2 block. x [B,S,D].

    params: w_in [D, 2*Di + 2*N + H], conv_w [K, Di+2N], conv_b, A_log [H],
    D_skip [H], norm_scale [Di], w_out [Di, D], dt_bias [H].
    Returns (y, new_decode_state) where decode_state = (conv_buf, h).
    """
    b, s, d = x.shape
    w_in = params["w_in"]
    di = params["w_out"].shape[0]
    h_heads = params["A_log"].shape[0]
    n = d_state

    zxbcdt = jnp.dot(x, w_in, preferred_element_type=F32).astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [di, di + di + 2 * n], axis=-1)

    if decode_state is not None:
        conv_buf, h0 = decode_state
        conv_buf = jnp.concatenate([conv_buf[:, 1:], xbc], axis=1)
        xbc_conv = jnp.einsum("bkc,kc->bc", conv_buf.astype(F32),
                              params["conv_w"].astype(F32))
        xbc_conv = (xbc_conv + params["conv_b"])[:, None]
        xbc_conv = jax.nn.silu(xbc_conv).astype(x.dtype)
    else:
        conv_buf = None
        xbc_conv = jax.nn.silu(
            _causal_conv(xbc, params["conv_w"], params["conv_b"])
        ).astype(x.dtype)

    xh, B_, C_ = jnp.split(xbc_conv, [di, di + n], axis=-1)
    xh = xh.reshape(b, -1, h_heads, headdim)
    dt = jax.nn.softplus(dt.astype(F32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(F32))

    if decode_state is not None:
        y, h_new = ssd_step(xh, dt, A, B_, C_, h0)
        new_state = (conv_buf, h_new)
    else:
        y, h_last = ssd_chunked(xh, dt, A, B_, C_, chunk=chunk)
        new_state = h_last
    y = y + xh.astype(F32) * params["D_skip"][None, None, :, None]
    y = y.reshape(b, -1, di)
    # gated RMSNorm (Mamba-2 uses norm(y * silu(z)))
    from repro.models.layers import rms_norm
    y = rms_norm((y * jax.nn.silu(z.astype(F32))).astype(x.dtype),
                 params["norm_scale"])
    out = jnp.dot(y, params["w_out"], preferred_element_type=F32)
    return out.astype(x.dtype), new_state
