"""Shared neural layers for the 10-arch substrate (pure JAX, scan-friendly).

Everything here is a pure function over a params pytree. Attention uses a
chunked online-softmax ("flash") formulation so prefill_32k / train_4k never
materialize an [S, S] score matrix; decode uses a single fused softmax over
the KV cache. All matmuls accumulate in f32 via preferred_element_type.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]

F32 = jnp.float32


# ----------------------------------------------------------------- norms ---
def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    out = x.astype(F32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(F32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(x.dtype)


# ------------------------------------------------------------------ rope ---
def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x [..., S, H, hd]; positions [..., S] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., :, None].astype(F32) * freqs   # [..., S, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ mlps ---
def _act(name: str):
    return dict(gelu=jax.nn.gelu, silu=jax.nn.silu, relu=jax.nn.relu)[name]


def gated_mlp(params: Params, x, act: str = "silu"):
    """SwiGLU (act=silu) / GeGLU (act=gelu): (act(x W_g) * x W_u) W_d."""
    g = jnp.dot(x, params["wg"], preferred_element_type=F32)
    u = jnp.dot(x, params["wu"], preferred_element_type=F32)
    h = (_act(act)(g) * u).astype(x.dtype)
    return jnp.dot(h, params["wd"], preferred_element_type=F32).astype(x.dtype)


def dense_mlp(params: Params, x, act: str = "gelu"):
    h = jnp.dot(x, params["w1"], preferred_element_type=F32)
    if "b1" in params:
        h = h + params["b1"]
    h = _act(act)(h).astype(x.dtype)
    o = jnp.dot(h, params["w2"], preferred_element_type=F32)
    if "b2" in params:
        o = o + params["b2"]
    return o.astype(x.dtype)


# ------------------------------------------------------- flash attention ---
NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _attn_block(qb, kb, vb, mask, scale):
    """One (q-chunk, kv-chunk) online-softmax block.

    qb [B,cq,KV,G,hd]  kb/vb [B,ck,KV,hd]  mask [cq,ck] bool (True=keep).
    Returns (scores_max [B,KV,G,cq], exp_scores [B,KV,G,cq,ck]).
    """
    s = jnp.einsum("bqkgh,bckh->bkgqc", qb.astype(F32), kb.astype(F32)) * scale
    s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
    return s


def _block_mask(q_pos, k_pos, sk, causal, window):
    mask = (k_pos[None, :] < sk)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    return mask


@functools.lru_cache(maxsize=64)
def _make_flash_vjp(causal: bool, window: int, cq: int, ck: int,
                    sq: int, sk: int, q_offset: int, sk_valid: int = 0,
                    block_dtype: str = "float32"):
    """custom_vjp flash attention specialized to static geometry.

    The naive differentiated double-scan saves the [nq, nk, B, KV, G, cq, ck]
    exp-score tensors for the backward (tens of GB at train_4k); this VJP
    saves only (q, k, v, out, lse) and recomputes each score block in the
    backward — the standard FlashAttention-2 strategy, adapted to XLA scans.
    """
    nq = sq // cq
    nk = sk // ck
    sk_valid = sk_valid or sk
    bdt = jnp.dtype(block_dtype)

    def fwd_pass(q, k, v):
        # q [B,Sq,KV,G,hd] (grouped); k/v [B,Sk,KV,hd]; all padded.
        b, _, kvh, g, hd = q.shape
        scale = 1.0 / float(hd) ** 0.5
        qg = q.reshape(b, nq, cq, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
        kc = k.reshape(b, nk, ck, kvh, hd).transpose(1, 0, 2, 3, 4)
        vc = v.reshape(b, nk, ck, kvh, hd).transpose(1, 0, 2, 3, 4)

        def per_q(qi):
            qb = qg[qi]
            q_pos = q_offset + qi * cq + jnp.arange(cq)

            def kv_step(carry, ki):
                m, l, acc = carry
                k_pos = ki * ck + jnp.arange(ck)
                mask = _block_mask(q_pos, k_pos, sk_valid, causal, window)
                s = jnp.einsum("bqkgh,bckh->bkgqc", qb.astype(F32),
                               kc[ki].astype(F32)) * scale
                s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
                blk_max = jnp.max(s, axis=-1)
                new_m = jnp.maximum(m, blk_max)
                p = jnp.exp(s - new_m[..., None])
                corr = jnp.exp(m - new_m)
                new_l = l * corr + jnp.sum(p, axis=-1)
                pv = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(bdt),
                                vc[ki].astype(bdt),
                                preferred_element_type=F32)
                return (new_m, new_l, new_acc_fix(acc, corr, pv)), None

            def new_acc_fix(acc, corr, pv):
                return acc * corr[..., None] + pv

            m0 = jnp.full((b, kvh, g, cq), NEG_INF, F32)
            l0 = jnp.zeros((b, kvh, g, cq), F32)
            a0 = jnp.zeros((b, kvh, g, cq, hd), F32)
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nk))
            out = acc / jnp.maximum(l[..., None], 1e-30)
            lse = m + jnp.log(jnp.maximum(l, 1e-30))
            return out, lse                      # [B,KV,G,cq,(hd)], [B,KV,G,cq]

        outs, lses = jax.lax.map(per_q, jnp.arange(nq))
        # outs [nq,B,KV,G,cq,hd] -> [B,Sq,KV,G,hd]
        out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, kvh, g, hd)
        # lses [nq,B,KV,G,cq] -> [B,KV,G,Sq]
        lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, kvh, g, sq)
        return out.astype(q.dtype), lse

    @jax.custom_vjp
    def flash(q, k, v):
        return fwd_pass(q, k, v)[0]

    def flash_fwd(q, k, v):
        out, lse = fwd_pass(q, k, v)
        return out, (q, k, v, out, lse)

    def flash_bwd(res, dout):
        q, k, v, out, lse = res
        b, _, kvh, g, hd = q.shape
        scale = 1.0 / float(hd) ** 0.5
        qg = q.reshape(b, nq, cq, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
        kc = k.reshape(b, nk, ck, kvh, hd).transpose(1, 0, 2, 3, 4)
        vc = v.reshape(b, nk, ck, kvh, hd).transpose(1, 0, 2, 3, 4)
        dog = dout.astype(F32).reshape(b, nq, cq, kvh, g, hd) \
            .transpose(1, 0, 2, 3, 4, 5)
        og = out.astype(F32).reshape(b, nq, cq, kvh, g, hd) \
            .transpose(1, 0, 2, 3, 4, 5)
        lseg = lse.transpose(0, 3, 1, 2).reshape(b, nq, cq, kvh, g) \
            .transpose(1, 0, 3, 4, 2)            # [nq,B,KV,G,cq]
        # D = rowsum(dout * out)
        Dg = jnp.sum(dog * og, axis=-1)          # [nq,B,cq,KV,G]
        Dg = Dg.transpose(0, 1, 3, 4, 2)         # [nq,B,KV,G,cq]

        def per_q(qi):
            qb = qg[qi].astype(F32)
            dob = dog[qi]
            lse_b = lseg[qi]
            D_b = Dg[qi]
            q_pos = q_offset + qi * cq + jnp.arange(cq)

            def kv_step(dq_acc, ki):
                k_pos = ki * ck + jnp.arange(ck)
                mask = _block_mask(q_pos, k_pos, sk_valid, causal, window)
                kb = kc[ki].astype(F32)
                vb = vc[ki].astype(F32)
                s = jnp.einsum("bqkgh,bckh->bkgqc", qb, kb) * scale
                s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
                p = jnp.exp(s - lse_b[..., None])             # [B,KV,G,cq,ck]
                dv_c = jnp.einsum("bkgqc,bqkgh->bckh", p.astype(bdt),
                                  dob.astype(bdt),
                                  preferred_element_type=F32)
                dp = jnp.einsum("bqkgh,bckh->bkgqc", dob.astype(bdt),
                                vb.astype(bdt), preferred_element_type=F32)
                ds = (p * (dp - D_b[..., None]) * scale)
                dq_blk = jnp.einsum("bkgqc,bckh->bqkgh", ds.astype(bdt),
                                    kb.astype(bdt),
                                    preferred_element_type=F32)
                dk_c = jnp.einsum("bkgqc,bqkgh->bckh", ds.astype(bdt),
                                  qb.astype(bdt), preferred_element_type=F32)
                return dq_acc + dq_blk, (dk_c, dv_c)

            dq0 = jnp.zeros((b, cq, kvh, g, hd), F32)
            dq_b, (dk_chunks, dv_chunks) = jax.lax.scan(
                kv_step, dq0, jnp.arange(nk))
            return dq_b, dk_chunks, dv_chunks    # dk/dv: [nk,B,ck,KV,hd]

        dqs, dks, dvs = jax.lax.map(per_q, jnp.arange(nq))
        dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kvh, g, hd)
        dk = jnp.sum(dks, axis=0).transpose(1, 0, 2, 3, 4) \
            .reshape(b, sk, kvh, hd)
        dv = jnp.sum(dvs, axis=0).transpose(1, 0, 2, 3, 4) \
            .reshape(b, sk, kvh, hd)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


@functools.partial(jax.jit, static_argnames=("q_offset", "causal", "window",
                                              "chunk_q", "chunk_k",
                                              "skip_future", "gqa",
                                              "pad_heads_to", "block_dtype",
                                              "shard_heads"))
def flash_attention(q, k, v, q_offset=0, causal: bool = True,
                    window: int = 0, chunk_q: int = 512, chunk_k: int = 1024,
                    skip_future: bool = False, gqa: str = "repeat",
                    pad_heads_to: int = 0, block_dtype: str = "float32",
                    shard_heads: bool = False):
    """Chunked attention. q [B,Sq,H,hd], k/v [B,Sk,KV,hd] -> [B,Sq,H,hd].

    GQA via head grouping (G = H // KV). ``causal`` masks with the global
    query offset ``q_offset`` (prefill continuation / decode windows).
    ``window > 0`` = sliding-window (local) attention.
    ``skip_future``: iterate kv chunks with a dynamic bound so fully-masked
    future blocks are never computed (halves causal FLOPs; the paper-faithful
    masked-full variant is kept for the §Perf baseline via False).
    """
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    h_true = h
    if gqa == "repeat" and kvh != h:
        # Sharding-friendly layout (DESIGN.md §5 / EXPERIMENTS §Perf): the
        # grouped [B,S,KV,G,hd] reshape splits the sharded head dim and
        # forces GSPMD to all-gather activations per layer; repeating kv to
        # one lane per q-head keeps every tensor sharded on the SAME head
        # axis. kv was replicated anyway whenever KV < mesh model size.
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        kvh = h
    if pad_heads_to and pad_heads_to > h and kvh == h:
        # Head padding: divisibility-driven (e.g. 28 heads -> 32 on a
        # 16-way model axis). Padded q lanes attend to padded (zero) kv
        # lanes, produce zeros, and are sliced off before the out proj.
        pad = pad_heads_to - h
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        h = kvh = pad_heads_to
    if shard_heads:
        # Padding only pays off if GSPMD actually splits the head dim —
        # the (replicated) projection weights cannot carry that sharding,
        # so constrain the activations explicitly.
        from jax.sharding import PartitionSpec as _P
        U = _P.UNCONSTRAINED
        spec = _P(U, U, "model", U)
        q = jax.lax.with_sharding_constraint(q, spec)
        k = jax.lax.with_sharding_constraint(k, spec)
        v = jax.lax.with_sharding_constraint(v, spec)
    g = h // kvh
    scale = 1.0 / jnp.sqrt(hd).astype(F32)
    cq = min(chunk_q, sq)
    ck = min(chunk_k, sk)
    nq = -(-sq // cq)
    nk = -(-sk // ck)
    sq_p, sk_p = nq * cq, nk * ck
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))

    if not (skip_future and causal):
        # memory-safe custom-VJP path (recompute-based backward)
        flash = _make_flash_vjp(causal, window, cq, ck, sq_p, sk_p,
                                int(q_offset), sk_valid=sk,
                                block_dtype=block_dtype)
        qg_flat = q.reshape(b, sq_p, kvh, g, hd)
        out = flash(qg_flat, k, v)
        out = out.reshape(b, sq_p, h, hd)
        return out[:, :sq, :h_true].astype(q.dtype)

    qg = q.reshape(b, nq, cq, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(b, nk, ck, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, ck, kvh, hd).transpose(1, 0, 2, 3, 4)

    q_pos_base = jnp.asarray(q_offset, jnp.int32)

    def per_q_chunk(qi, qb):
        q_pos = q_pos_base + qi * cq + jnp.arange(cq)          # [cq]

        def kv_step(carry, ki):
            m, l, acc = carry
            kb = kc[ki]
            vb = vc[ki]
            k_pos = ki * ck + jnp.arange(ck)                   # [ck]
            mask = (k_pos[None, :] < sk)
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            s = _attn_block(qb, kb, vb, mask, scale)           # [B,KV,G,cq,ck]
            blk_max = jnp.max(s, axis=-1)
            new_m = jnp.maximum(m, blk_max)
            p = jnp.exp(s - new_m[..., None])
            corr = jnp.exp(m - new_m)
            new_l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqc,bckh->bkgqh", p, vb.astype(F32))
            new_acc = acc * corr[..., None] + pv
            return (new_m, new_l, new_acc), None

        m0 = jnp.full((b, kvh, g, cq), NEG_INF, F32)
        l0 = jnp.zeros((b, kvh, g, cq), F32)
        a0 = jnp.zeros((b, kvh, g, cq, hd), F32)

        if skip_future and causal:
            # dynamic kv bound: only chunks whose start can be visible
            hi = jnp.minimum(
                (q_pos_base + (qi + 1) * cq + ck - 1) // ck, nk)
            lo = jnp.int32(0)
            if window:
                lo = jnp.maximum(
                    (q_pos_base + qi * cq - window) // ck, 0).astype(jnp.int32)

            def body(ki, carry):
                carry, _ = kv_step(carry, ki)
                return carry

            m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out  # [B,KV,G,cq,hd]

    outs = jax.lax.map(lambda qi: per_q_chunk(qi, qg[qi]), jnp.arange(nq))
    # [nq,B,KV,G,cq,hd] -> [B, Sq, H, hd]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq_p, h, hd)
    return out[:, :sq, :h_true].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, window: int = 0):
    """Single-token attention over a KV cache.

    q [B,1,H,hd]; k/v_cache [B,Smax,KV,hd]; cache_len [] or [B] — number of
    valid cache entries (the new token's KV must already be written).
    """
    b, _, h, hd = q.shape
    _, smax, kvh, _ = k_cache.shape
    g = h // kvh
    scale = 1.0 / jnp.sqrt(hd).astype(F32)
    qg = q.reshape(b, kvh, g, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(F32),
                   k_cache.astype(F32)) * scale
    pos = jnp.arange(smax)
    cl = jnp.asarray(cache_len)
    cl = cl[:, None] if cl.ndim == 1 else cl
    mask = pos[None, :] < cl                                  # [B, Smax]
    if window:
        mask = mask & (pos[None, :] >= cl - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(F32))
    return o.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------- projections ---
def qkv_project(params: Params, x, num_heads, num_kv_heads, head_dim):
    """x [B,S,D] -> q [B,S,H,hd], k/v [B,S,KV,hd]."""
    b, s, _ = x.shape
    q = jnp.dot(x, params["wq"], preferred_element_type=F32)
    k = jnp.dot(x, params["wk"], preferred_element_type=F32)
    v = jnp.dot(x, params["wv"], preferred_element_type=F32)
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return (q.reshape(b, s, num_heads, head_dim).astype(x.dtype),
            k.reshape(b, s, num_kv_heads, head_dim).astype(x.dtype),
            v.reshape(b, s, num_kv_heads, head_dim).astype(x.dtype))


def out_project(params: Params, o):
    b, s, h, hd = o.shape
    return jnp.dot(o.reshape(b, s, h * hd), params["wo"],
                   preferred_element_type=F32).astype(o.dtype)
