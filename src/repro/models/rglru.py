"""Griffin / RecurrentGemma recurrent block — arXiv:2402.19427.

Recurrent block = two branches: (linear -> GeLU) gate and
(linear -> causal conv1d(4) -> RG-LRU), merged multiplicatively then
projected out. The RG-LRU recurrence

    r_t = sigmoid(x_t W_r + b_r)
    i_t = sigmoid(x_t W_i + b_i)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

runs as an associative scan over (a, b) pairs for training/prefill and as a
single fused step for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import _causal_conv

F32 = jnp.float32
_C = 8.0  # Griffin's fixed scalar c


def _lru_coeffs(params, x):
    r = jax.nn.sigmoid(jnp.dot(x.astype(F32), params["w_r"]) + params["b_r"])
    i = jax.nn.sigmoid(jnp.dot(x.astype(F32), params["w_i"]) + params["b_i"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r      # [B,S,W] (<=0)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * x.astype(F32))
    return a, gated


def rg_lru(params, x, h0=None):
    """x [B,S,W] -> (y [B,S,W], h_last [B,W]) via associative scan."""
    a, b = _lru_coeffs(params, x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(F32))
    _, ys = jax.lax.associative_scan(combine, (a, b), axis=1)
    return ys.astype(x.dtype), ys[:, -1]


def rg_lru_step(params, x, h):
    """Single-token update. x [B,1,W], h [B,W]."""
    a, b = _lru_coeffs(params, x)
    h_new = a[:, 0] * h.astype(F32) + b[:, 0]
    return h_new[:, None].astype(x.dtype), h_new


def recurrent_block(params, x, decode_state=None):
    """Griffin recurrent block. x [B,S,D].

    params: w_gate [D,W], w_branch [D,W], conv_w [K,W], conv_b [W],
            lru (w_r, w_i, b_r, b_i, lam), w_out [W,D].
    decode_state: (conv_buf [B,K,W], h [B,W]) or None.
    """
    gate = jax.nn.gelu(jnp.dot(x, params["w_gate"],
                               preferred_element_type=F32))
    br = jnp.dot(x, params["w_branch"], preferred_element_type=F32) \
        .astype(x.dtype)
    if decode_state is not None:
        conv_buf, h = decode_state
        conv_buf = jnp.concatenate([conv_buf[:, 1:], br], axis=1)
        c = jnp.einsum("bkc,kc->bc", conv_buf.astype(F32),
                       params["conv_w"].astype(F32)) + params["conv_b"]
        c = c[:, None].astype(x.dtype)
        y, h_new = rg_lru_step(params["lru"], c, h)
        new_state = (conv_buf, h_new)
    else:
        c = _causal_conv(br, params["conv_w"], params["conv_b"])
        y, h_last = rg_lru(params["lru"], c)
        new_state = h_last
    out = jnp.dot((y.astype(F32) * gate).astype(x.dtype), params["w_out"],
                  preferred_element_type=F32)
    return out.astype(x.dtype), new_state
