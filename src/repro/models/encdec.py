"""Encoder-decoder family (whisper-small backbone).

Per the shape card the audio conv frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings [B, 1500, D] straight into the encoder stack.
Whisper conventions: LayerNorm (with bias), plain GELU MLP, sinusoidal
positions on the encoder, learned positions on the decoder, full (MHA)
attention, cross-attention from every decoder layer into the encoder output.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

F32 = jnp.float32
Params = dict[str, Any]


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def sinusoids(length: int, channels: int):
    t = jnp.arange(length, dtype=F32)[:, None]
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(channels // 2, dtype=F32)
                  / (channels // 2 - 1))
    ang = t * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_ln(d):
    return dict(scale=jnp.ones((d,), F32), bias=jnp.zeros((d,), F32))


def _init_attn(rng, cfg, dt):
    d, h, kv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    ks = jax.random.split(rng, 4)
    sc = d ** -0.5
    return dict(
        wq=(jax.random.normal(ks[0], (d, h * hd)) * sc).astype(dt),
        wk=(jax.random.normal(ks[1], (d, kv * hd)) * sc).astype(dt),
        wv=(jax.random.normal(ks[2], (d, kv * hd)) * sc).astype(dt),
        wo=(jax.random.normal(ks[3], (h * hd, d)) * (h * hd) ** -0.5
            ).astype(dt),
        bq=jnp.zeros((h * hd,), dt), bk=jnp.zeros((kv * hd,), dt),
        bv=jnp.zeros((kv * hd,), dt),
    )


def _init_mlp(rng, cfg, dt):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 2)
    return dict(
        w1=(jax.random.normal(ks[0], (d, f)) * d ** -0.5).astype(dt),
        b1=jnp.zeros((f,), dt),
        w2=(jax.random.normal(ks[1], (f, d)) * f ** -0.5).astype(dt),
        b2=jnp.zeros((d,), dt),
    )


def _init_enc_block(rng, cfg, dt):
    ks = jax.random.split(rng, 2)
    return dict(ln1=_init_ln(cfg.d_model), attn=_init_attn(ks[0], cfg, dt),
                ln2=_init_ln(cfg.d_model), mlp=_init_mlp(ks[1], cfg, dt))


def _init_dec_block(rng, cfg, dt):
    ks = jax.random.split(rng, 3)
    return dict(
        ln1=_init_ln(cfg.d_model), self_attn=_init_attn(ks[0], cfg, dt),
        ln2=_init_ln(cfg.d_model), cross_attn=_init_attn(ks[1], cfg, dt),
        ln3=_init_ln(cfg.d_model), mlp=_init_mlp(ks[2], cfg, dt),
    )


def init_params(cfg: ModelConfig, rng) -> Params:
    dt = _dt(cfg)
    ks = jax.random.split(rng, 6)

    def stack(fn, r, n):
        blocks = [fn(jax.random.fold_in(r, i)) for i in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)

    return dict(
        embed=(jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model))
               * 0.02).astype(dt),
        dec_pos=(jax.random.normal(ks[1], (4096, cfg.d_model)) * 0.01
                 ).astype(dt),
        enc_blocks=stack(lambda r: _init_enc_block(r, cfg, dt), ks[2],
                         cfg.encoder_layers),
        dec_blocks=stack(lambda r: _init_dec_block(r, cfg, dt), ks[3],
                         cfg.num_layers),
        enc_ln=_init_ln(cfg.d_model),
        dec_ln=_init_ln(cfg.d_model),
    )


def abstract_params(cfg: ModelConfig) -> Params:
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.key(0))


def _mha(attn, q_src, kv_src, cfg, causal, decode=None):
    b, s, _ = q_src.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (jnp.dot(q_src, attn["wq"], preferred_element_type=F32)
         + attn["bq"]).reshape(b, s, h, hd).astype(q_src.dtype)
    k = (jnp.dot(kv_src, attn["wk"], preferred_element_type=F32)
         + attn["bk"]).reshape(b, kv_src.shape[1], kv, hd).astype(q_src.dtype)
    v = (jnp.dot(kv_src, attn["wv"], preferred_element_type=F32)
         + attn["bv"]).reshape(b, kv_src.shape[1], kv, hd).astype(q_src.dtype)
    if decode is not None:
        k_cache, v_cache, cache_len = decode
        bidx = jnp.arange(b)
        k_cache = k_cache.at[bidx, cache_len].set(k[:, 0])
        v_cache = v_cache.at[bidx, cache_len].set(v[:, 0])
        o = L.decode_attention(q, k_cache, v_cache, cache_len + 1)
        return L.out_project(attn, o), (k_cache, v_cache)
    o = L.flash_attention(q, k, v, causal=causal, skip_future=False)
    return L.out_project(attn, o), None


def encode(cfg: ModelConfig, params: Params, frames):
    """frames [B, T, D] (stubbed conv-frontend output) -> [B, T, D]."""
    x = frames.astype(_dt(cfg)) + sinusoids(
        frames.shape[1], cfg.d_model).astype(_dt(cfg))[None]

    def blk(x, p):
        h = L.layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"])
        o, _ = _mha(p["attn"], h, h, cfg, causal=False)
        x = x + o
        h = L.layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"])
        x = x + L.dense_mlp(p["mlp"], h, "gelu")
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(blk), x, params["enc_blocks"])
    return L.layer_norm(x, params["enc_ln"]["scale"], params["enc_ln"]["bias"])


def forward(cfg: ModelConfig, params: Params, tokens, *, frontend_embeds,
            remat: bool = True, skip_future: bool = True):
    """Teacher-forced decoder logits. tokens [B, S]; frontend [B, T, D]."""
    enc = encode(cfg, params, frontend_embeds)
    b, s = tokens.shape
    dt = _dt(cfg)
    pos = params["dec_pos"]
    if s > pos.shape[0]:  # extend learned positions by tiling (32k prefill)
        pos = jnp.concatenate([pos] * (-(-s // pos.shape[0])), axis=0)
    x = params["embed"][tokens].astype(dt) + pos[:s][None]

    def blk(x, p):
        h = L.layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"])
        o, _ = _mha(p["self_attn"], h, h, cfg, causal=True)
        x = x + o
        h = L.layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"])
        o, _ = _mha(p["cross_attn"], h, enc, cfg, causal=False)
        x = x + o
        h = L.layer_norm(x, p["ln3"]["scale"], p["ln3"]["bias"])
        x = x + L.dense_mlp(p["mlp"], h, "gelu")
        return x, None

    fn = jax.checkpoint(blk) if remat else blk
    x, _ = jax.lax.scan(fn, x, params["dec_blocks"])
    x = L.layer_norm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"])
    logits = jnp.dot(x, params["embed"].T, preferred_element_type=F32)
    return logits, 0.0


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               frontend_tokens: int = 0, dtype=None) -> Params:
    dt = dtype or _dt(cfg)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    t = frontend_tokens or cfg.num_frontend_tokens
    nl = cfg.num_layers
    return dict(
        cache_len=jnp.zeros((batch,), jnp.int32),
        k=jnp.zeros((nl, batch, max_seq, kv, hd), dt),
        v=jnp.zeros((nl, batch, max_seq, kv, hd), dt),
        cross_k=jnp.zeros((nl, batch, t, kv, hd), dt),
        cross_v=jnp.zeros((nl, batch, t, kv, hd), dt),
    )


def decode_step(cfg: ModelConfig, params: Params, cache: Params, token):
    """One decoder step against precomputed cross KV."""
    dt = _dt(cfg)
    b = token.shape[0]
    cache_len = cache["cache_len"]
    pos = params["dec_pos"]
    pidx = jnp.mod(cache_len, pos.shape[0])
    x = params["embed"][token].astype(dt) + pos[pidx][:, None]
    new_cache = dict(cache)

    def blk(x, scanned):
        p, kc, vc, ck, cv = scanned
        h = L.layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"])
        o, (kc, vc) = _mha(p["self_attn"], h, h, cfg, causal=True,
                           decode=(kc, vc, cache_len))
        x = x + o
        h = L.layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"])
        hq = cfg.num_heads
        hd = cfg.resolved_head_dim
        q = (jnp.dot(h, p["cross_attn"]["wq"], preferred_element_type=F32)
             + p["cross_attn"]["bq"]).reshape(b, 1, hq, hd).astype(dt)
        o = L.decode_attention(q, ck, cv,
                               jnp.full((b,), ck.shape[1], jnp.int32))
        x = x + L.out_project(p["cross_attn"], o)
        h = L.layer_norm(x, p["ln3"]["scale"], p["ln3"]["bias"])
        x = x + L.dense_mlp(p["mlp"], h, "gelu")
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        blk, x, (params["dec_blocks"], cache["k"], cache["v"],
                 cache["cross_k"], cache["cross_v"]))
    new_cache["k"], new_cache["v"] = ks, vs
    x = L.layer_norm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"])
    logits = jnp.dot(x, params["embed"].T, preferred_element_type=F32)
    new_cache["cache_len"] = cache_len + 1
    return logits, new_cache
