"""Unified model API over all 10 architectures.

    cfg = get_config("qwen2-7b")
    params = model.init_params(cfg, rng)          # or abstract_params(cfg)
    logits, aux = model.forward(cfg, params, batch)
    loss = model.loss_fn(cfg, params, batch)
    cache = model.init_cache(cfg, batch=8, max_seq=1024)
    logits, cache = model.decode_step(cfg, params, cache, token)

``batch`` is a dict: tokens [B,S] int32, labels [B,S] int32 (-1 = masked),
and frontend_embeds [B,T,D] for audio/vision archs (stubbed embeddings per
the shape card).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer

F32 = jnp.float32


def _mod(cfg: ModelConfig):
    return encdec if cfg.family == "encdec" else transformer


def init_params(cfg: ModelConfig, rng):
    return _mod(cfg).init_params(cfg, rng)


def abstract_params(cfg: ModelConfig):
    return _mod(cfg).abstract_params(cfg)


def needs_frontend(cfg: ModelConfig) -> bool:
    return cfg.num_frontend_tokens > 0


def forward(cfg: ModelConfig, params, batch, *, remat: bool = True,
            skip_future: bool = False, opts: dict | None = None):
    if cfg.family == "encdec":
        return encdec.forward(cfg, params, batch["tokens"],
                              frontend_embeds=batch["frontend_embeds"],
                              remat=remat)
    return transformer.forward(cfg, params, batch["tokens"],
                               frontend_embeds=batch.get("frontend_embeds"),
                               remat=remat, skip_future=skip_future,
                               opts=opts)


def loss_fn(cfg: ModelConfig, params, batch, *, remat: bool = True,
            skip_future: bool = False, aux_weight: float = 0.01,
            opts: dict | None = None):
    logits, aux = forward(cfg, params, batch, remat=remat,
                          skip_future=skip_future, opts=opts)
    labels = batch["labels"]
    mask = labels >= 0
    logits = logits.astype(F32)
    # Vocab-sharding-safe CE: take_along_axis over a model-sharded vocab dim
    # would make SPMD all-gather the full [B,S,V] logits (tens of GB).
    # A broadcasted-iota one-hot select keeps every op sharded over V.
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    onehot = vocab_iota == jnp.maximum(labels, 0)[..., None]
    picked = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = jnp.where(mask, lse - picked, 0.0)
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
    return loss + aux_weight * aux


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, **kw):
    return _mod(cfg).init_cache(cfg, batch, max_seq, **kw)


def decode_step(cfg: ModelConfig, params, cache, token,
                opts: dict | None = None):
    if cfg.family == "encdec":
        return encdec.decode_step(cfg, params, cache, token)
    return transformer.decode_step(cfg, params, cache, token, opts)


def make_batch(cfg: ModelConfig, batch: int, seq: int, rng=None,
               abstract: bool = False):
    """Concrete (or abstract) training batch for this arch."""
    t_front = cfg.num_frontend_tokens
    if abstract:
        out = dict(
            tokens=jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            labels=jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        )
        if t_front:
            out["frontend_embeds"] = jax.ShapeDtypeStruct(
                (batch, t_front, cfg.d_model), jnp.dtype(cfg.dtype))
        return out
    rng = rng if rng is not None else jax.random.key(0)
    k1, k2, k3 = jax.random.split(rng, 3)
    out = dict(
        tokens=jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size,
                                  jnp.int32),
        labels=jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size,
                                  jnp.int32),
    )
    if t_front:
        out["frontend_embeds"] = (jax.random.normal(
            k3, (batch, t_front, cfg.d_model)) * 0.02).astype(cfg.dtype)
    return out
