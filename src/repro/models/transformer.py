"""Decoder-only model assembly for the dense / moe / vlm / hybrid / ssm
families. One module builds init, forward (train / prefill), and single-token
decode from a ``ModelConfig``.

Structure notes (DESIGN.md §3):
  * every homogeneous layer stack is ``lax.scan``'d over stacked params
    ([L, ...] leading axis) so HLO size is O(1) in depth;
  * heterogeneous wiring (vlm cross-attn every N, hybrid R/R/A pattern) scans
    over *superblocks* with the pattern unrolled inside;
  * decode carries a cache pytree whose shape depends only on the config and
    max sequence length (ring-buffered local windows for hybrid; constant
    SSM state for mamba — that is what makes long_500k runnable there).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.moe import moe_ffn
from repro.models.rglru import recurrent_block
from repro.models.ssm import mamba2_block

F32 = jnp.float32
Params = dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# =================================================================== init ===
def _init_attn(rng, cfg: ModelConfig, dt):
    d, h, kv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    ks = jax.random.split(rng, 4)
    sc = d ** -0.5
    p = dict(
        wq=(jax.random.normal(ks[0], (d, h * hd)) * sc).astype(dt),
        wk=(jax.random.normal(ks[1], (d, kv * hd)) * sc).astype(dt),
        wv=(jax.random.normal(ks[2], (d, kv * hd)) * sc).astype(dt),
        wo=(jax.random.normal(ks[3], (h * hd, d)) * (h * hd) ** -0.5).astype(dt),
    )
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((h * hd,), dt), bk=jnp.zeros((kv * hd,), dt),
                 bv=jnp.zeros((kv * hd,), dt))
    return p


def _init_mlp(rng, cfg: ModelConfig, dt):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.mlp_act == "gelu_mlp":      # plain 2-matrix MLP (whisper)
        return dict(
            w1=(jax.random.normal(ks[0], (d, f)) * d ** -0.5).astype(dt),
            b1=jnp.zeros((f,), dt),
            w2=(jax.random.normal(ks[1], (f, d)) * f ** -0.5).astype(dt),
            b2=jnp.zeros((d,), dt),
        )
    return dict(
        wg=(jax.random.normal(ks[0], (d, f)) * d ** -0.5).astype(dt),
        wu=(jax.random.normal(ks[1], (d, f)) * d ** -0.5).astype(dt),
        wd=(jax.random.normal(ks[2], (f, d)) * f ** -0.5).astype(dt),
    )


def _init_moe(rng, cfg: ModelConfig, dt):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(rng, 4)
    return dict(
        wr=(jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(F32),
        wg=(jax.random.normal(ks[1], (e, d, f)) * d ** -0.5).astype(dt),
        wu=(jax.random.normal(ks[2], (e, d, f)) * d ** -0.5).astype(dt),
        wd=(jax.random.normal(ks[3], (e, f, d)) * f ** -0.5).astype(dt),
    )


def _init_dense_block(rng, cfg: ModelConfig, dt, moe: bool):
    ks = jax.random.split(rng, 3)
    blk = dict(
        norm1=jnp.zeros((cfg.d_model,), F32),
        attn=_init_attn(ks[0], cfg, dt),
        norm2=jnp.zeros((cfg.d_model,), F32),
    )
    if moe:
        blk["moe"] = _init_moe(ks[1], cfg, dt)
    else:
        blk["mlp"] = _init_mlp(ks[1], cfg, dt)
    return blk


def _init_cross_block(rng, cfg: ModelConfig, dt):
    ks = jax.random.split(rng, 3)
    return dict(
        norm1=jnp.zeros((cfg.d_model,), F32),
        attn=_init_attn(ks[0], cfg, dt),
        norm2=jnp.zeros((cfg.d_model,), F32),
        mlp=_init_mlp(ks[1], cfg, dt),
        gate=jnp.zeros((), F32),          # gated cross-attn (llama3.2-vision)
    )


def _init_recurrent_block(rng, cfg: ModelConfig, dt):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(rng, 6)
    return dict(
        norm1=jnp.zeros((d,), F32),
        w_gate=(jax.random.normal(ks[0], (d, w)) * d ** -0.5).astype(dt),
        w_branch=(jax.random.normal(ks[1], (d, w)) * d ** -0.5).astype(dt),
        conv_w=(jax.random.normal(ks[2], (cfg.ssm_conv, w)) * 0.1).astype(dt),
        conv_b=jnp.zeros((w,), dt),
        lru=dict(
            w_r=(jax.random.normal(ks[3], (w, w)) * w ** -0.5).astype(F32),
            w_i=(jax.random.normal(ks[4], (w, w)) * w ** -0.5).astype(F32),
            b_r=jnp.zeros((w,), F32), b_i=jnp.zeros((w,), F32),
            lam=jnp.full((w,), 0.5, F32),
        ),
        w_out=(jax.random.normal(ks[5], (w, d)) * w ** -0.5).astype(dt),
        norm2=jnp.zeros((d,), F32),
        mlp=_init_mlp(jax.random.fold_in(rng, 7), cfg, dt),
    )


def _init_ssm_block(rng, cfg: ModelConfig, dt):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_headdim
    ks = jax.random.split(rng, 3)
    z_dim = 2 * di + 2 * n + nh
    return dict(
        norm1=jnp.zeros((d,), F32),
        w_in=(jax.random.normal(ks[0], (d, z_dim)) * d ** -0.5).astype(dt),
        conv_w=(jax.random.normal(ks[1], (cfg.ssm_conv, di + 2 * n)) * 0.1
                ).astype(dt),
        conv_b=jnp.zeros((di + 2 * n,), dt),
        A_log=jnp.zeros((nh,), F32),
        dt_bias=jnp.zeros((nh,), F32),
        D_skip=jnp.ones((nh,), F32),
        norm_scale=jnp.zeros((di,), F32),
        w_out=(jax.random.normal(ks[2], (di, d)) * di ** -0.5).astype(dt),
    )


def _stack(init_fn, rng, n: int):
    """Initialize n blocks and stack leaves on a leading axis."""
    blocks = [init_fn(jax.random.fold_in(rng, i)) for i in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


def init_params(cfg: ModelConfig, rng) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 8)
    params: Params = dict(
        embed=(jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model))
               * 0.02).astype(dt),
        final_norm=jnp.zeros((cfg.d_model,), F32),
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            ks[1], (cfg.d_model, cfg.vocab_size)) * cfg.d_model ** -0.5
        ).astype(dt)

    fam = cfg.family
    if fam in ("dense", "moe"):
        params["blocks"] = _stack(
            lambda r: _init_dense_block(r, cfg, dt, fam == "moe"),
            ks[2], cfg.num_layers)
    elif fam == "vlm":
        every = cfg.cross_attn_every
        n_super = cfg.num_layers // every
        params["blocks"] = _stack(
            lambda r: jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[_init_dense_block(jax.random.fold_in(r, i), cfg, dt, False)
                  for i in range(every)]),
            ks[2], n_super)
        params["cross_blocks"] = _stack(
            lambda r: _init_cross_block(r, cfg, dt), ks[3], n_super)
    elif fam == "hybrid":
        pat = cfg.block_pattern
        n_super = cfg.num_layers // len(pat)
        tail = cfg.num_layers - n_super * len(pat)

        def init_super(r):
            out = {}
            for i, c in enumerate(pat):
                ri = jax.random.fold_in(r, i)
                out[f"b{i}"] = (_init_recurrent_block(ri, cfg, dt) if c == "R"
                                else _init_dense_block(ri, cfg, dt, False))
            return out

        params["blocks"] = _stack(init_super, ks[2], n_super)
        for i in range(tail):
            c = pat[i % len(pat)]
            ri = jax.random.fold_in(ks[4], i)
            params[f"tail{i}"] = (
                _init_recurrent_block(ri, cfg, dt) if c == "R"
                else _init_dense_block(ri, cfg, dt, False))
    elif fam == "ssm":
        params["blocks"] = _stack(lambda r: _init_ssm_block(r, cfg, dt),
                                  ks[2], cfg.num_layers)
    else:
        raise ValueError(f"family {fam} not handled here")
    return params


def abstract_params(cfg: ModelConfig) -> Params:
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(0))


# ================================================================ forward ===
def _self_attn(blk, x, positions, cfg: ModelConfig, window: int = 0,
               decode=None, causal: bool = True,
               skip_future: bool = False, rope: bool = True,
               opts: dict | None = None):
    h = L.rms_norm(x, blk["norm1"], cfg.norm_eps)
    q, k, v = L.qkv_project(blk["attn"], h, cfg.num_heads,
                            cfg.num_kv_heads, cfg.resolved_head_dim)
    if rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    if decode is not None:
        k_cache, v_cache, cache_len = decode
        # write current kv at position cache_len (ring-buffer for windows)
        idx = jnp.mod(cache_len, k_cache.shape[1])
        bidx = jnp.arange(k.shape[0])
        k_cache = k_cache.at[bidx, idx].set(k[:, 0])
        v_cache = v_cache.at[bidx, idx].set(v[:, 0])
        if window and k_cache.shape[1] <= window:
            # ring buffer holds exactly the window: everything valid
            valid = jnp.minimum(cache_len + 1, k_cache.shape[1])
            o = L.decode_attention(q, k_cache, v_cache, valid, window=0)
        else:
            o = L.decode_attention(q, k_cache, v_cache, cache_len + 1,
                                   window=window)
        out = x + L.out_project(blk["attn"], o)
        return out, (k_cache, v_cache)
    opts = opts or {}
    o = L.flash_attention(
        q, k, v, q_offset=0, causal=causal, window=window,
        skip_future=skip_future,
        pad_heads_to=opts.get("pad_heads_to", 0),
        block_dtype=opts.get("attn_block_dtype", "float32"),
        shard_heads=opts.get("shard_attn_heads", False))
    return x + L.out_project(blk["attn"], o), None


def _ffn(blk, x, cfg: ModelConfig, opts: dict | None = None):
    opts = opts or {}
    h = L.rms_norm(x, blk["norm2"], cfg.norm_eps)
    if "moe" in blk:
        y, aux = moe_ffn(blk["moe"], h, num_experts=cfg.num_experts,
                         experts_per_token=cfg.experts_per_token,
                         capacity_factor=cfg.capacity_factor,
                         act=cfg.mlp_act,
                         impl=opts.get("moe_impl", "sort"),
                         shard_experts=opts.get("moe_shard_experts", False))
        return x + y, aux
    if cfg.mlp_act == "gelu_mlp":
        return x + L.dense_mlp(blk["mlp"], h, "gelu"), 0.0
    return x + L.gated_mlp(blk["mlp"], h, cfg.mlp_act), 0.0


def _cross_attn(blk, x, kv_src, cfg: ModelConfig):
    """Gated cross-attention to (precomputed) vision embeddings."""
    h = L.rms_norm(x, blk["norm1"], cfg.norm_eps)
    q, k, v = L.qkv_project(blk["attn"], h, cfg.num_heads,
                            cfg.num_kv_heads, cfg.resolved_head_dim)
    # kv from the frontend embeds
    b, t, _ = kv_src.shape
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = jnp.dot(kv_src, blk["attn"]["wk"], preferred_element_type=F32) \
        .reshape(b, t, kvh, hd).astype(x.dtype)
    v = jnp.dot(kv_src, blk["attn"]["wv"], preferred_element_type=F32) \
        .reshape(b, t, kvh, hd).astype(x.dtype)
    o = L.flash_attention(q, k, v, causal=False, skip_future=False)
    x = x + (jnp.tanh(blk["gate"])
             * L.out_project(blk["attn"], o)).astype(x.dtype)
    y, _ = _ffn(blk, x, cfg)
    return y


def _rec_block(blk, x, cfg: ModelConfig, decode_state=None):
    h = L.rms_norm(x, blk["norm1"], cfg.norm_eps)
    y, new_state = recurrent_block(blk, h, decode_state)
    x = x + y
    y2, _ = _ffn(blk, x, cfg)
    return y2, new_state


def _ssm_block(blk, x, cfg: ModelConfig, decode_state=None):
    h = L.rms_norm(x, blk["norm1"], cfg.norm_eps)
    y, new_state = mamba2_block(blk, h, headdim=cfg.ssm_headdim,
                                d_state=cfg.ssm_state, chunk=cfg.ssm_chunk,
                                decode_state=decode_state)
    return x + y, new_state


# ------------------------------------------------------------- full pass ---
def forward(cfg: ModelConfig, params: Params, tokens, *,
            frontend_embeds=None, remat: bool = True,
            skip_future: bool = False, opts: dict | None = None):
    """Token logits for train/prefill. tokens [B, S] -> logits [B, S, V].

    Returns (logits, aux_loss).
    """
    dt = _dtype(cfg)
    b, s = tokens.shape
    x = params["embed"][tokens].astype(dt)
    if cfg.family in ("dense", "moe", "vlm", "hybrid"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt) if cfg.tie_embeddings else x
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    aux_total = 0.0
    opts = opts or {}

    fam = cfg.family
    if fam in ("dense", "moe"):
        def blk_fn(x, blk):
            x, _ = _self_attn(blk, x, positions, cfg,
                              skip_future=skip_future, opts=opts)
            x, aux = _ffn(blk, x, cfg, opts)
            return x, aux
        if remat:
            blk_fn = jax.checkpoint(blk_fn)
        x, auxs = jax.lax.scan(blk_fn, x, params["blocks"])
        aux_total = jnp.sum(auxs)
    elif fam == "vlm":
        def super_fn(x, blks):
            selfs, cross = blks
            def inner(x, blk):
                x, _ = _self_attn(blk, x, positions, cfg,
                                  skip_future=skip_future, opts=opts)
                x, _ = _ffn(blk, x, cfg, opts)
                return x, 0.0
            x, _ = jax.lax.scan(inner, x, selfs)
            x = _cross_attn(cross, x, frontend_embeds, cfg)
            return x, 0.0
        if remat:
            super_fn = jax.checkpoint(super_fn)
        x, _ = jax.lax.scan(super_fn, x,
                            (params["blocks"], params["cross_blocks"]))
    elif fam == "hybrid":
        pat = cfg.block_pattern

        def super_fn(x, blks):
            for i, c in enumerate(pat):
                blk = blks[f"b{i}"]
                if c == "R":
                    x, _ = _rec_block(blk, x, cfg)
                else:
                    x, _ = _self_attn(blk, x, positions, cfg,
                                      window=cfg.local_window,
                                      skip_future=skip_future, opts=opts)
                    x, _ = _ffn(blk, x, cfg, opts)
            return x, 0.0
        if remat:
            super_fn = jax.checkpoint(super_fn)
        x, _ = jax.lax.scan(super_fn, x, params["blocks"])
        i = 0
        while f"tail{i}" in params:
            blk = params[f"tail{i}"]
            c = pat[i % len(pat)]
            if c == "R":
                x, _ = _rec_block(blk, x, cfg)
            else:
                x, _ = _self_attn(blk, x, positions, cfg,
                                  window=cfg.local_window,
                                  skip_future=skip_future, opts=opts)
                x, _ = _ffn(blk, x, cfg, opts)
            i += 1
    elif fam == "ssm":
        def blk_fn(x, blk):
            x, _ = _ssm_block(blk, x, cfg)
            return x, 0.0
        if remat:
            blk_fn = jax.checkpoint(blk_fn)
        x, _ = jax.lax.scan(blk_fn, x, params["blocks"])
    else:
        raise ValueError(fam)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.dot(x, head, preferred_element_type=F32)
    return logits, aux_total


# ================================================================= decode ===
def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               frontend_tokens: int = 0, dtype=None) -> Params:
    """Decode cache pytree (shapes only depend on config/batch/max_seq)."""
    dt = dtype or _dtype(cfg)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    fam = cfg.family
    cache: Params = dict(cache_len=jnp.zeros((batch,), jnp.int32))
    if fam in ("dense", "moe"):
        cache["k"] = jnp.zeros((cfg.num_layers, batch, max_seq, kv, hd), dt)
        cache["v"] = jnp.zeros((cfg.num_layers, batch, max_seq, kv, hd), dt)
    elif fam == "vlm":
        every = cfg.cross_attn_every
        n_super = cfg.num_layers // every
        cache["k"] = jnp.zeros((n_super, every, batch, max_seq, kv, hd), dt)
        cache["v"] = jnp.zeros((n_super, every, batch, max_seq, kv, hd), dt)
        t = frontend_tokens or cfg.num_frontend_tokens
        cache["cross_k"] = jnp.zeros((n_super, batch, t, kv, hd), dt)
        cache["cross_v"] = jnp.zeros((n_super, batch, t, kv, hd), dt)
    elif fam == "hybrid":
        pat = cfg.block_pattern
        n_super = cfg.num_layers // len(pat)
        n_attn = sum(c == "A" for c in pat)
        n_rec = sum(c == "R" for c in pat)
        w = cfg.lru_width or cfg.d_model
        win = min(cfg.local_window, max_seq)
        cache["k"] = jnp.zeros((n_super, n_attn, batch, win, kv, hd), dt)
        cache["v"] = jnp.zeros((n_super, n_attn, batch, win, kv, hd), dt)
        cache["lru_h"] = jnp.zeros((n_super, n_rec, batch, w), F32)
        cache["conv"] = jnp.zeros((n_super, n_rec, batch, cfg.ssm_conv, w), dt)
        tail = cfg.num_layers - n_super * len(pat)
        for i in range(tail):
            c = pat[i % len(pat)]
            if c == "R":
                cache[f"tail{i}_h"] = jnp.zeros((batch, w), F32)
                cache[f"tail{i}_conv"] = jnp.zeros(
                    (batch, cfg.ssm_conv, w), dt)
            else:
                cache[f"tail{i}_k"] = jnp.zeros((batch, win, kv, hd), dt)
                cache[f"tail{i}_v"] = jnp.zeros((batch, win, kv, hd), dt)
    elif fam == "ssm":
        di = cfg.ssm_expand * cfg.d_model
        nh = di // cfg.ssm_headdim
        cache["conv"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_conv, di + 2 * cfg.ssm_state), dt)
        cache["h"] = jnp.zeros(
            (cfg.num_layers, batch, nh, cfg.ssm_headdim, cfg.ssm_state), F32)
    return cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params, token,
                opts: dict | None = None):
    """One decode step. token [B, 1] -> (logits [B, 1, V], new cache)."""
    opts = opts or {}
    dt = _dtype(cfg)
    b = token.shape[0]
    x = params["embed"][token].astype(dt)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    cache_len = cache["cache_len"]
    positions = cache_len[:, None]
    fam = cfg.family
    new_cache = dict(cache)

    if fam in ("dense", "moe"):
        if opts.get("decode_cache_in_carry"):
            # Cache as scan CARRY with per-layer in-place DUS: the xs/ys
            # path stacks a fresh full-cache copy every step (2x cache
            # HBM traffic; see EXPERIMENTS §Perf decode iteration).
            def blk_fn(carry, blk):
                x, kall, vall, li = carry
                kc = jax.lax.dynamic_index_in_dim(kall, li, 0, False)
                vc = jax.lax.dynamic_index_in_dim(vall, li, 0, False)
                x, (kc, vc) = _self_attn(blk, x, positions, cfg,
                                         decode=(kc, vc, cache_len))
                kall = jax.lax.dynamic_update_slice_in_dim(
                    kall, kc[None], li, axis=0)
                vall = jax.lax.dynamic_update_slice_in_dim(
                    vall, vc[None], li, axis=0)
                x, _ = _ffn(blk, x, cfg, opts)
                return (x, kall, vall, li + 1), None
            (x, ks, vs, _), _ = jax.lax.scan(
                blk_fn, (x, cache["k"], cache["v"], jnp.int32(0)),
                params["blocks"])
        else:
            def blk_fn(x, scanned):
                blk, kc, vc = scanned
                x, (kc, vc) = _self_attn(blk, x, positions, cfg,
                                         decode=(kc, vc, cache_len))
                x, _ = _ffn(blk, x, cfg, opts)
                return x, (kc, vc)
            x, (ks, vs) = jax.lax.scan(
                blk_fn, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = ks, vs
    elif fam == "vlm":
        def super_fn(x, scanned):
            blks, cross, kc, vc, ck, cv = scanned
            def inner(x, inner_s):
                blk, kci, vci = inner_s
                x, (kci, vci) = _self_attn(blk, x, positions, cfg,
                                           decode=(kci, vci, cache_len))
                x, _ = _ffn(blk, x, cfg)
                return x, (kci, vci)
            x, (kc, vc) = jax.lax.scan(inner, x, (blks, kc, vc))
            # cross attention against precomputed cross kv
            h = L.rms_norm(x, cross["norm1"], cfg.norm_eps)
            q, _, _ = L.qkv_project(cross["attn"], h, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.resolved_head_dim)
            o = L.decode_attention(q, ck, cv,
                                   jnp.full((b,), ck.shape[1], jnp.int32))
            x = x + (jnp.tanh(cross["gate"])
                     * L.out_project(cross["attn"], o)).astype(x.dtype)
            x, _ = _ffn(cross, x, cfg)
            return x, (kc, vc)
        x, (ks, vs) = jax.lax.scan(
            super_fn, x, (params["blocks"], params["cross_blocks"],
                          cache["k"], cache["v"],
                          cache["cross_k"], cache["cross_v"]))
        new_cache["k"], new_cache["v"] = ks, vs
    elif fam == "hybrid":
        pat = cfg.block_pattern

        def super_fn(x, scanned):
            blks, kc, vc, hs, conv = scanned
            ai = ri = 0
            kc_n, vc_n, hs_n, conv_n = list(kc), list(vc), list(hs), list(conv)
            for i, c in enumerate(pat):
                blk = blks[f"b{i}"]
                if c == "R":
                    x, (cb, hh) = _rec_block(blk, x, cfg,
                                             (conv[ri], hs[ri]))
                    conv_n[ri], hs_n[ri] = cb, hh
                    ri += 1
                else:
                    x, (kk, vv) = _self_attn(blk, x, positions, cfg,
                                             window=cfg.local_window,
                                             decode=(kc[ai], vc[ai],
                                                     cache_len))
                    kc_n[ai], vc_n[ai] = kk, vv
                    x, _ = _ffn(blk, x, cfg)
                    ai += 1
            return x, (jnp.stack(kc_n), jnp.stack(vc_n),
                       jnp.stack(hs_n), jnp.stack(conv_n))
        x, (ks, vs, hs, conv) = jax.lax.scan(
            super_fn, x, (params["blocks"], cache["k"], cache["v"],
                          cache["lru_h"], cache["conv"]))
        new_cache.update(k=ks, v=vs, lru_h=hs, conv=conv)
        i = 0
        while f"tail{i}" in params:
            blk = params[f"tail{i}"]
            c = pat[i % len(pat)]
            if c == "R":
                x, (cb, hh) = _rec_block(
                    blk, x, cfg, (cache[f"tail{i}_conv"],
                                  cache[f"tail{i}_h"]))
                new_cache[f"tail{i}_conv"] = cb
                new_cache[f"tail{i}_h"] = hh
            else:
                x, (kk, vv) = _self_attn(
                    blk, x, positions, cfg, window=cfg.local_window,
                    decode=(cache[f"tail{i}_k"], cache[f"tail{i}_v"],
                            cache_len))
                new_cache[f"tail{i}_k"] = kk
                new_cache[f"tail{i}_v"] = vv
                x, _ = _ffn(blk, x, cfg)
            i += 1
    elif fam == "ssm":
        def blk_fn(x, scanned):
            blk, conv, h = scanned
            x, (conv, h) = _ssm_block(blk, x, cfg, (conv, h))
            return x, (conv, h)
        x, (conv, h) = jax.lax.scan(
            blk_fn, x, (params["blocks"], cache["conv"], cache["h"]))
        new_cache["conv"], new_cache["h"] = conv, h
    else:
        raise ValueError(fam)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.dot(x, head, preferred_element_type=F32)
    new_cache["cache_len"] = cache_len + 1
    return logits, new_cache
