"""Shared quantization: one audited quantizer for gradient sync AND corpus
compression, plus the quantized corpus representations the search path scores.

Two consumers, one quantizer
----------------------------

* ``distributed.compression.compressed_psum`` (gradient all-reduce) uses the
  flat block quantizer — :data:`BLOCK`, :func:`quantize_blocks`,
  :func:`block_view` — exactly as it always did (they simply moved here, so
  wire-format and corpus quantization share one audited implementation).
* The search path uses the *corpus* representations below: every search
  round scores compressed vectors; the final merged frontier is re-scored
  with exact float similarities before diversification (see
  ``sharded_search.search``), so quantization is a memory knob, never a
  certificate knob (``docs/ARCHITECTURE.md`` contract 13).

Corpus representations
----------------------

* :class:`Int8Corpus` — symmetric int8 with one f32 scale per
  ``scale_rows`` consecutive rows (the corpus analog of the gradient path's
  per-block shared scale). Codes are exactly 4x smaller than f32; the scale
  sidecar adds ``4 / scale_rows`` bytes per vector, so end-to-end
  bytes/vector is ``d + 4/scale_rows`` vs ``4d`` — 3.97x at d=64 with the
  default ``scale_rows=8`` (any nonzero sidecar makes a strict 4.0x total
  mathematically unreachable; the 4x is exact on the code payload).
* :class:`PQCorpus` — product quantization: ``d`` split into ``M``
  subspaces, each vector stored as ``M`` uint8 codebook indices (``C <=
  256`` centroids per subspace, k-means trained at index build). Strictly
  smaller than int8: ``M + codebook_bytes/n`` bytes per vector.

Scoring semantics (the parity contract)
---------------------------------------

Quantized similarity is defined by the *shared jnp arithmetic in this
module*, which both the ``kernels/ref.py`` oracles and the Pallas kernels
consume:

* int8 — the query is symmetrically quantized per row (``amax/127``), the
  dot runs int8 x int8 with **int32 accumulation** (exact integers, so the
  Pallas ``dot_general`` and the jnp oracle agree bitwise), and
  :func:`int8_postprocess` applies the scale products + metric transform —
  one implementation, so ref / interpret / pallas are bit-exact.
* PQ — asymmetric distance computation: :func:`pq_luts_many` builds
  per-subspace lookup tables from the *float* query, and scores are the
  LUT gather-sum :func:`pq_lut_sum` (accumulated subspace-by-subspace,
  left to right — the Pallas LUT kernel's one-hot matmuls reproduce each
  gather exactly, so the same accumulation order gives bit parity).

The shard-local beam search scores gathered *compressed* neighbor blocks
with the same arithmetic via :func:`prepare_query` / :func:`score_rows`
(``core.beam_search`` dispatches on the corpus type), so in-loop scores and
the batched ``kernels.ops.quantized_similarity_many`` scores agree to ~1
ulp on the same rows (bitwise within an op's ladder; across compilation
contexts XLA's fusion freedom allows the last bit to differ).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-12          # norm guard, mirrors core.similarity._EPS

# --------------------------------------------------------------------------
# The flat block quantizer (shared with distributed.compression)
# --------------------------------------------------------------------------

BLOCK = 2048


def quantize_blocks(x, scale):
    """Symmetric int8: ``scale`` is the per-step size (amax/127);
    ``q = clip(round(x / scale), -127, 127)``."""
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q.astype(jnp.int8)


def block_view(flat):
    """Pad a flat vector to whole :data:`BLOCK`-sized rows.

    Returns ``(blocks[nb, BLOCK], n)`` with ``n`` the original length."""
    n = flat.shape[0]
    nb = -(-n // BLOCK)
    pad = nb * BLOCK - n
    return jnp.pad(flat, (0, pad)).reshape(nb, BLOCK), n


# --------------------------------------------------------------------------
# Corpus representations
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Int8Corpus:
    """Symmetric int8 corpus with one f32 scale per ``scale_rows`` rows.

    ``codes[i] = round(x[i] / scales[i // scale_rows])`` — reconstruction
    error is bounded by half a step per element (one step at the clip
    boundary), the same bound the gradient path's EF buffer relies on.
    """
    codes: jnp.ndarray    # int8[n, d]
    scales: jnp.ndarray   # f32[nb], nb = ceil(n / scale_rows)
    scale_rows: int = dataclasses.field(metadata=dict(static=True), default=8)

    @property
    def shape(self) -> tuple:
        return tuple(self.codes.shape)

    def row_scales(self) -> jnp.ndarray:
        """Per-row step sizes f32[n] (the scale sidecar, expanded)."""
        n = self.codes.shape[0]
        return self.scales[jnp.arange(n) // self.scale_rows]

    def dequantize(self) -> jnp.ndarray:
        """Reconstructed f32[n, d] corpus (the scoring oracle's target)."""
        return self.codes.astype(jnp.float32) * self.row_scales()[:, None]

    def bytes_per_vector(self) -> float:
        n, d = self.codes.shape
        return (n * d * 1 + self.scales.shape[0] * 4) / n

    def code_bytes_per_vector(self) -> float:
        """Code payload only — exactly ``d`` bytes (4x smaller than f32)."""
        return float(self.codes.shape[1])


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PQCorpus:
    """Product-quantized corpus: per-subspace codebook indices.

    ``d`` is split into ``M`` contiguous subspaces of ``d // M`` dims; each
    row stores the nearest centroid index per subspace (uint8, ``C <= 256``).
    """
    codes: jnp.ndarray      # uint8[n, M]
    codebooks: jnp.ndarray  # f32[M, C, d // M]

    @property
    def shape(self) -> tuple:
        m, _, ds = self.codebooks.shape
        return (int(self.codes.shape[0]), m * ds)

    def dequantize(self) -> jnp.ndarray:
        idx = self.codes.astype(jnp.int32)
        m = self.codebooks.shape[0]
        parts = [self.codebooks[j, idx[:, j]] for j in range(m)]
        return jnp.concatenate(parts, axis=-1)

    def bytes_per_vector(self) -> float:
        n, m = self.codes.shape
        return (n * m * 1 + self.codebooks.size * 4) / n

    def code_bytes_per_vector(self) -> float:
        return float(self.codes.shape[1])


QUANT_SCHEMES = ("int8", "pq")


def is_quantized(corpus) -> bool:
    return isinstance(corpus, (Int8Corpus, PQCorpus))


def corpus_bytes_per_vector(corpus) -> float:
    """Stored bytes per vector: quantized corpora report their real payload
    (codes + amortized sidecars); a float array reports ``itemsize * d``."""
    if is_quantized(corpus):
        return float(corpus.bytes_per_vector())
    return float(np.dtype(corpus.dtype).itemsize * corpus.shape[-1])


# --------------------------------------------------------------------------
# Builders (host-side, at index build)
# --------------------------------------------------------------------------

def quantize_int8(x, scale_rows: int = 8) -> Int8Corpus:
    """Quantize a corpus to :class:`Int8Corpus`.

    One shared scale per ``scale_rows`` consecutive rows (amax of the whole
    row block / 127 — the corpus analog of ``compressed_psum``'s cross-axis
    shared block scale), so the sidecar stays at ``4 / scale_rows`` bytes
    per vector.
    """
    x = jnp.asarray(x, jnp.float32)
    n, d = x.shape
    nb = -(-n // scale_rows)
    pad = nb * scale_rows - n
    xb = jnp.pad(x, ((0, pad), (0, 0))).reshape(nb, scale_rows * d)
    amax = jnp.max(jnp.abs(xb), axis=1)
    scales = jnp.maximum(amax, _EPS) / 127.0
    codes = quantize_blocks(xb, scales[:, None]).reshape(nb * scale_rows,
                                                         d)[:n]
    return Int8Corpus(codes=codes, scales=scales, scale_rows=int(scale_rows))


def _kmeans(sub: np.ndarray, c: int, iters: int,
            rng: np.random.Generator) -> np.ndarray:
    """Plain seeded k-means (squared-L2) for one PQ subspace."""
    n = sub.shape[0]
    cb = sub[rng.choice(n, size=c, replace=False)].copy()
    for _ in range(iters):
        d2 = (np.einsum("nd,nd->n", sub, sub)[:, None]
              - 2.0 * (sub @ cb.T)
              + np.einsum("cd,cd->c", cb, cb)[None, :])
        assign = np.argmin(d2, axis=1)
        for j in range(c):
            members = sub[assign == j]
            if members.shape[0]:       # empty cluster keeps its centroid
                cb[j] = members.mean(axis=0)
    return cb


def pq_encode(x: np.ndarray, codebooks: np.ndarray) -> np.ndarray:
    """Nearest-centroid codes uint8[n, M] for ``x`` under ``codebooks``."""
    x = np.asarray(x, np.float32)
    m, c, ds = codebooks.shape
    codes = np.empty((x.shape[0], m), np.uint8)
    for j in range(m):
        sub = x[:, j * ds:(j + 1) * ds]
        cb = codebooks[j]
        d2 = (np.einsum("nd,nd->n", sub, sub)[:, None]
              - 2.0 * (sub @ cb.T)
              + np.einsum("cd,cd->c", cb, cb)[None, :])
        codes[:, j] = np.argmin(d2, axis=1).astype(np.uint8)
    return codes


def default_pq_m(d: int, max_m: int = 16) -> int:
    """Default PQ subspace count: the largest ``m <= max_m`` that splits
    ``d`` evenly with subspace width ``>= 2`` (``1`` when ``d < 4``).

    Narrow subspaces keep the ADC score error small enough that the
    quantized beam still finds (most of) the float frontier — the 10k
    recall floor ``tests/test_quant.py`` pins assumes this default; wider
    subspaces trade recall for bytes, so pass ``pq_m`` explicitly to take
    that trade."""
    for m in range(min(int(max_m), d // 2), 1, -1):
        if d % m == 0:
            return m
    return 1


def train_pq(x, m: int = 8, codes: int = 256, iters: int = 10,
             seed: int = 0, sample: int = 16384) -> PQCorpus:
    """Train per-subspace codebooks (seeded k-means on a sample) and encode.

    ``d`` must split evenly into ``m`` subspaces; ``codes <= 256`` so
    indices fit uint8 (the whole point of the byte budget).
    """
    x = np.asarray(x, np.float32)
    n, d = x.shape
    if d % m:
        raise ValueError(f"d={d} does not split into m={m} subspaces")
    if codes > 256:
        raise ValueError(f"codes={codes} > 256 would not fit uint8")
    c = min(int(codes), n)
    rng = np.random.default_rng(seed)
    fit = x[rng.choice(n, size=min(int(sample), n), replace=False)]
    ds = d // m
    cbs = np.stack([_kmeans(fit[:, j * ds:(j + 1) * ds], c, int(iters), rng)
                    for j in range(m)])
    return PQCorpus(codes=jnp.asarray(pq_encode(x, cbs)),
                    codebooks=jnp.asarray(cbs, dtype=jnp.float32))


def quantize_corpus(x, scheme: str, *, scale_rows: int = 8,
                    pq_m: int | None = None, pq_codes: int = 256,
                    pq_iters: int = 10, pq_sample: int = 16384,
                    seed: int = 0):
    """Build the quantized corpus for ``scheme`` in :data:`QUANT_SCHEMES`.

    ``pq_m=None`` picks :func:`default_pq_m` for the corpus width."""
    if scheme == "int8":
        return quantize_int8(x, scale_rows=scale_rows)
    if scheme == "pq":
        x = np.asarray(x, np.float32)
        m = pq_m if pq_m is not None else default_pq_m(x.shape[-1])
        return train_pq(x, m=m, codes=pq_codes, iters=pq_iters,
                        seed=seed, sample=pq_sample)
    raise ValueError(
        f"unknown quantization scheme {scheme!r}; expected {QUANT_SCHEMES}")


# --------------------------------------------------------------------------
# Shared scoring arithmetic (the oracles' AND the kernels' ground truth)
# --------------------------------------------------------------------------

def quantize_queries(qs) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row symmetric int8 query codes: ``(codes int8[b, d], scales
    f32[b])`` with ``scale = max(amax, eps) / 127`` per row."""
    qs = jnp.asarray(qs, jnp.float32)
    scales = jnp.maximum(jnp.max(jnp.abs(qs), axis=-1), _EPS) / 127.0
    return quantize_blocks(qs, scales[..., None]), scales


def int8_postprocess(dots, qsq, xsq, q_scale, x_scale, metric: str):
    """Dequantize int32 dot/norm accumulators and apply the metric transform.

    THE bit-parity anchor: the jnp oracle, the Pallas kernel wrapper, and
    the beam loop's block scorer all call this one function on bit-equal
    int32 inputs, so their f32 outputs match bitwise. Shapes broadcast
    (batched: ``dots[b, n]``, ``qsq/q_scale[b, 1]``, ``xsq/x_scale[1, n]``;
    block: ``dots/xsq/x_scale[m]``, scalars for the query side).
    """
    s = q_scale * x_scale
    dots_f = dots.astype(jnp.float32) * s
    if metric == "ip":
        return dots_f
    q2 = qsq.astype(jnp.float32) * (q_scale * q_scale)
    x2 = xsq.astype(jnp.float32) * (x_scale * x_scale)
    if metric == "cos":
        qn = jnp.sqrt(jnp.maximum(q2, _EPS))
        xn = jnp.sqrt(jnp.maximum(x2, _EPS))
        return dots_f / (qn * xn)
    if metric == "l2":
        d2 = jnp.maximum(q2 + x2 - 2.0 * dots_f, 0.0)
        return 1.0 - jnp.sqrt(d2)
    raise ValueError(f"unknown metric {metric!r}")


def int8_score_from_dots(dots, q_codes, q_scales, corpus, metric: str):
    """Batched int8 scores from precomputed exact integer dots.

    ``dots`` int32[b, n] from either the Pallas kernel or the oracle's
    ``dot_general`` — exact integers either way, so both producers feed
    bit-equal inputs into the one shared float postprocess here.
    """
    qc = q_codes.astype(jnp.int32)
    xc = corpus.codes.astype(jnp.int32)
    qsq = jnp.sum(qc * qc, axis=-1, keepdims=True)
    xsq = jnp.sum(xc * xc, axis=-1)[None, :]
    return int8_postprocess(dots, qsq, xsq, q_scales[:, None],
                            corpus.row_scales()[None, :], metric)


def pq_luts_many(qs, codebooks, metric: str):
    """Per-subspace ADC lookup tables for a query batch.

    Returns ``(T f32[b, M, C], S f32[M, C], qn f32[b])``: the score is a
    transform of ``sum_m T[b, m, code]`` (squared distances for l2, dots
    for ip/cos), ``S`` carries the centroid squared norms cos needs for the
    reconstructed-vector norm, and ``qn`` the float query norms.
    """
    qs = jnp.asarray(qs, jnp.float32)
    m, _, ds = codebooks.shape
    qsub = qs.reshape(qs.shape[0], m, ds)
    dots = jnp.einsum("bms,mcs->bmc", qsub, codebooks)
    csq = jnp.sum(codebooks * codebooks, axis=-1)          # [M, C]
    if metric == "l2":
        qsq = jnp.sum(qsub * qsub, axis=-1)                # [b, M]
        T = qsq[:, :, None] - 2.0 * dots + csq[None]
    elif metric in ("ip", "cos"):
        T = dots
    else:
        raise ValueError(f"unknown metric {metric!r}")
    qn = jnp.sqrt(jnp.maximum(jnp.sum(qs * qs, axis=-1), _EPS))
    return T, csq, qn


def pq_lut_sum(T, codes):
    """``sum_m T[..., m, codes[:, m]]`` accumulated subspace-by-subspace.

    The accumulation is explicitly left-to-right over ``m`` — the Pallas
    LUT kernel's per-subspace one-hot matmuls add in the same order (each
    one-hot dot reproduces the gathered entry exactly: the other addends
    are exact zeros), so oracle and kernel sums are bitwise equal.
    """
    idx = jnp.asarray(codes).astype(jnp.int32)
    m = T.shape[-2]
    out = T[..., 0, :][..., idx[:, 0]]
    for j in range(1, m):
        out = out + T[..., j, :][..., idx[:, j]]
    return out


def pq_postprocess(sumT, sumS, qn, metric: str):
    """Metric transform over the LUT sums (shared by oracle and kernel)."""
    if metric == "ip":
        return sumT
    if metric == "l2":
        return 1.0 - jnp.sqrt(jnp.maximum(sumT, 0.0))
    if metric == "cos":
        xn = jnp.sqrt(jnp.maximum(sumS, _EPS))
        return sumT / (qn * xn)
    raise ValueError(f"unknown metric {metric!r}")


# --------------------------------------------------------------------------
# Per-search query views (the beam loop's compressed block scoring)
# --------------------------------------------------------------------------

class Int8Query(NamedTuple):
    """One query, pre-quantized for int8 block scoring."""
    codes: jnp.ndarray   # int8[d]
    scale: jnp.ndarray   # f32[]


class PQQuery(NamedTuple):
    """One query's ADC tables for PQ block scoring."""
    luts: jnp.ndarray     # f32[M, C]
    sq_luts: jnp.ndarray  # f32[M, C] centroid squared norms
    qnorm: jnp.ndarray    # f32[]


def prepare_query(corpus, q, metric: str):
    """Precompute the per-search query view for ``corpus``.

    Float corpora return ``q`` unchanged (the beam loop's float path stays
    byte-identical); quantized corpora return the small pytree the block
    scorer consumes — computed once per search, outside the expansion loop.
    """
    if isinstance(corpus, Int8Corpus):
        codes, scales = quantize_queries(q[None, :])
        return Int8Query(codes=codes[0], scale=scales[0])
    if isinstance(corpus, PQCorpus):
        T, S, qn = pq_luts_many(q[None, :], corpus.codebooks, metric)
        return PQQuery(luts=T[0], sq_luts=S, qnorm=qn[0])
    return q


def score_rows(prep, corpus, idx, metric: str):
    """Score the gathered compressed rows ``corpus[idx]`` against ``prep``.

    ``idx`` int32[m] (non-negative). Uses the same shared arithmetic as the
    batched ops; values agree with ``kernels.ops.quantized_similarity_many``
    to ~1 ulp (XLA may fuse/FMA the float postprocess differently across
    compilation contexts — the *bitwise* contract is between the ladder
    rungs of the batched op, not between loop and batch).
    """
    idx = jnp.asarray(idx)
    if isinstance(corpus, Int8Corpus):
        rows = corpus.codes[idx].astype(jnp.int32)           # (m, d)
        rsc = corpus.scales[idx // corpus.scale_rows]        # (m,)
        qc = prep.codes.astype(jnp.int32)
        dots = jnp.sum(rows * qc, axis=-1)                   # exact int32
        qsq = jnp.sum(qc * qc)
        xsq = jnp.sum(rows * rows, axis=-1)
        return int8_postprocess(dots, qsq, xsq, prep.scale, rsc, metric)
    if isinstance(corpus, PQCorpus):
        codes = corpus.codes[idx]                            # (m, M)
        sumT = pq_lut_sum(prep.luts, codes)
        sumS = pq_lut_sum(prep.sq_luts, codes)
        return pq_postprocess(sumT, sumS, prep.qnorm, metric)
    raise TypeError(f"score_rows needs a quantized corpus, got {type(corpus)}")
