"""Progressive Score Search — paper Algorithm 4 (Theorem 2 early stop).

Phase 1 runs PGS (guarantees a size-k diverse set exists among the
candidates and warm-starts the queue). Each round then:
  1. builds G^eps over the first K candidates (incremental extension),
  2. runs div-A* for the optimal sets of sizes 1..k,
  3. computes minValue = min_i (S_k - S_i)/(k - i)  (Theorem 2),
  4. stops if minValue > s_K — the result is then certified optimal over the
     whole database (under the paper's 100%-recall beam assumption);
     otherwise resumes ProgressiveBeamSearch* until the frontier score drops
     below minValue and sets K <- stable_count // ef.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import div_astar as da
from repro.core.diversity_graph import build_adjacency, extend_adjacency
from repro.core.graph import FlatGraph
from repro.core.pgs import DiverseResult, pgs
from repro.core.theorems import theorem2_min_value


def pss(graph: FlatGraph, q, k: int, eps: float, ef: int = 40,
        max_iters: int = 64, max_expansions: int = 400_000) -> DiverseResult:
    pgs_res, driver, K = pgs(graph, q, k, eps, ef)
    n = graph.size
    adj = None
    prev_ids = None
    best = pgs_res  # fallback if certification never fires
    for it in range(max_iters):
        K = max(k, min(K, n))
        ids, scores = driver.prefix(K)
        if adj is not None and prev_ids is not None \
                and K >= prev_ids.shape[0] \
                and bool(jnp.all(ids[: prev_ids.shape[0]] == prev_ids)):
            adj = extend_adjacency(graph, adj, prev_ids, ids, eps)
        else:
            adj = build_adjacency(graph, ids, eps)
        prev_ids = ids
        res = da.div_astar(jnp.where(ids >= 0, scores, -jnp.inf), adj, k,
                           max_expansions=max_expansions)
        driver.stats.div_calls += 1
        if np.isfinite(float(res.best_scores[k - 1])):
            sel = np.asarray(res.best_sets[k - 1])
            ids_np, sc_np = np.asarray(ids), np.asarray(scores)
            out_ids = np.where(sel >= 0, ids_np[np.maximum(sel, 0)], -1)
            out_sc = np.where(sel >= 0, sc_np[np.maximum(sel, 0)], 0.0)
            best = DiverseResult(out_ids.astype(np.int32),
                                 out_sc.astype(np.float32),
                                 float(out_sc.sum()), driver.stats)
        min_value = float(theorem2_min_value(res.best_scores, k))
        s_K = float(scores[K - 1]) if K <= ids.shape[0] else -np.inf
        if min_value > s_K:
            driver.stats.certified = bool(res.complete)
            break
        if driver.stats.exhausted or K >= n:
            break
        stable_before = driver.stable_prefix_len()
        stable = driver.expand_until_below(min_value)
        if stable <= stable_before:  # no progress — graph exhausted
            driver.stats.exhausted = True
            if stable >= n or driver.capacity >= driver.max_capacity:
                K = min(stable, n)
                continue
        K = max(k, stable // ef)
    driver.stats.K_final = K
    return best._replace(stats=driver.stats)
