"""Baselines the paper compares against (§II-B, §IV-A).

* ``greedy_fixed``  — beam search with fixed L (default 400, as the paper's
  Greedy_400), then one greedy diversification pass. May return < k results;
  the paper scores missing slots as 0, and so do we.
* ``div_astar_oracle`` — exact top-X candidates (brute force) + div-A*:
  the ground-truth generator for recall (the paper's div-A* baseline).
* ``ip_greedy``     — Hirata et al. [24] (Eqs. 1-2): greedy selection on
  f(p, S) = lambda * <p,q> + c * (1 - lambda) * min pairwise dist(S ∪ {p});
  applies to ip/cos spaces, included for the Fig. 8 reproduction.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import div_astar as da
from repro.core.beam_search import beam_search
from repro.core.diversity_graph import build_adjacency
from repro.core.graph import FlatGraph
from repro.core.pgs import DiverseResult
from repro.core.progressive import SearchStats
from repro.index.flat import exact_topk
from repro.kernels import ops as kops


def greedy_fixed(graph: FlatGraph, q, k: int, eps: float,
                 L: int = 400) -> DiverseResult:
    ids, scores = beam_search(graph, jnp.asarray(q, jnp.float32), L, L)
    adj = build_adjacency(graph, ids, eps)
    sel, count = kops.greedy_diversify(scores, adj, k, valid=ids >= 0)
    sel = np.asarray(sel)
    ids_np, sc_np = np.asarray(ids), np.asarray(scores)
    out_ids = np.where(sel >= 0, ids_np[np.maximum(sel, 0)], -1)
    out_sc = np.where(sel >= 0, sc_np[np.maximum(sel, 0)], 0.0)  # missing = 0
    st = SearchStats(K_final=L)
    return DiverseResult(out_ids.astype(np.int32), out_sc.astype(np.float32),
                         float(out_sc.sum()), st)


def div_astar_oracle(vectors: np.ndarray, metric: str, q, k: int, eps: float,
                     X: int = 2048, max_expansions: int = 2_000_000,
                     grow_until_certified: bool = True) -> DiverseResult:
    """Exact candidates + div-A*; X doubles until Theorem 2 certifies global
    optimality (so the ground truth is optimal over the WHOLE dataset)."""
    from repro.core.theorems import theorem2_min_value

    n = vectors.shape[0]
    X = min(X, n)
    while True:
        ids, scores = exact_topk(np.asarray(q)[None], vectors, X, metric)
        ids, scores = ids[0], scores[0]
        vecs = jnp.asarray(vectors[ids])
        adj = kops.pairwise_adjacency(vecs, eps, metric)
        res = da.div_astar(jnp.asarray(scores), adj, k,
                           max_expansions=max_expansions)
        ok = np.isfinite(float(res.best_scores[k - 1]))
        min_value = float(theorem2_min_value(res.best_scores, k))
        certified = ok and (min_value > float(scores[X - 1]) or X >= n)
        if certified or not grow_until_certified or X >= n:
            break
        X = min(2 * X, n)
    sel = np.asarray(res.best_sets[k - 1])
    out_ids = np.where(sel >= 0, ids[np.maximum(sel, 0)], -1)
    out_sc = np.where(sel >= 0, scores[np.maximum(sel, 0)], 0.0)
    st = SearchStats(K_final=X, certified=bool(res.complete))
    return DiverseResult(out_ids.astype(np.int32), out_sc.astype(np.float32),
                         float(out_sc.sum()), st)


def ip_greedy(graph: FlatGraph, q, k: int, lam: float, c: float = 1.0,
              L: int = 400) -> DiverseResult:
    """IP-greedy (Eq. 2). dist = euclidean distance (as in [24])."""
    ids, scores = beam_search(graph, jnp.asarray(q, jnp.float32), L, L)
    ids_np = np.asarray(ids)
    valid = ids_np >= 0
    vecs = np.asarray(graph.vectors)[np.maximum(ids_np, 0)]
    rel = np.asarray(scores)  # <p, q> (ip) or cos
    # pairwise euclidean distances among candidates
    d2 = np.maximum(
        (vecs ** 2).sum(1)[:, None] + (vecs ** 2).sum(1)[None, :]
        - 2.0 * vecs @ vecs.T, 0.0)
    dist = np.sqrt(d2)
    chosen: list[int] = []
    cur_min = np.inf
    for _ in range(k):
        best_j, best_f = -1, -np.inf
        for j in range(len(ids_np)):
            if not valid[j] or j in chosen:
                continue
            new_min = cur_min if not chosen else min(
                cur_min, float(dist[j, chosen].min()))
            if not chosen:
                new_min_term = 0.0
            else:
                new_min_term = new_min
            f = lam * float(rel[j]) + c * (1.0 - lam) * new_min_term
            if f > best_f:
                best_f, best_j = f, j
        if best_j < 0:
            break
        if chosen:
            cur_min = min(cur_min, float(dist[best_j, chosen].min()))
        chosen.append(best_j)
    out_ids = np.full(k, -1, np.int32)
    out_sc = np.zeros(k, np.float32)
    for t, j in enumerate(chosen):
        out_ids[t] = ids_np[j]
        out_sc[t] = rel[j]
    st = SearchStats(K_final=L)
    return DiverseResult(out_ids, out_sc, float(out_sc.sum()), st)
