"""Batched (vmapped) diverse search — the TPU serving path.

The progressive drivers are per-query host loops (faithful to the paper's
Alg. 2-4 pause/resume structure). Production serving wants one jitted,
fixed-shape program over a request batch; these entry points provide it:

* ``batch_beam_search``      — vmapped Alg. 1 over B queries (lockstep
                               while_loop; done lanes idle, standard TPU
                               batching trade-off).
* ``batch_greedy_diverse``   — beam + adjacency + greedy per query, all
                               vmapped (the paper's greedy baseline at scale).
* ``batch_optimal_diverse``  — beam + adjacency + div-A* per query, with a
                               Theorem-2 certificate per lane. This is
                               "PSS with a fixed K budget": the progressive
                               growth is replaced by a static K chosen from
                               the Theorem-1/2 statistics of the workload,
                               and the certificate reports which lanes would
                               have needed more candidates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import beam_search as bs
from repro.core import div_astar as da
from repro.core.graph import FlatGraph
from repro.core.theorems import theorem2_min_value
from repro.kernels import ops as kops


@functools.partial(jax.jit, static_argnames=("k", "L", "capacity"))
def batch_beam_search(graph: FlatGraph, qs: jnp.ndarray, k: int, L: int,
                      capacity: int | None = None):
    """ids[B, k], scores[B, k] for a query batch qs[B, d]."""
    capacity = capacity or L

    def one(q):
        state = bs.init_state(graph, q, capacity)
        state = bs.run_search(graph, q, state, stable_limit=L)
        return state.queue.ids[:k], state.queue.scores[:k]

    return jax.vmap(one)(qs)


@functools.partial(jax.jit, static_argnames=("k", "L"))
def batch_greedy_diverse(graph: FlatGraph, qs: jnp.ndarray, k: int,
                         eps, L: int):
    """Greedy-diversified results (ids[B, k], scores[B, k], count[B])."""

    def one(q):
        state = bs.init_state(graph, q, L)
        state = bs.run_search(graph, q, state, stable_limit=L)
        ids = state.queue.ids
        scores = state.queue.scores
        vecs = graph.vectors[jnp.maximum(ids, 0)]
        adj = kops.pairwise_adjacency(vecs, eps, graph.metric, ids >= 0)
        sel, count = kops.greedy_diversify(scores, adj, k, valid=ids >= 0)
        out_ids = jnp.where(sel >= 0, ids[jnp.maximum(sel, 0)], -1)
        out_sc = jnp.where(sel >= 0, scores[jnp.maximum(sel, 0)], 0.0)
        return out_ids, out_sc, count

    return jax.vmap(one)(qs)


@functools.partial(jax.jit, static_argnames=("k", "K", "ef", "max_expansions"))
def batch_optimal_diverse(graph: FlatGraph, qs: jnp.ndarray, k: int,
                          eps, K: int, ef: int = 4,
                          max_expansions: int = 100_000):
    """div-A*-optimal results over a fixed top-K candidate budget.

    Returns (ids[B, k], scores[B, k], total[B], certified[B]). ``certified``
    is the per-lane Theorem-2 check: True means the result is optimal over
    the whole database, not just the K candidates (under the paper's
    beam-recall assumption); False lanes should be re-run through the
    progressive driver.
    """
    L = K * ef

    def one(q):
        state = bs.init_state(graph, q, L)
        state = bs.run_search(graph, q, state, stable_limit=L)
        ids = state.queue.ids[:K]
        scores = state.queue.scores[:K]
        vecs = graph.vectors[jnp.maximum(ids, 0)]
        adj = kops.pairwise_adjacency(vecs, eps, graph.metric, ids >= 0)
        res = da.div_astar(jnp.where(ids >= 0, scores, -jnp.inf), adj, k,
                           max_expansions=max_expansions)
        sel = res.best_sets[k - 1]
        out_ids = jnp.where(sel >= 0, ids[jnp.maximum(sel, 0)], -1)
        out_sc = jnp.where(sel >= 0, scores[jnp.maximum(sel, 0)], 0.0)
        min_value = theorem2_min_value(res.best_scores, k)
        certified = (min_value > scores[K - 1]) & res.complete
        return out_ids, out_sc, jnp.sum(out_sc), certified

    return jax.vmap(one)(qs)
