"""Batched progressive engine (paper Alg. 2-4 over a lane batch).

This is the middle layer of the serving stack's three-way split:

* ``core.lane_state`` — pure fixed-shape per-lane state (queue/beam pytrees,
  ``extract_lane`` / ``inject_lane`` / ``recycle_lane``).
* this module — the **engine**: one-dispatch search bursts, bucketed exact
  queue growth, batched diversify/verify kernels, and a per-lane state
  machine (``ProgressiveEngine.step()``) that advances every occupied lane
  one progressive round. Lanes are independent: each carries its own
  ``(k, eps, ef)`` and its own method (PGS / PDS / PSS), and a certified
  lane's slot can be recycled for a new query between steps.
* ``serve.scheduler`` — continuous-batching admission on top of ``step()``:
  a request queue feeds freed lanes so one heavy-tailed query never stalls
  the batch (see that module for the latency story). The scheduler drives
  the engine through the backend-neutral ``core.backend.LaneBackend``
  protocol, which this engine implements for the single-host case
  (``sharded_search.engine.ShardedEngine`` is the mesh case).

Device-side structure (unchanged from the original engine):

* **One-dispatch bursts** — a single ``lax.map`` dispatch advances every
  lane's beam-search ``while_loop`` to that lane's own stop condition;
  lanes run lane-serial on device, paying the sum of per-lane work with
  none of the per-query dispatch overhead (see ``_batched_search_loop``).
* **Per-lane logical capacity** — all lanes share one fixed-shape state at
  the physical capacity, but each lane's queue is clamped to its own
  logical capacity after every insert, so per-lane semantics are *bit-exact*
  with a solo ``ProgressiveDriver`` at that capacity.
* **Bucketed growth** — lanes whose candidate budget outgrows their capacity
  are rebuilt together per power-of-two target with the exact rebuild of
  ``beam_search.rebuild_for_growth`` (one vmapped rebuild per bucket).
* **Batched diversify + verify** — the PGS/warm-start round is ONE fused
  dispatch per (prefix width, k) group (``kops.fused_round_batch``: prefix
  masking, candidate gather, G^eps adjacency, greedy selection and output
  extraction in a single ``pallas_call`` on the kernel paths — see
  ``kernels/fused_round.py``); the remaining verify stages (Theorem-1
  degree schedules, div-A*) run per-group from masked prefixes, with
  Theorem-2 certificates coming back per lane.

Compile-signature discipline: every jitted call site is logged in a
``SignatureLog`` keyed by its shape/static signature — ``(lane count,
physical capacity)`` for bursts, ``(group size, prefix width[, k])`` for the
diversify stages — and group sizes / widths / capacities are all padded to
powers of two, so the number of distinct signatures is logarithmic in batch
size and capacity. ``ProgressiveEngine.prewarm()`` compiles the capacity
ladder up front (the scheduler calls it at start) and the log exposes any
signature first seen after ``freeze()`` as *unplanned*.

Entry points: ``batch_pgs`` (Alg. 2), ``batch_pds`` (Alg. 3), ``batch_pss``
(Alg. 4, the default serving path) — lockstep wrappers that admit the whole
batch and step the engine until every lane finishes, returning a
``BatchDiverseResult`` whose per-lane ids/scores match the per-query drivers
exactly.

Parity scope: every per-lane decision replicates the per-query driver's
formulas, queue-score computations are batch-invariant by construction
(``query_sim``'s reduce form, the rank-merge insert, top_k rebuilds), and
``tests/test_batch_progressive.py`` enforces bit-equality on the CPU
reference path — including for recycled lanes, which must match a fresh solo
driver for the new query. The one caveat is the adjacency build: ``sims >
eps`` edges come from matmuls whose accumulation order XLA may vary across
batch shapes and backends, so a pair landing within one rounding step of
``eps`` could in principle flip an edge relative to the solo driver (which
additionally uses ``extend_adjacency``'s different-shaped matmul). Measured
bit-stable across vmap/widths on CPU; re-validate the parity suite before
relying on bit-equality on a new backend.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.core import beam_search as bs
from repro.core import div_astar as da
from repro.core import lane_state
from repro.core import queue as qmod
from repro.core.backend import LaneRequest
from repro.core.bucketing import (next_pow2 as _next_pow2, pow2_group_sizes,
                                  pow2_padded_indices)
from repro.core.diversity_graph import degrees as _degrees
from repro.core.graph import FlatGraph
from repro.core.pgs import DiverseResult
from repro.core.progressive import SearchStats
from repro.core.theorems import theorem1_K, theorem2_min_value
from repro.kernels import ops as kops


# --------------------------------------------------------------- results ----

@dataclasses.dataclass
class BatchSearchStats:
    """Per-lane counters mirroring ``progressive.SearchStats``."""
    expansions: np.ndarray
    growths: np.ndarray
    search_calls: np.ndarray
    div_calls: np.ndarray
    certified: np.ndarray
    exhausted: np.ndarray
    K_final: np.ndarray

    @classmethod
    def zeros(cls, b: int) -> "BatchSearchStats":
        return cls(expansions=np.zeros(b, np.int64),
                   growths=np.zeros(b, np.int64),
                   search_calls=np.zeros(b, np.int64),
                   div_calls=np.zeros(b, np.int64),
                   certified=np.zeros(b, bool),
                   exhausted=np.zeros(b, bool),
                   K_final=np.zeros(b, np.int64))

    def reset_lane(self, lane: int) -> None:
        for f in dataclasses.fields(self):
            getattr(self, f.name)[lane] = 0

    def lane_view(self, lane: int) -> SearchStats:
        return SearchStats(expansions=int(self.expansions[lane]),
                           growths=int(self.growths[lane]),
                           search_calls=int(self.search_calls[lane]),
                           div_calls=int(self.div_calls[lane]),
                           certified=bool(self.certified[lane]),
                           exhausted=bool(self.exhausted[lane]),
                           K_final=int(self.K_final[lane]))


class BatchDiverseResult(NamedTuple):
    ids: np.ndarray      # int32[B, k], -1 padded
    scores: np.ndarray   # f32[B, k]
    totals: np.ndarray   # f32[B]
    stats: BatchSearchStats


# ------------------------------------------------------ signature logging ----

class SignatureBudgetExceeded(RuntimeError):
    """The engine would compile more distinct signatures than allowed."""


class SignatureLog:
    """Registry of jit call signatures the engine has issued.

    A *signature* is the (call site, shape/static args) tuple that determines
    whether XLA reuses a compilation: e.g. ``("search", B, C)`` for the burst
    loop or ``("div_astar", group, width, k)`` for verification. ``note``
    raises ``SignatureBudgetExceeded`` once more than ``limit`` distinct
    signatures exist — the compile-budget backstop. After ``freeze()``
    (scheduler prewarm done), first-seen signatures are additionally recorded
    in ``unplanned`` so tests can assert the ladder was fully pre-warmed.
    """

    def __init__(self, limit: int | None = 1024):
        self.limit = limit
        self.counts: dict[tuple, int] = {}
        self.frozen = False
        self.unplanned: list[tuple] = []

    def note(self, kind: str, *shape) -> None:
        sig = (kind, *(int(s) for s in shape))
        if sig not in self.counts:
            if self.limit is not None and len(self.counts) >= self.limit:
                raise SignatureBudgetExceeded(
                    f"signature {sig} would exceed the compile budget of "
                    f"{self.limit} distinct signatures")
            self.counts[sig] = 0
            if self.frozen:
                self.unplanned.append(sig)
        self.counts[sig] += 1

    def freeze(self) -> None:
        self.frozen = True

    def __len__(self) -> int:
        return len(self.counts)


def jit_cache_sizes() -> dict[str, int]:
    """Tracing-cache sizes of the engine's jitted device functions (test
    hook: a serving pass that recompiles shows up as a growing entry)."""
    fns = dict(search=_batched_search_loop, rebuild=_rebuild_lanes,
               prefix=_mask_prefix, adjacency=_batched_adjacency,
               div_astar=_batched_div_astar, theorem1=_batched_theorem1,
               fused_round=kops._ref_fused_round_batch)
    return {name: int(f._cache_size()) for name, f in fns.items()
            if hasattr(f, "_cache_size")}


# ------------------------------------------------------- device functions ----

def _merge_insert(queue: qmod.Queue, new_ids: jnp.ndarray,
                  new_scores: jnp.ndarray, new_mask: jnp.ndarray) -> qmod.Queue:
    """Bit-identical replacement for ``queue.insert`` on an already-sorted
    queue. ``queue.insert`` re-sorts all C+M entries with an O(C log C)
    *comparator* sort per expansion step — the dominant cost of the burst
    at (B, C) shapes. Here each entry's merged position is its rank
    under the same (score desc, id asc) order, computed from an O(C*M)
    vectorized comparison matrix (M = M0 graph degree, so this is the same
    cost class as the dedup matrix insert already builds). Ties (only the
    empty-slot sentinel) resolve queue-first / index-order, matching the
    stable lexsort exactly."""
    cap = queue.capacity
    m = new_ids.shape[0]
    b_ids, b_scores, b_stable = qmod.dedup_candidates(
        queue, new_ids, new_scores, new_mask)
    a_ids, a_scores = queue.ids, queue.scores

    def before(s1, i1, s2, i2):
        # strict (score desc, id asc) precedence
        return (s1 > s2) | ((s1 == s2) & (i1 < i2))

    # a entries keep their rank among a (queue is sorted); b entries ahead
    # of a_i push it back. Full ties (empty sentinels) resolve a-first.
    # rank of each b among b (strict order; sentinel ties resolve by index)
    bb = before(b_scores[:, None], b_ids[:, None],
                b_scores[None, :], b_ids[None, :])
    tie_bb = (b_scores[:, None] == b_scores[None, :]) & \
        (b_ids[:, None] == b_ids[None, :]) & (
        jnp.arange(m)[:, None] < jnp.arange(m)[None, :])
    rank_b = jnp.sum(bb | tie_bb, axis=0)
    inv_rank = jnp.argmax(rank_b[:, None] == jnp.arange(m)[None, :], axis=0)
    bs_ids, bs_scores = b_ids[inv_rank], b_scores[inv_rank]
    bs_stable = b_stable[inv_rank]
    # merged slot of each sorted-b element: a entries ahead of it (ties:
    # queue entries first, matching the stable concat-lexsort), plus its
    # own rank among b
    a_before_b = before(a_scores[:, None], a_ids[:, None],
                        bs_scores[None, :], bs_ids[None, :]) | (
        (a_scores[:, None] == bs_scores[None, :])
        & (a_ids[:, None] == bs_ids[None, :]))
    pos_b = jnp.sum(a_before_b, axis=0) + jnp.arange(m)
    # slot-wise gather (no scatter, no comparator sort): slot r holds
    # b_sorted[cb[r]] if some b lands at r, else a[r - cb[r]]
    slots = jnp.arange(cap)
    cb = jnp.sum(pos_b[None, :] < slots[:, None], axis=1)
    is_b = jnp.any(pos_b[None, :] == slots[:, None], axis=1)
    ai = jnp.minimum(slots - cb, cap - 1)
    bi = jnp.minimum(cb, m - 1)
    return qmod.Queue(
        ids=jnp.where(is_b, bs_ids[bi], a_ids[ai]),
        scores=jnp.where(is_b, bs_scores[bi], a_scores[ai]),
        stable=jnp.where(is_b, bs_stable[bi], queue.stable[ai]),
    )


@functools.partial(jax.jit, static_argnames=("graph_metric",))
def _batched_search_loop(vectors, neighbors, qs, state, caps, stable_limits,
                         min_values, max_steps, graph_metric: str):
    """One-dispatch burst: every lane runs its own beam-search while_loop.

    Identical to ``beam_search._search_loop`` per lane, plus the logical
    capacity clamp: entries at positions >= cap are forced back to the empty
    sentinel after each insert, which is exactly a capacity-``cap`` queue
    stored in a wider array.

    Lanes run lane-serial on device (``lax.map``): lane step counts vary
    several-fold, so a vmapped while_loop would charge every lane the
    straggler's trip count, while ``lax.map`` pays exactly the sum of
    per-lane work with none of the per-call dispatch overhead the per-query
    driver loop pays (measured ~2x faster than the vmapped variant on CPU
    even before straggler effects; revisit per-backend — on TPU the lockstep
    vmap variant may win back).
    """
    C = state.queue.ids.shape[-1]
    pos = jnp.arange(C)

    def one(args):
        q, st, cap, sl, mv, ms = args

        def clamp(queue: qmod.Queue) -> qmod.Queue:
            live = pos < cap
            return qmod.Queue(jnp.where(live, queue.ids, -1),
                              jnp.where(live, queue.scores, qmod.NEG_INF),
                              jnp.where(live, queue.stable, True))

        # the frontier pointer rides in the carry so the queue is scanned
        # once per expansion, not once in cond and again in body
        def cond(c):
            st, p, exists = c
            score_ok = st.queue.scores[p] >= mv
            return exists & score_ok & (st.steps < ms)

        def body(c):
            st, p, _ = c
            queue, visited, steps = st
            node = queue.ids[p]
            queue = qmod.Queue(queue.ids, queue.scores,
                               queue.stable.at[p].set(True))
            visited = visited.at[node].set(True)
            nbrs = neighbors[node]
            safe = jnp.maximum(nbrs, 0)
            fresh = (nbrs >= 0) & ~visited[safe]
            sims = kops.batch_similarity(q, vectors[safe], graph_metric)
            queue = clamp(_merge_insert(queue, nbrs, sims, fresh))
            p2, exists2 = qmod.first_unstable(queue, sl)
            return bs.SearchState(queue, visited, steps + 1), p2, exists2

        p0, exists0 = qmod.first_unstable(st.queue, sl)
        out, _, _ = jax.lax.while_loop(cond, body, (st, p0, exists0))
        return out

    return jax.lax.map(
        one, (qs, state, caps, stable_limits, min_values, max_steps))


@functools.partial(jax.jit, static_argnames=("new_capacity",))
def _rebuild_lanes(graph: FlatGraph, qs, state, new_capacity: int):
    """Exact rebuild of a growth bucket's lanes.

    Same construction as ``beam_search.rebuild_for_growth`` — rescore
    (visited ∪ queue), rebuild the queue — but the new queue is selected
    with ``lax.top_k`` instead of a full N-entry comparator sort: entries
    are indexed by node id, and top_k's documented lower-index-first tie
    rule is exactly the queue's (score desc, id asc) order, so the result
    is bit-identical at a fraction of the cost. Bit-parity of the rescoring
    itself holds because ``query_sim`` uses a batch-invariant reduce (see
    ``similarity.query_sim``).

    The caller slices the input queue to ``new_capacity`` (entries past a
    lane's logical capacity are padding sentinels), so the compile signature
    depends only on (group size, target capacity), not on the batch's
    physical capacity.
    """
    n = graph.size
    k0 = min(new_capacity, n)
    pad = new_capacity - k0

    def one(q, st):
        vis_scores = kops.batch_similarity(q, graph.vectors, graph.metric)
        safe = jnp.maximum(st.queue.ids, 0)
        # membership via add-scatter: duplicate target slots (several empty
        # sentinels all map to node 0) accumulate instead of racing, which
        # .set would leave order-undefined
        in_queue = jnp.zeros((n,), jnp.int32).at[safe].add(
            (st.queue.ids >= 0).astype(jnp.int32)) > 0
        frontier_unstable = jnp.zeros((n,), jnp.int32).at[safe].add(
            ((st.queue.ids >= 0) & ~st.queue.stable).astype(jnp.int32)) > 0
        member = st.visited | in_queue
        scores = jnp.where(member, vis_scores, qmod.NEG_INF)
        top_scores, sel = jax.lax.top_k(scores, k0)
        valid = top_scores > qmod.NEG_INF  # similarities are always finite
        queue = lane_state.pad_queue(qmod.Queue(
            ids=jnp.where(valid, sel.astype(jnp.int32), -1),
            scores=jnp.where(valid, top_scores, qmod.NEG_INF),
            stable=jnp.where(valid, ~frontier_unstable[sel], True)), pad)
        return bs.SearchState(queue, st.visited, st.steps)

    return jax.vmap(one)(qs, state)


_batched_stable_count = jax.jit(jax.vmap(qmod.stable_count))


@functools.partial(jax.jit, static_argnames=("metric",))
def _batched_adjacency(vectors, ids, eps, metric: str):
    """Per-lane G^eps adjacency; ``eps`` is a per-lane f32 vector so lanes
    with different diversification levels share one compilation."""
    vecs = vectors[jnp.maximum(ids, 0)]
    valid = ids >= 0
    return jax.vmap(
        lambda v, m, e: kops.pairwise_adjacency(v, e, metric, m)
    )(vecs, valid, eps)


@functools.partial(jax.jit, static_argnames=("k", "max_expansions"))
def _batched_div_astar(scores, adj, k: int, max_expansions: int):
    """Batched div-A* + Theorem-2 minValue per lane.

    Lane-serial on device (``lax.map``) rather than vmapped: div-A* trip
    counts are heavy-tailed (the paper's §IV hard cases run 10-100x the
    median), and a vmapped while_loop would make every lane pay the
    straggler's trips with both cond branches materialized. ``lax.map``
    keeps the per-query cost profile — one dispatch for the whole batch,
    branch-and-bound pruning intact per lane."""
    def one(s, a):
        r = da.div_astar(s, a, k, max_expansions)
        return r, theorem2_min_value(r.best_scores, k)
    return jax.lax.map(lambda args: one(*args), (scores, adj))


@functools.partial(jax.jit, static_argnames=("k",))
def _batched_theorem1(adj, valid, k: int):
    """Theorem-1 sufficient candidate count per lane (PDS degree schedule)."""
    deg = jax.vmap(_degrees)(adj, valid)
    return jax.vmap(lambda d: theorem1_K(d, k))(deg)


@jax.jit
def _mask_prefix(ids, scores, Ks):
    keep = jnp.arange(ids.shape[-1])[None, :] < Ks[:, None]
    return (jnp.where(keep, ids, -1),
            jnp.where(keep, scores, -jnp.inf))


# ----------------------------------------------------------------- driver ----

class BatchProgressiveDriver:
    """Owns a whole batch's lane state across pause/resume (lower engine half).

    Mirrors ``progressive.ProgressiveDriver`` lane-for-lane: the same
    capacity policy, growth thresholds, and stop conditions are applied to
    every lane individually (as host-side numpy vectors), so each lane's
    trajectory is identical to a solo driver on the same query. State lives
    in ``core.lane_state`` pytrees; ``recycle`` re-initializes one lane slot
    for a new query without disturbing siblings.
    """

    def __init__(self, graph: FlatGraph, qs, ef: int, k: int,
                 capacity0: int | None = None,
                 max_capacity: int | None = None,
                 max_signatures: int | None = 1024):
        self.graph = graph
        self.qs = jnp.asarray(qs, jnp.float32)
        self.B = int(self.qs.shape[0])
        self.ef = ef
        self.k = k
        n = graph.size
        if capacity0 is None:
            capacity0 = min(_next_pow2(max(2 * k * ef, 256)), _next_pow2(n))
        self.max_capacity = max_capacity or _next_pow2(n)
        self.caps = np.full(self.B, capacity0, np.int64)
        self.signatures = SignatureLog(max_signatures)
        self.signatures.note("init", self.B, capacity0)
        self.state = lane_state.init_lanes(graph, self.qs, capacity0)
        self.stats = BatchSearchStats.zeros(self.B)

    # -- capacity management ------------------------------------------------
    @property
    def physical_capacity(self) -> int:
        return lane_state.physical_capacity(self.state)

    def _ensure_physical(self, cap: int) -> None:
        self.state = lane_state.pad_lanes(self.state, cap)

    def recycle(self, lane: int, q, capacity0: int) -> None:
        """Hand lane ``lane`` to a new query: fresh solo-equivalent state at
        logical capacity ``capacity0``, stats zeroed, siblings untouched."""
        self._ensure_physical(capacity0)
        self.signatures.note("recycle", self.B, self.physical_capacity)
        self.state = lane_state.recycle_lane(self.graph, self.state, lane, q)
        self.qs = self.qs.at[lane].set(jnp.asarray(q, jnp.float32))
        self.caps[lane] = capacity0
        self.stats.reset_lane(lane)

    def _grow_lanes(self, req: np.ndarray, mask: np.ndarray) -> None:
        """Grow each masked lane to next_pow2(req) (clamped), per-bucket.

        Same policy as ``ProgressiveDriver._grow_to`` per lane; lanes landing
        on the same power-of-two bucket are rebuilt together in one vmapped
        exact rebuild, with the bucket padded to a power-of-two lane count so
        rebuild signatures stay logarithmic in batch size.
        """
        targets = np.array([min(_next_pow2(int(r)), self.max_capacity)
                            for r in req])
        grow = mask & (targets > self.caps)
        if not grow.any():
            return
        self._ensure_physical(int(targets[grow].max()))
        C = self.physical_capacity
        for cap in sorted(set(int(c) for c in targets[grow])):
            idx = np.flatnonzero(grow & (targets == cap))
            m = len(idx)
            padded = pow2_padded_indices(idx)
            g = len(padded)
            jidx = jnp.asarray(padded)
            sub = lane_state.select_lanes(self.state, jidx)
            sub = lane_state.slice_queue_capacity(sub, cap)
            self.signatures.note("rebuild", g, cap)
            rebuilt = _rebuild_lanes(self.graph, self.qs[jidx], sub, cap)
            q = lane_state.pad_queue(rebuilt.queue, C - cap)
            ridx = jnp.asarray(idx)
            bq = self.state.queue
            self.state = bs.SearchState(
                qmod.Queue(bq.ids.at[ridx].set(q.ids[:m]),
                           bq.scores.at[ridx].set(q.scores[:m]),
                           bq.stable.at[ridx].set(q.stable[:m])),
                self.state.visited, self.state.steps)
            self.caps[idx] = cap
            self.stats.growths[idx] += 1

    # -- search bursts ------------------------------------------------------
    def ensure_stable(self, targets: np.ndarray,
                      min_values: np.ndarray | None = None,
                      active: np.ndarray | None = None) -> np.ndarray:
        """Resume every active lane until its first ``targets[i]`` candidates
        are stable (or its frontier drops below ``min_values[i]``).
        Returns the per-lane stable prefix length."""
        n = self.graph.size
        if active is None:
            active = np.ones(self.B, bool)
        if not active.any():
            return self.stable_prefix_len()
        targets = np.minimum(np.asarray(targets, np.int64), n)
        need = active & (targets + 8 > self.caps)
        self._grow_lanes((targets * 1.5).astype(np.int64) + 64, need)
        if min_values is None:
            min_values = np.full(self.B, -np.inf, np.float32)
        sl = np.where(active, np.minimum(targets, self.caps), 0)
        ms = 4 * self.caps + 64
        self.signatures.note("search", self.B, self.physical_capacity)
        self.state = _batched_search_loop(
            self.graph.vectors, self.graph.neighbors, self.qs, self.state,
            jnp.asarray(self.caps, jnp.int32), jnp.asarray(sl, jnp.int32),
            jnp.asarray(min_values, jnp.float32), jnp.asarray(ms, jnp.int32),
            self.graph.metric)
        self.stats.search_calls[active] += 1
        self.stats.expansions = np.asarray(self.state.steps, np.int64).copy()
        return np.asarray(_batched_stable_count(self.state.queue), np.int64)

    def expand_until_below(self, min_values: np.ndarray,
                           active: np.ndarray) -> np.ndarray:
        """PSS's ProgressiveBeamSearch* per lane: expand while the frontier
        score is >= minValue, growing capacity as needed."""
        stable = np.zeros(self.B, np.int64)
        remaining = active.copy()
        while remaining.any():
            got = self.ensure_stable(np.where(remaining, self.caps, 0),
                                     min_values, remaining)
            stable[remaining] = got[remaining]
            done = (stable < self.caps) | (self.caps >= self.max_capacity)
            remaining = remaining & ~done
            if remaining.any():
                self._grow_lanes(self.caps * 2, remaining)
        return stable

    def stable_prefix_len(self) -> np.ndarray:
        return np.asarray(_batched_stable_count(self.state.queue), np.int64)

    # -- candidate prefixes -------------------------------------------------
    def _buckets(self, Ks: np.ndarray) -> np.ndarray:
        return np.minimum(
            np.maximum(64, np.array([_next_pow2(int(K)) for K in Ks])),
            self.caps)

    def _group_lanes(self, Ks: np.ndarray, active: np.ndarray, ks=None):
        """Group active lanes by (width bucket[, k]) — shared by the masked
        and raw prefix generators. Yields (lane_indices, width,
        padded_jnp_indices, Ks_pad): groups are padded to a power-of-two
        lane count (pad rows keep K=0 -> all-sentinel) so compile
        signatures stay bounded; only the first ``len(lane_indices)`` rows
        are real."""
        Ks = np.minimum(np.asarray(Ks, np.int64), self.caps)
        buckets = self._buckets(Ks)
        groups: dict[tuple, list[int]] = {}
        for i in np.flatnonzero(active):
            key = (int(buckets[i]), -1 if ks is None else int(ks[i]))
            groups.setdefault(key, []).append(i)
        for (width, _k), idx in sorted(groups.items()):
            idx = np.asarray(idx)
            padded = pow2_padded_indices(idx)
            Ks_pad = np.zeros(len(padded), np.int64)
            Ks_pad[:len(idx)] = Ks[idx]
            yield idx, width, jnp.asarray(padded), Ks_pad

    def prefix_groups(self, Ks: np.ndarray, active: np.ndarray, ks=None):
        """Yield (lane_indices, ids, scores) per (width bucket[, k]) group.

        The multi-dispatch diversify/verify stages (PDS, PDS-final, PSS)
        consume prefixes through this: lanes whose prefix lands in the same
        power-of-two bucket (and, when ``ks`` is given, share the same
        ``k``) are processed together at exactly that width. Width changes
        div-A*'s cursor-step accounting (padding slots consume budget), so
        running each lane at its own per-query bucket width — not the batch
        max — is what keeps div-A* results identical to the per-query
        driver. Rows are ``_mask_prefix``-masked: positions >= K carry the
        id=-1 / -inf sentinels.
        """
        for idx, width, jidx, Ks_pad in self._group_lanes(Ks, active, ks):
            self.signatures.note("prefix", len(jidx), width)
            ids, scores = _mask_prefix(
                self.state.queue.ids[jidx, :width],
                self.state.queue.scores[jidx, :width],
                jnp.asarray(Ks_pad, jnp.int32))
            yield idx, ids, scores

    def prefix_groups_raw(self, Ks: np.ndarray, active: np.ndarray, ks=None):
        """Like ``prefix_groups`` but yields the *raw* queue rows plus the
        per-lane budgets: (lane_indices, ids, scores, Ks_pad).

        For consumers that fold the prefix masking into their own dispatch —
        the fused round kernel (``kops.fused_round_batch``) takes the raw
        sorted rows and ``Ks`` and performs masking, gather, adjacency and
        greedy diversification in one call, so a separate ``_mask_prefix``
        launch here would be a wasted round trip.
        """
        for idx, width, jidx, Ks_pad in self._group_lanes(Ks, active, ks):
            yield (idx, self.state.queue.ids[jidx, :width],
                   self.state.queue.scores[jidx, :width], Ks_pad)


# ----------------------------------------------------------------- engine ----

LANE_FREE, LANE_PGS, LANE_PSS, LANE_PDS, LANE_PDS_FIN, LANE_DONE = range(6)

_METHOD_STATUS = {"pss": LANE_PGS, "pgs": LANE_PGS, "pds": LANE_PDS}


class ProgressiveEngine:
    """Per-lane progressive state machine over a ``BatchProgressiveDriver``.

    Each lane independently runs one of the paper's methods with its own
    ``(k, eps, ef)``:

    * ``pgs``  — Alg. 2 rounds: stabilize K*ef, greedy-diversify, grow K.
    * ``pss``  — Alg. 4: the PGS warm start, then div-A* + Theorem-2
      certificate rounds with ProgressiveBeamSearch* resumption.
    * ``pds``  — Alg. 3: Theorem-1 degree schedule rounds, then one
      certified div-A*.

    ``step()`` advances every occupied lane one round (search bursts batched
    across lanes in one dispatch, diversify/verify batched per (width, k)
    group) and returns the lanes that finished. Finished lanes can be
    re-admitted with a **new query** via ``admit`` (lane recycling) — the
    continuous-batching hook the serving scheduler drives. Per-lane results
    are bit-identical to the per-query drivers regardless of admission
    order, because every device op is lane-separable and batch-invariant.

    This is the single-host implementation of the ``core.backend.LaneBackend``
    protocol (``admit``/``step``/``harvest``/``recycle``/``prewarm``/
    ``signature_log``); ``sharded_search.engine.ShardedEngine`` is the mesh
    one, and ``serve.scheduler.LaneScheduler`` drives either.
    """

    methods = ("pss", "pgs", "pds")

    def __init__(self, graph: FlatGraph, num_lanes: int | None = None, *,
                 driver: BatchProgressiveDriver | None = None,
                 max_k: int = 16, default_ef: int = 40,
                 capacity0: int | None = None,
                 max_capacity: int | None = None,
                 max_iters: int = 64, max_expansions: int = 400_000,
                 max_signatures: int | None = 1024,
                 kernel_impl: str | None = None):
        self.graph = graph
        # backend for the fused PGS round ("auto"/"ref"/"interpret"/
        # "pallas"); None defers to kops.set_default_impl / "auto".
        self.kernel_impl = kernel_impl
        if driver is None:
            if num_lanes is None:
                raise ValueError("need num_lanes or driver")
            d = int(graph.vectors.shape[1])
            base_cap = capacity0 or min(256, _next_pow2(graph.size))
            driver = BatchProgressiveDriver(
                graph, jnp.zeros((num_lanes, d), jnp.float32),
                ef=default_ef, k=1, capacity0=base_cap,
                max_capacity=max_capacity, max_signatures=max_signatures)
        self.driver = driver
        self.B = driver.B
        self.max_k = max_k
        self.default_ef = default_ef
        self._capacity0 = capacity0
        self._max_capacity = max_capacity
        self._max_signatures = max_signatures
        self.max_iters = max_iters
        self.max_expansions = max_expansions
        self.status = np.full(self.B, LANE_FREE, np.int8)
        self.to_pss = np.zeros(self.B, bool)
        self.ks = np.full(self.B, 1, np.int64)
        self.epss = np.zeros(self.B, np.float64)
        self.efs = np.full(self.B, default_ef, np.int64)
        self.K = np.zeros(self.B, np.int64)
        self.iters = np.zeros(self.B, np.int64)
        self.maxK = np.full(self.B, graph.size, np.int64)
        self.out_ids = np.full((self.B, max_k), -1, np.int32)
        self.out_sc = np.zeros((self.B, max_k), np.float32)
        self._unharvested: list[int] = []
        #: when True, each certificate-bearing round keeps the lane's sorted
        #: candidate frontier host-side (``last_candidates[lane]`` =
        #: ``(cand_ids, cand_scores, slack_or_None)``) so a result's
        #: Theorem-2 certificate can be audited or cached after harvest —
        #: the single-host mirror of ``ShardedEngine.record_candidates``
        self.record_candidates = False
        self.last_candidates: list = [None] * self.B
        # LaneBackend contract 13: the single-host engine always scores the
        # exact float corpus, so its certificates need no rerank stage
        self.compressed = bool(quant.is_quantized(graph.vectors))

    # -- admission ----------------------------------------------------------
    @property
    def num_lanes(self) -> int:
        return self.B

    @property
    def bytes_per_vector(self) -> float:
        """Stored corpus bytes per vector (f32 graph: ``4 * d``)."""
        return quant.corpus_bytes_per_vector(self.graph.vectors)

    @property
    def signatures(self) -> SignatureLog:
        return self.driver.signatures

    @property
    def signature_log(self) -> SignatureLog:
        return self.driver.signatures

    def free_lanes(self) -> np.ndarray:
        return np.flatnonzero((self.status == LANE_FREE)
                              | (self.status == LANE_DONE))

    def active_count(self) -> int:
        return int(((self.status != LANE_FREE)
                    & (self.status != LANE_DONE)).sum())

    def _set_lane(self, lane: int, k: int, eps: float, ef: int, method: str,
                  max_K: int | None) -> None:
        if method not in _METHOD_STATUS:
            raise ValueError(f"unknown progressive method {method!r}")
        if k > self.max_k:
            raise ValueError(f"k={k} exceeds engine max_k={self.max_k}")
        self.ks[lane] = k
        self.epss[lane] = eps
        self.efs[lane] = ef
        self.K[lane] = k
        self.iters[lane] = 0
        self.maxK[lane] = max_K or self.graph.size
        self.out_ids[lane] = -1
        self.out_sc[lane] = 0.0
        self.last_candidates[lane] = None
        self.to_pss[lane] = method == "pss"
        self.status[lane] = _METHOD_STATUS[method]

    def admit(self, lane: int, q, *, k: int | None = None,
              eps: float | None = None, ef: int | None = None,
              method: str = "pss", max_K: int | None = None) -> None:
        """Recycle lane ``lane`` for a new request (fresh solo-equivalent
        state; bit-identical trajectory to a fresh per-query driver).

        ``q`` is either a query vector with explicit ``k``/``eps`` keywords,
        or a ``core.backend.LaneRequest`` (the protocol form the scheduler
        uses) carrying all of them — in which case no keywords may be given.
        """
        if isinstance(q, LaneRequest):
            if (k, eps, ef, max_K) != (None,) * 4 or method != "pss":
                raise TypeError("pass parameters on the LaneRequest, not as "
                                "admit keywords")
            req = q
            q, k, eps = req.q, req.k, req.eps
            ef, method, max_K = req.ef, req.method, req.max_K
        elif k is None or eps is None:
            raise TypeError("admit needs k= and eps= (or a LaneRequest)")
        if self.status[lane] not in (LANE_FREE, LANE_DONE):
            raise RuntimeError(f"lane {lane} is still occupied")
        if lane in self._unharvested:     # direct re-admission skips harvest
            self._unharvested.remove(lane)
        ef = int(ef or self.default_ef)
        n = self.graph.size
        cap0 = self._capacity0 or min(_next_pow2(max(2 * k * ef, 256)),
                                      _next_pow2(n))
        self.driver.recycle(lane, q, cap0)
        self._set_lane(lane, k, eps, ef, method, max_K)

    def admit_in_place(self, lane: int, *, k: int, eps: float, ef: int,
                       method: str = "pss", max_K: int | None = None) -> None:
        """Admit a lane whose state the driver already initialized (lockstep
        wrappers: the driver was constructed over the real query batch)."""
        self._set_lane(lane, k, eps, ef, method, max_K)

    def harvest(self) -> list[tuple[int, DiverseResult]]:
        """Drain the lanes that finished since the last harvest (protocol
        form of ``step()``'s return + ``result()``); the lanes stay reserved
        until ``recycle``."""
        out = [(lane, self.result(lane)) for lane in self._unharvested]
        self._unharvested = []
        return out

    def recycle(self, lane: int) -> None:
        """Return a harvested lane's slot to the free pool."""
        if self.status[lane] != LANE_DONE:
            raise RuntimeError(f"lane {lane} is not finished")
        self.status[lane] = LANE_FREE

    def swap_graph(self, graph: FlatGraph) -> None:
        """Install a new epoch's graph (the mutable index's rebuild swap).

        Only legal with no occupied lane: per-lane search state (visited
        bitmaps, beam queues) is shaped by the corpus size, so an in-flight
        lane cannot survive a swap — the serving layer drains lanes first
        (contract 15; harvested-but-unrecycled lanes are fine, their
        results live host-side). A fresh driver is built over the new
        graph; the signature log carries across so recompile audits span
        epochs (a grown corpus legitimately traces new shapes).
        """
        if self.active_count():
            raise RuntimeError("cannot swap the graph under occupied lanes "
                               "— drain in-flight lanes first (contract 15)")
        log = self.driver.signatures
        d = int(self.driver.qs.shape[1])
        base_cap = self._capacity0 or min(256, _next_pow2(graph.size))
        self.driver = BatchProgressiveDriver(
            graph, jnp.zeros((self.B, d), jnp.float32),
            ef=self.default_ef, k=1, capacity0=base_cap,
            max_capacity=self._max_capacity,
            max_signatures=self._max_signatures)
        log.note("swap", self.B, graph.size)
        self.driver.signatures = log
        self.graph = graph
        self.compressed = bool(quant.is_quantized(graph.vectors))

    # -- results ------------------------------------------------------------
    def result(self, lane: int) -> DiverseResult:
        """Solo-driver-compatible result for a finished lane."""
        k = int(self.ks[lane])
        ids = self.out_ids[lane, :k].copy()
        sc = self.out_sc[lane, :k].copy()
        return DiverseResult(ids.astype(np.int32), sc.astype(np.float32),
                             float(sc.sum()), self.driver.stats.lane_view(lane))

    def gather(self, k: int) -> BatchDiverseResult:
        """All-lane result at a uniform ``k`` (lockstep wrappers)."""
        ids = self.out_ids[:, :k].copy()
        sc = self.out_sc[:, :k].copy()
        return BatchDiverseResult(ids, sc, sc.sum(axis=1), self.driver.stats)

    # -- the state machine --------------------------------------------------
    def step(self) -> list[int]:
        """Advance every occupied lane one progressive round.

        Stage order (each stage batched over the lanes in that phase, masks
        recomputed between stages so same-step transitions flow downward —
        matching the solo drivers, which run e.g. the first PSS verification
        immediately after the PGS warm start with no search in between):

        1. search burst — PGS/PDS lanes stabilize their first K*ef.
        2. PGS round    — one fused diversify dispatch per group; grow K /
           warm-start PSS / finish.
        3. PDS round    — Theorem-1 degree schedule; update K / go final.
        4. PDS final    — one certified div-A*.
        5. PSS round    — div-A* + Theorem-2 certificate; uncertified lanes
           resume ProgressiveBeamSearch* below their minValue.

        Returns the lane indices that finished during this step.
        """
        finished: list[int] = []
        smask = (self.status == LANE_PGS) | (self.status == LANE_PDS)
        stable = np.zeros(self.B, np.int64)
        if smask.any():
            targets = np.where(smask, self.K * self.efs, 0)
            stable = self.driver.ensure_stable(targets, active=smask)
        gmask = self.status == LANE_PGS
        if gmask.any():
            self._pgs_round(gmask, stable, finished)
        pmask = self.status == LANE_PDS
        if pmask.any():
            self._pds_round(pmask, stable)
        fmask = self.status == LANE_PDS_FIN
        if fmask.any():
            self._pds_final(fmask, finished)
        vmask = self.status == LANE_PSS
        if vmask.any():
            self._pss_round(vmask, finished)
        return finished

    def run_to_completion(self) -> None:
        while self.active_count():
            self.step()

    def _group_eps(self, idx: np.ndarray, g: int) -> jnp.ndarray:
        e = np.zeros(g, np.float32)
        e[:len(idx)] = self.epss[idx]
        return jnp.asarray(e)

    def _finish(self, lane: int, finished: list[int]) -> None:
        self.driver.stats.K_final[lane] = self.K[lane]
        self.status[lane] = LANE_DONE
        self._unharvested.append(int(lane))
        finished.append(int(lane))

    # Alg. 2 round: one fused diversification dispatch over the stabilized
    # prefix — masking, gather, G^eps adjacency, greedy selection and output
    # extraction all inside kops.fused_round_batch (a single pallas_call on
    # the kernel paths; see kernels/fused_round.py).
    def _pgs_round(self, gmask, stable, finished) -> None:
        d, n = self.driver, self.graph.size
        exhausted = gmask & (stable < np.minimum(self.K * self.efs, n))
        self.K = np.where(exhausted, np.maximum(self.K, stable), self.K)
        count = np.zeros(self.B, np.int64)
        for idx, ids, scores, Ks_pad in d.prefix_groups_raw(self.K, gmask,
                                                            ks=self.ks):
            k_g = int(self.ks[idx[0]])
            g, width = ids.shape
            d.signatures.note("fused_round", g, width, k_g)
            sel_ids, sel_sc, cnt, _cert = kops.fused_round_batch(
                self.graph.vectors, ids, scores, Ks_pad,
                self._group_eps(idx, g), k_g, self.graph.metric,
                impl=self.kernel_impl)
            cnt_np = np.asarray(cnt)
            sid_np, ssc_np = np.asarray(sel_ids), np.asarray(sel_sc)
            for gi, lane in enumerate(idx):
                count[lane] = cnt_np[gi]
                self.out_ids[lane, :k_g] = sid_np[gi]
                self.out_sc[lane, :k_g] = ssc_np[gi]
        d.stats.div_calls[gmask] += 1
        success = gmask & (count >= self.ks)
        ex_term = gmask & ~success & exhausted
        d.stats.exhausted |= ex_term
        cont = gmask & ~success & ~ex_term
        self.K = np.where(cont, self.K + self.ks, self.K)
        self.iters[cont] += 1
        iter_term = cont & (self.iters >= self.max_iters)
        for lane in np.flatnonzero(success | ex_term | iter_term):
            if self.to_pss[lane]:
                d.stats.K_final[lane] = self.K[lane]
                self.status[lane] = LANE_PSS
                self.iters[lane] = 0
            else:
                self._finish(lane, finished)

    # Alg. 3 round: Theorem-1 degree schedule for the next K.
    def _pds_round(self, pmask, stable) -> None:
        d, n = self.driver, self.graph.size
        K_new = np.zeros(self.B, np.int64)
        for idx, ids, scores in d.prefix_groups(self.K, pmask, ks=self.ks):
            k_g = int(self.ks[idx[0]])
            g, width = ids.shape
            d.signatures.note("adjacency", g, width)
            adj = _batched_adjacency(self.graph.vectors, ids,
                                     self._group_eps(idx, g),
                                     self.graph.metric)
            d.signatures.note("theorem1", g, width, k_g)
            kn = np.asarray(_batched_theorem1(adj, ids >= 0, k_g))
            K_new[idx] = kn[:len(idx)]
        K_new = np.minimum(K_new, n)
        ex = pmask & (K_new > self.maxK)
        d.stats.exhausted |= ex
        fin_stable = pmask & ~ex & (stable >= np.minimum(K_new * self.efs, n))
        cont = pmask & ~ex & ~fin_stable
        self.K = np.where(fin_stable | cont, K_new, self.K)
        # (the per-query driver's third break — stable < min(K*ef, n) while
        # stable >= n — is vacuous and intentionally not replicated)
        self.iters[cont] += 1
        iter_term = cont & (self.iters >= self.max_iters)
        self.status[ex | fin_stable | iter_term] = LANE_PDS_FIN

    # Alg. 3 final: one certified div-A* over the scheduled prefix.
    def _pds_final(self, fmask, finished) -> None:
        d = self.driver
        for idx, ids, scores in d.prefix_groups(self.K, fmask, ks=self.ks):
            k_g = int(self.ks[idx[0]])
            g, width = ids.shape
            d.signatures.note("adjacency", g, width)
            adj = _batched_adjacency(self.graph.vectors, ids,
                                     self._group_eps(idx, g),
                                     self.graph.metric)
            d.signatures.note("div_astar", g, width, k_g)
            masked = jnp.where(ids >= 0, scores, -jnp.inf)
            res, _ = _batched_div_astar(masked, adj, k_g, self.max_expansions)
            sets_np = np.asarray(res.best_sets)
            complete_np = np.asarray(res.complete)
            ids_np, sc_np = np.asarray(ids), np.asarray(scores)
            for gi, lane in enumerate(idx):
                s = sets_np[gi, k_g - 1]
                self.out_ids[lane, :k_g] = np.where(
                    s >= 0, ids_np[gi][np.maximum(s, 0)], -1)
                self.out_sc[lane, :k_g] = np.where(
                    s >= 0, sc_np[gi][np.maximum(s, 0)], 0.0)
                d.stats.certified[lane] = (bool(complete_np[gi])
                                           and not bool(d.stats.exhausted[lane]))
                if self.record_candidates:
                    # pds certificates are Theorem-1-shaped: no minValue
                    # slack to hand over — consumers must re-audit
                    Kl = int(min(self.K[lane], width))
                    self.last_candidates[lane] = (
                        ids_np[gi, :Kl].astype(np.int32).copy(),
                        sc_np[gi, :Kl].astype(np.float32).copy(), None)
        d.stats.div_calls[fmask] += 1
        for lane in np.flatnonzero(fmask):
            self._finish(lane, finished)

    # Alg. 4 round: div-A* + Theorem-2 certificate, then resumption.
    def _pss_round(self, vmask, finished) -> None:
        d, n = self.driver, self.graph.size
        over = vmask & (self.iters >= self.max_iters)
        for lane in np.flatnonzero(over):
            self._finish(lane, finished)
        mask = vmask & ~over
        if not mask.any():
            return
        self.iters[mask] += 1
        self.K = np.where(mask, np.maximum(self.ks, np.minimum(self.K, n)),
                          self.K)
        min_values = np.full(self.B, -np.inf)
        s_K = np.full(self.B, -np.inf)
        complete = np.zeros(self.B, bool)
        for idx, ids, scores in d.prefix_groups(self.K, mask, ks=self.ks):
            k_g = int(self.ks[idx[0]])
            g, width = ids.shape
            d.signatures.note("adjacency", g, width)
            adj = _batched_adjacency(self.graph.vectors, ids,
                                     self._group_eps(idx, g),
                                     self.graph.metric)
            d.signatures.note("div_astar", g, width, k_g)
            masked = jnp.where(ids >= 0, scores, -jnp.inf)
            res, mv = _batched_div_astar(masked, adj, k_g, self.max_expansions)
            best_scores_np = np.asarray(res.best_scores)
            sets_np = np.asarray(res.best_sets)
            complete_np = np.asarray(res.complete)
            mv_np = np.asarray(mv, np.float64)
            ids_np, sc_np = np.asarray(ids), np.asarray(scores)
            for gi, lane in enumerate(idx):
                complete[lane] = complete_np[gi]
                min_values[lane] = mv_np[gi]
                if np.isfinite(best_scores_np[gi, k_g - 1]):
                    s = sets_np[gi, k_g - 1]
                    self.out_ids[lane, :k_g] = np.where(
                        s >= 0, ids_np[gi][np.maximum(s, 0)], -1)
                    self.out_sc[lane, :k_g] = np.where(
                        s >= 0, sc_np[gi][np.maximum(s, 0)], 0.0)
                s_K[lane] = (sc_np[gi, self.K[lane] - 1]
                             if self.K[lane] <= width else -np.inf)
                if self.record_candidates:
                    Kl = int(min(self.K[lane], width))
                    self.last_candidates[lane] = (
                        ids_np[gi, :Kl].astype(np.int32).copy(),
                        sc_np[gi, :Kl].astype(np.float32).copy(),
                        float(min_values[lane] - s_K[lane]))
        d.stats.div_calls[mask] += 1
        certified = mask & (min_values > s_K)
        d.stats.certified |= certified & complete
        stop = mask & ~certified & (d.stats.exhausted | (self.K >= n))
        for lane in np.flatnonzero(certified | stop):
            self._finish(lane, finished)
        rem = mask & ~certified & ~stop
        if not rem.any():
            return
        stable_before = d.stable_prefix_len()
        stable = d.expand_until_below(np.asarray(min_values, np.float32), rem)
        no_prog = rem & (stable <= stable_before)
        d.stats.exhausted |= no_prog
        hard = no_prog & ((stable >= n) | (d.caps >= d.max_capacity))
        self.K = np.where(rem & hard, np.minimum(stable, n), self.K)
        self.K = np.where(rem & ~hard,
                          np.maximum(self.ks, stable // self.efs), self.K)

    # -- prewarm ------------------------------------------------------------
    def prewarm(self, *, max_capacity: int | None = None,
                ks: tuple = (), widths: tuple = ()) -> list[tuple]:
        """Compile the capacity ladder ahead of serving.

        Walks the power-of-two physical capacities from the current one up to
        ``max_capacity`` (default: the driver's max) and compiles the search
        burst, lane recycle, and every power-of-two growth-bucket rebuild at
        each rung, using throwaway states (the live lane state is untouched
        and the physical capacity is NOT grown — growth stays on-demand; this
        only fills XLA's compile cache so mid-serving growth never pays a
        trace). Optionally pre-compiles the diversify/verify stages for the
        given ``ks`` x ``widths`` grids. Returns the signatures warmed.
        """
        d = self.driver
        top = min(max_capacity or d.max_capacity, d.max_capacity)
        dim = int(self.graph.vectors.shape[1])
        qs0 = jnp.zeros((self.B, dim), jnp.float32)
        caps_ladder = []
        c = d.physical_capacity
        while True:
            caps_ladder.append(c)
            if c >= top:
                break
            c *= 2
        group_sizes = pow2_group_sizes(self.B)
        warmed: list[tuple] = []

        def note(kind, *shape):
            d.signatures.note(kind, *shape)
            warmed.append((kind, *shape))

        zeros_b = jnp.zeros(self.B, jnp.int32)
        for cap in caps_ladder:
            state = lane_state.init_lanes(self.graph, qs0, cap)
            note("init", self.B, cap)
            # zero step budget: compiles the burst, executes nothing
            _batched_search_loop(
                self.graph.vectors, self.graph.neighbors, qs0, state,
                jnp.full(self.B, cap, jnp.int32), zeros_b,
                jnp.zeros(self.B, jnp.float32), zeros_b, self.graph.metric
            ).queue.ids.block_until_ready()
            note("search", self.B, cap)
            lane_state.recycle_lane(self.graph, state, 0,
                                    np.zeros(dim, np.float32))
            note("recycle", self.B, cap)
            for g in group_sizes:
                sub = lane_state.select_lanes(state,
                                              jnp.zeros(g, jnp.int32))
                sub = lane_state.slice_queue_capacity(sub, cap)
                _rebuild_lanes(self.graph, jnp.zeros((g, dim), jnp.float32),
                               sub, cap)
                note("rebuild", g, cap)
        for k in ks:
            for width in widths:
                for g in group_sizes:
                    ids = jnp.full((g, width), -1, jnp.int32)
                    sc = jnp.full((g, width), -jnp.inf, jnp.float32)
                    note("prefix", g, width)
                    _mask_prefix(ids, sc, jnp.zeros(g, jnp.int32))
                    note("adjacency", g, width)
                    adj = _batched_adjacency(self.graph.vectors, ids,
                                             jnp.zeros(g, jnp.float32),
                                             self.graph.metric)
                    note("greedy", g, width, k)
                    kops.greedy_diversify_batch(sc, adj, k, valid=ids >= 0)
                    note("fused_round", g, width, k)
                    kops.fused_round_batch(self.graph.vectors, ids, sc,
                                           np.zeros(g, np.int64),
                                           jnp.zeros(g, jnp.float32),
                                           k, self.graph.metric,
                                           impl=self.kernel_impl)
                    note("theorem1", g, width, k)
                    _batched_theorem1(adj, ids >= 0, k)
                    note("div_astar", g, width, k)
                    _batched_div_astar(sc, adj, k, self.max_expansions)
        return warmed


# ------------------------------------------------------- lockstep wrappers --

def _run_lockstep(graph: FlatGraph, qs, k: int, eps: float, ef: int,
                  method: str, max_iters: int, max_expansions: int,
                  driver: BatchProgressiveDriver | None = None,
                  max_K: int | None = None,
                  kernel_impl: str | None = None
                  ) -> tuple[BatchDiverseResult, ProgressiveEngine]:
    qs = jnp.asarray(qs, jnp.float32)
    if driver is None:
        driver = BatchProgressiveDriver(graph, qs, ef, k)
    engine = ProgressiveEngine(graph, driver=driver, max_k=k, default_ef=ef,
                               max_iters=max_iters,
                               max_expansions=max_expansions,
                               kernel_impl=kernel_impl)
    for lane in range(driver.B):
        engine.admit_in_place(lane, k=k, eps=eps, ef=ef, method=method,
                              max_K=max_K)
    engine.run_to_completion()
    return engine.gather(k), engine


def batch_pgs(graph: FlatGraph, qs, k: int, eps: float, ef: int = 40,
              driver: BatchProgressiveDriver | None = None,
              max_iters: int = 64
              ) -> tuple[BatchDiverseResult, BatchProgressiveDriver, np.ndarray]:
    """Batched Alg. 2: returns (result, driver, K_final) — batch_pss reuses
    the driver and per-lane K exactly like the per-query pgs/pss pair."""
    res, engine = _run_lockstep(graph, qs, k, eps, ef, "pgs", max_iters,
                                400_000, driver=driver)
    return res, engine.driver, engine.K.copy()


def batch_pds(graph: FlatGraph, qs, k: int, eps: float, ef: int = 40,
              max_K: int | None = None, max_iters: int = 64,
              max_expansions: int = 400_000) -> BatchDiverseResult:
    """Batched Alg. 3 (Theorem-1 degree schedule): per-lane results identical
    to the per-query ``pds`` driver."""
    res, _ = _run_lockstep(graph, qs, k, eps, ef, "pds", max_iters,
                           max_expansions, max_K=max_K)
    return res


def _concat_results(parts: list[BatchDiverseResult]) -> BatchDiverseResult:
    stats = BatchSearchStats(*[
        np.concatenate([getattr(p.stats, f.name) for p in parts])
        for f in dataclasses.fields(BatchSearchStats)])
    return BatchDiverseResult(np.vstack([p.ids for p in parts]),
                              np.vstack([p.scores for p in parts]),
                              np.concatenate([p.totals for p in parts]),
                              stats)


def batch_pss(graph: FlatGraph, qs, k: int, eps: float, ef: int = 40,
              max_iters: int = 64, max_expansions: int = 400_000,
              streams: int = 1,
              kernel_impl: str | None = None) -> BatchDiverseResult:
    """Batched Alg. 4 — the lockstep engine entry point.

    Phase 1 runs batched PGS (warm start + a size-k diverse set exists among
    the candidates). Each round then builds every active lane's G^eps, runs
    batched div-A*, applies the Theorem-2 certificate per lane, and resumes
    ProgressiveBeamSearch* only for the uncertified lanes. Per-lane results
    are identical to the per-query ``pss`` driver. (For continuous batching —
    new queries admitted into lanes freed by certified ones — drive
    ``ProgressiveEngine`` through ``serve.scheduler.LaneScheduler``.)

    ``streams > 1`` splits the batch into that many sub-batches driven from
    worker threads, overlapping host orchestration with device work (jax
    dispatch releases the GIL). Every lane's trajectory is independent of
    its batch, so streaming changes nothing about the results; ``streams=2``
    is the measured sweet spot on CPU hosts.
    """
    qs = jnp.asarray(qs, jnp.float32)
    if streams > 1 and qs.shape[0] > 1:
        parts = np.array_split(np.arange(qs.shape[0]),
                               min(streams, qs.shape[0]))
        with concurrent.futures.ThreadPoolExecutor(len(parts)) as ex:
            futs = [ex.submit(batch_pss, graph, qs[jnp.asarray(c)], k, eps,
                              ef, max_iters, max_expansions, 1, kernel_impl)
                    for c in parts]
            return _concat_results([f.result() for f in futs])
    res, _ = _run_lockstep(graph, qs, k, eps, ef, "pss", max_iters,
                           max_expansions, kernel_impl=kernel_impl)
    return res


def batch_progressive_search(graph: FlatGraph, qs, k: int, eps: float,
                             method: str = "pss", ef: int = 40,
                             **kwargs) -> BatchDiverseResult:
    """One entry point for the batched progressive engine."""
    if method == "pss":
        return batch_pss(graph, qs, k, eps, ef, **kwargs)
    if method == "pds":
        return batch_pds(graph, qs, k, eps, ef, **kwargs)
    if method == "pgs":
        res, _, _ = batch_pgs(graph, qs, k, eps, ef, **kwargs)
        return res
    raise ValueError(f"unknown batched progressive method {method!r}")
