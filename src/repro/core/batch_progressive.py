"""Batched progressive serving engine (paper Alg. 2-4 over a request batch).

The per-query drivers (``pgs``/``pds``/``pss``) are faithful but serve one
query at a time: every pause/inspect/resume cycle costs a host round-trip and
a single-lane device dispatch. This module runs the *same* progressive
framework over a whole batch at once:

* **One-dispatch device bursts** — a single ``lax.map`` dispatch advances
  every lane's beam-search ``while_loop`` to that lane's own stop condition
  (stable-prefix target reached, frontier below its Theorem-2 ``minValue``,
  or step budget); lanes run lane-serial on device, paying exactly the sum
  of per-lane work with none of the per-query dispatch overhead (see
  ``_batched_search_loop`` for the lax.map-vs-vmap trade-off).
* **Per-lane logical capacity** — all lanes share one fixed-shape state at
  the max bucket capacity, but each lane's queue is clamped to its own
  logical capacity after every insert, so per-lane semantics are *bit-exact*
  with a solo ``ProgressiveDriver`` at that capacity.
* **Bucketed growth** — lanes whose candidate budget outgrows their capacity
  are grouped by next-power-of-two target and rebuilt together with the
  exact rebuild of ``beam_search.rebuild_for_growth`` (one vmapped rebuild
  per bucket), preserving the unbounded-queue semantics of the paper.
* **Batched diversify + verify** — adjacency builds and greedy selection
  (the (B, K)-grid Pallas kernel) run vmapped across the batch, div-A*
  lane-serial (its trip counts are heavy-tailed); Theorem-2 certificates
  come back per lane and only uncertified lanes re-enter the progressive
  loop.

Entry points: ``batch_pgs`` (Alg. 2), ``batch_pss`` (Alg. 4, the default
serving path), both returning a ``BatchDiverseResult`` whose per-lane
ids/scores match the per-query drivers exactly.

Parity scope: every per-lane decision replicates the per-query driver's
formulas, queue-score computations are batch-invariant by construction
(``query_sim``'s reduce form, the rank-merge insert, top_k rebuilds), and
``tests/test_batch_progressive.py`` enforces bit-equality on the CPU
reference path. The one caveat is the adjacency build: ``sims > eps`` edges
come from matmuls whose accumulation order XLA may vary across batch shapes
and backends, so a pair landing within one rounding step of ``eps`` could in
principle flip an edge relative to the solo driver (which additionally uses
``extend_adjacency``'s different-shaped matmul). Measured bit-stable across
vmap/widths on CPU; re-validate the parity suite before relying on
bit-equality on a new backend.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import beam_search as bs
from repro.core import div_astar as da
from repro.core import queue as qmod
from repro.core.graph import FlatGraph
from repro.core.progressive import _next_pow2
from repro.core.theorems import theorem2_min_value
from repro.kernels import ops as kops


# --------------------------------------------------------------- results ----

@dataclasses.dataclass
class BatchSearchStats:
    """Per-lane counters mirroring ``progressive.SearchStats``."""
    expansions: np.ndarray
    growths: np.ndarray
    search_calls: np.ndarray
    div_calls: np.ndarray
    certified: np.ndarray
    exhausted: np.ndarray
    K_final: np.ndarray

    @classmethod
    def zeros(cls, b: int) -> "BatchSearchStats":
        return cls(expansions=np.zeros(b, np.int64),
                   growths=np.zeros(b, np.int64),
                   search_calls=np.zeros(b, np.int64),
                   div_calls=np.zeros(b, np.int64),
                   certified=np.zeros(b, bool),
                   exhausted=np.zeros(b, bool),
                   K_final=np.zeros(b, np.int64))


class BatchDiverseResult(NamedTuple):
    ids: np.ndarray      # int32[B, k], -1 padded
    scores: np.ndarray   # f32[B, k]
    totals: np.ndarray   # f32[B]
    stats: BatchSearchStats


# ------------------------------------------------------- device functions ----

@functools.partial(jax.jit, static_argnames=("capacity",))
def _batched_init(graph: FlatGraph, qs: jnp.ndarray, capacity: int):
    return jax.vmap(lambda q: bs.init_state(graph, q, capacity))(qs)


def _pad_queue(queue: qmod.Queue, pad: int) -> qmod.Queue:
    """Extend a queue's last axis with empty-slot sentinels (id=-1,
    score=-inf, stable=True) — the one place the sentinel convention for
    padding lives in this module."""
    if pad == 0:
        return queue
    spec = [(0, 0)] * (queue.ids.ndim - 1) + [(0, pad)]
    return qmod.Queue(
        ids=jnp.pad(queue.ids, spec, constant_values=-1),
        scores=jnp.pad(queue.scores, spec, constant_values=-np.inf),
        stable=jnp.pad(queue.stable, spec, constant_values=True),
    )


def _merge_insert(queue: qmod.Queue, new_ids: jnp.ndarray,
                  new_scores: jnp.ndarray, new_mask: jnp.ndarray) -> qmod.Queue:
    """Bit-identical replacement for ``queue.insert`` on an already-sorted
    queue. ``queue.insert`` re-sorts all C+M entries with an O(C log C)
    *comparator* sort per expansion step — the dominant cost of the burst
    at (B, C) shapes. Here each entry's merged position is its rank
    under the same (score desc, id asc) order, computed from an O(C*M)
    vectorized comparison matrix (M = M0 graph degree, so this is the same
    cost class as the dedup matrix insert already builds). Ties (only the
    empty-slot sentinel) resolve queue-first / index-order, matching the
    stable lexsort exactly."""
    cap = queue.capacity
    m = new_ids.shape[0]
    b_ids, b_scores, b_stable = qmod.dedup_candidates(
        queue, new_ids, new_scores, new_mask)
    a_ids, a_scores = queue.ids, queue.scores

    def before(s1, i1, s2, i2):
        # strict (score desc, id asc) precedence
        return (s1 > s2) | ((s1 == s2) & (i1 < i2))

    # a entries keep their rank among a (queue is sorted); b entries ahead
    # of a_i push it back. Full ties (empty sentinels) resolve a-first.
    # rank of each b among b (strict order; sentinel ties resolve by index)
    bb = before(b_scores[:, None], b_ids[:, None],
                b_scores[None, :], b_ids[None, :])
    tie_bb = (b_scores[:, None] == b_scores[None, :]) & \
        (b_ids[:, None] == b_ids[None, :]) & (
        jnp.arange(m)[:, None] < jnp.arange(m)[None, :])
    rank_b = jnp.sum(bb | tie_bb, axis=0)
    inv_rank = jnp.argmax(rank_b[:, None] == jnp.arange(m)[None, :], axis=0)
    bs_ids, bs_scores = b_ids[inv_rank], b_scores[inv_rank]
    bs_stable = b_stable[inv_rank]
    # merged slot of each sorted-b element: a entries ahead of it (ties:
    # queue entries first, matching the stable concat-lexsort), plus its
    # own rank among b
    a_before_b = before(a_scores[:, None], a_ids[:, None],
                        bs_scores[None, :], bs_ids[None, :]) | (
        (a_scores[:, None] == bs_scores[None, :])
        & (a_ids[:, None] == bs_ids[None, :]))
    pos_b = jnp.sum(a_before_b, axis=0) + jnp.arange(m)
    # slot-wise gather (no scatter, no comparator sort): slot r holds
    # b_sorted[cb[r]] if some b lands at r, else a[r - cb[r]]
    slots = jnp.arange(cap)
    cb = jnp.sum(pos_b[None, :] < slots[:, None], axis=1)
    is_b = jnp.any(pos_b[None, :] == slots[:, None], axis=1)
    ai = jnp.minimum(slots - cb, cap - 1)
    bi = jnp.minimum(cb, m - 1)
    return qmod.Queue(
        ids=jnp.where(is_b, bs_ids[bi], a_ids[ai]),
        scores=jnp.where(is_b, bs_scores[bi], a_scores[ai]),
        stable=jnp.where(is_b, bs_stable[bi], queue.stable[ai]),
    )


@functools.partial(jax.jit, static_argnames=("graph_metric",))
def _batched_search_loop(vectors, neighbors, qs, state, caps, stable_limits,
                         min_values, max_steps, graph_metric: str):
    """One-dispatch burst: every lane runs its own beam-search while_loop.

    Identical to ``beam_search._search_loop`` per lane, plus the logical
    capacity clamp: entries at positions >= cap are forced back to the empty
    sentinel after each insert, which is exactly a capacity-``cap`` queue
    stored in a wider array.

    Lanes run lane-serial on device (``lax.map``): lane step counts vary
    several-fold, so a vmapped while_loop would charge every lane the
    straggler's trip count, while ``lax.map`` pays exactly the sum of
    per-lane work with none of the per-call dispatch overhead the per-query
    driver loop pays (measured ~2x faster than the vmapped variant on CPU
    even before straggler effects; revisit per-backend — on TPU the lockstep
    vmap variant may win back).
    """
    C = state.queue.ids.shape[-1]
    pos = jnp.arange(C)

    def one(args):
        q, st, cap, sl, mv, ms = args

        def clamp(queue: qmod.Queue) -> qmod.Queue:
            live = pos < cap
            return qmod.Queue(jnp.where(live, queue.ids, -1),
                              jnp.where(live, queue.scores, qmod.NEG_INF),
                              jnp.where(live, queue.stable, True))

        # the frontier pointer rides in the carry so the queue is scanned
        # once per expansion, not once in cond and again in body
        def cond(c):
            st, p, exists = c
            score_ok = st.queue.scores[p] >= mv
            return exists & score_ok & (st.steps < ms)

        def body(c):
            st, p, _ = c
            queue, visited, steps = st
            node = queue.ids[p]
            queue = qmod.Queue(queue.ids, queue.scores,
                               queue.stable.at[p].set(True))
            visited = visited.at[node].set(True)
            nbrs = neighbors[node]
            safe = jnp.maximum(nbrs, 0)
            fresh = (nbrs >= 0) & ~visited[safe]
            sims = kops.batch_similarity(q, vectors[safe], graph_metric)
            queue = clamp(_merge_insert(queue, nbrs, sims, fresh))
            p2, exists2 = qmod.first_unstable(queue, sl)
            return bs.SearchState(queue, visited, steps + 1), p2, exists2

        p0, exists0 = qmod.first_unstable(st.queue, sl)
        out, _, _ = jax.lax.while_loop(cond, body, (st, p0, exists0))
        return out

    return jax.lax.map(
        one, (qs, state, caps, stable_limits, min_values, max_steps))


@functools.partial(jax.jit, static_argnames=("new_capacity",))
def _rebuild_lanes(graph: FlatGraph, qs, state, new_capacity: int):
    """Exact rebuild of a growth bucket's lanes.

    Same construction as ``beam_search.rebuild_for_growth`` — rescore
    (visited ∪ queue), rebuild the queue — but the new queue is selected
    with ``lax.top_k`` instead of a full N-entry comparator sort: entries
    are indexed by node id, and top_k's documented lower-index-first tie
    rule is exactly the queue's (score desc, id asc) order, so the result
    is bit-identical at a fraction of the cost. Bit-parity of the rescoring
    itself holds because ``query_sim`` uses a batch-invariant reduce (see
    ``similarity.query_sim``)."""
    n = graph.size
    k0 = min(new_capacity, n)
    pad = new_capacity - k0

    def one(q, st):
        vis_scores = kops.batch_similarity(q, graph.vectors, graph.metric)
        in_queue = jnp.zeros((n,), jnp.bool_).at[
            jnp.maximum(st.queue.ids, 0)].set(st.queue.ids >= 0)
        frontier_unstable = jnp.zeros((n,), jnp.bool_).at[
            jnp.maximum(st.queue.ids, 0)].set(
            (st.queue.ids >= 0) & ~st.queue.stable)
        member = st.visited | in_queue
        scores = jnp.where(member, vis_scores, qmod.NEG_INF)
        top_scores, sel = jax.lax.top_k(scores, k0)
        valid = top_scores > qmod.NEG_INF  # similarities are always finite
        queue = _pad_queue(qmod.Queue(
            ids=jnp.where(valid, sel.astype(jnp.int32), -1),
            scores=jnp.where(valid, top_scores, qmod.NEG_INF),
            stable=jnp.where(valid, ~frontier_unstable[sel], True)), pad)
        return bs.SearchState(queue, st.visited, st.steps)

    return jax.vmap(one)(qs, state)


_batched_stable_count = jax.jit(jax.vmap(qmod.stable_count))


@functools.partial(jax.jit, static_argnames=("metric",))
def _batched_adjacency(vectors, ids, eps, metric: str):
    vecs = vectors[jnp.maximum(ids, 0)]
    valid = ids >= 0
    return jax.vmap(
        lambda v, m: kops.pairwise_adjacency(v, eps, metric, m))(vecs, valid)


@functools.partial(jax.jit, static_argnames=("k", "max_expansions"))
def _batched_div_astar(scores, adj, k: int, max_expansions: int):
    """Batched div-A* + Theorem-2 minValue per lane.

    Lane-serial on device (``lax.map``) rather than vmapped: div-A* trip
    counts are heavy-tailed (the paper's §IV hard cases run 10-100x the
    median), and a vmapped while_loop would make every lane pay the
    straggler's trips with both cond branches materialized. ``lax.map``
    keeps the per-query cost profile — one dispatch for the whole batch,
    branch-and-bound pruning intact per lane."""
    def one(s, a):
        r = da.div_astar(s, a, k, max_expansions)
        return r, theorem2_min_value(r.best_scores, k)
    return jax.lax.map(lambda args: one(*args), (scores, adj))


@functools.partial(jax.jit, static_argnames=("width",))
def _batched_prefix(queue_ids, queue_scores, Ks, width: int):
    ids = queue_ids[:, :width]
    scores = queue_scores[:, :width]
    keep = jnp.arange(width)[None, :] < Ks[:, None]
    return (jnp.where(keep, ids, -1),
            jnp.where(keep, scores, -jnp.inf))


# ----------------------------------------------------------------- driver ----

class BatchProgressiveDriver:
    """Owns a whole batch's progressive search state across pause/resume.

    Mirrors ``progressive.ProgressiveDriver`` lane-for-lane: the same
    capacity policy, growth thresholds, and stop conditions are applied to
    every lane individually (as host-side numpy vectors), so each lane's
    trajectory is identical to a solo driver on the same query.
    """

    def __init__(self, graph: FlatGraph, qs, ef: int, k: int,
                 capacity0: int | None = None,
                 max_capacity: int | None = None):
        self.graph = graph
        self.qs = jnp.asarray(qs, jnp.float32)
        self.B = int(self.qs.shape[0])
        self.ef = ef
        self.k = k
        n = graph.size
        if capacity0 is None:
            capacity0 = min(_next_pow2(max(2 * k * ef, 256)), _next_pow2(n))
        self.max_capacity = max_capacity or _next_pow2(n)
        self.caps = np.full(self.B, capacity0, np.int64)
        self.state = _batched_init(graph, self.qs, capacity0)
        self.stats = BatchSearchStats.zeros(self.B)

    # -- capacity management ------------------------------------------------
    @property
    def physical_capacity(self) -> int:
        return int(self.state.queue.ids.shape[-1])

    def _ensure_physical(self, cap: int) -> None:
        C = self.physical_capacity
        if cap <= C:
            return
        queue = _pad_queue(self.state.queue, cap - C)
        self.state = bs.SearchState(queue, self.state.visited, self.state.steps)

    def _grow_lanes(self, req: np.ndarray, mask: np.ndarray) -> None:
        """Grow each masked lane to next_pow2(req) (clamped), per-bucket.

        Same policy as ``ProgressiveDriver._grow_to`` per lane; lanes landing
        on the same power-of-two bucket are rebuilt together in one vmapped
        exact rebuild.
        """
        targets = np.array([min(_next_pow2(int(r)), self.max_capacity)
                            for r in req])
        grow = mask & (targets > self.caps)
        if not grow.any():
            return
        self._ensure_physical(int(targets[grow].max()))
        C = self.physical_capacity
        for cap in sorted(set(int(c) for c in targets[grow])):
            idx = np.flatnonzero(grow & (targets == cap))
            jidx = jnp.asarray(idx)
            sub = jax.tree_util.tree_map(lambda a: a[jidx], self.state)
            rebuilt = _rebuild_lanes(self.graph, self.qs[jidx], sub, cap)
            q = _pad_queue(rebuilt.queue, C - cap)
            bq = self.state.queue
            self.state = bs.SearchState(
                qmod.Queue(bq.ids.at[jidx].set(q.ids),
                           bq.scores.at[jidx].set(q.scores),
                           bq.stable.at[jidx].set(q.stable)),
                self.state.visited, self.state.steps)
            self.caps[idx] = cap
            self.stats.growths[idx] += 1

    # -- search bursts ------------------------------------------------------
    def ensure_stable(self, targets: np.ndarray,
                      min_values: np.ndarray | None = None,
                      active: np.ndarray | None = None) -> np.ndarray:
        """Resume every active lane until its first ``targets[i]`` candidates
        are stable (or its frontier drops below ``min_values[i]``).
        Returns the per-lane stable prefix length."""
        n = self.graph.size
        if active is None:
            active = np.ones(self.B, bool)
        targets = np.minimum(np.asarray(targets, np.int64), n)
        need = active & (targets + 8 > self.caps)
        self._grow_lanes((targets * 1.5).astype(np.int64) + 64, need)
        if min_values is None:
            min_values = np.full(self.B, -np.inf, np.float32)
        sl = np.where(active, np.minimum(targets, self.caps), 0)
        ms = 4 * self.caps + 64
        self.state = _batched_search_loop(
            self.graph.vectors, self.graph.neighbors, self.qs, self.state,
            jnp.asarray(self.caps, jnp.int32), jnp.asarray(sl, jnp.int32),
            jnp.asarray(min_values, jnp.float32), jnp.asarray(ms, jnp.int32),
            self.graph.metric)
        self.stats.search_calls[active] += 1
        self.stats.expansions = np.asarray(self.state.steps, np.int64).copy()
        return np.asarray(_batched_stable_count(self.state.queue), np.int64)

    def expand_until_below(self, min_values: np.ndarray,
                           active: np.ndarray) -> np.ndarray:
        """PSS's ProgressiveBeamSearch* per lane: expand while the frontier
        score is >= minValue, growing capacity as needed."""
        stable = np.zeros(self.B, np.int64)
        remaining = active.copy()
        while remaining.any():
            got = self.ensure_stable(np.where(remaining, self.caps, 0),
                                     min_values, remaining)
            stable[remaining] = got[remaining]
            done = (stable < self.caps) | (self.caps >= self.max_capacity)
            remaining = remaining & ~done
            if remaining.any():
                self._grow_lanes(self.caps * 2, remaining)
        return stable

    def stable_prefix_len(self) -> np.ndarray:
        return np.asarray(_batched_stable_count(self.state.queue), np.int64)

    # -- candidate prefixes -------------------------------------------------
    def _buckets(self, Ks: np.ndarray) -> np.ndarray:
        return np.minimum(
            np.maximum(64, np.array([_next_pow2(int(K)) for K in Ks])),
            self.caps)

    def prefix_groups(self, Ks: np.ndarray, active: np.ndarray):
        """Yield (lane_indices, ids, scores) per power-of-two shape bucket.

        The diversify/verify stages consume prefixes through this: lanes
        whose prefix lands in the same bucket are processed together at
        exactly that width. Width changes div-A*'s cursor-step accounting
        (padding slots consume budget), so running each lane at its own
        per-query bucket width — not the batch max — is what keeps div-A*
        results identical to the per-query driver."""
        Ks = np.minimum(np.asarray(Ks, np.int64), self.caps)
        buckets = self._buckets(Ks)
        groups: dict[int, list[int]] = {}
        for i in np.flatnonzero(active):
            groups.setdefault(int(buckets[i]), []).append(i)
        for width, idx in sorted(groups.items()):
            idx = np.asarray(idx)
            jidx = jnp.asarray(idx)
            ids, scores = _batched_prefix(
                self.state.queue.ids[jidx], self.state.queue.scores[jidx],
                jnp.asarray(Ks[idx], jnp.int32), width)
            yield idx, ids, scores


# ---------------------------------------------------------------- batch PGS --

def batch_pgs(graph: FlatGraph, qs, k: int, eps: float, ef: int = 40,
              driver: BatchProgressiveDriver | None = None,
              max_iters: int = 64
              ) -> tuple[BatchDiverseResult, BatchProgressiveDriver, np.ndarray]:
    """Batched Alg. 2: returns (result, driver, K_final) — batch_pss reuses
    the driver and per-lane K exactly like the per-query pgs/pss pair."""
    if driver is None:
        driver = BatchProgressiveDriver(graph, qs, ef, k)
    B, n = driver.B, graph.size
    K = np.full(B, k, np.int64)
    active = np.ones(B, bool)
    out_ids = np.full((B, k), -1, np.int32)
    out_sc = np.zeros((B, k), np.float32)
    for _ in range(max_iters):
        if not active.any():
            break
        stable = driver.ensure_stable(K * ef, active=active)
        exhausted = stable < np.minimum(K * ef, n)
        K = np.where(active & exhausted, np.maximum(K, stable), K)
        count = np.zeros(B, np.int64)
        for idx, ids, scores in driver.prefix_groups(K, active):
            adj = _batched_adjacency(graph.vectors, ids, eps, graph.metric)
            sel, cnt = kops.greedy_diversify_batch(scores, adj, k,
                                                   valid=ids >= 0)
            count[idx] = np.asarray(cnt)
            sel_np = np.asarray(sel)
            ids_np = np.asarray(ids)
            sc_np = np.asarray(scores)
            for g, i in enumerate(idx):
                s = sel_np[g]
                out_ids[i] = np.where(s >= 0, ids_np[g][np.maximum(s, 0)], -1)
                out_sc[i] = np.where(s >= 0, sc_np[g][np.maximum(s, 0)], 0.0)
        driver.stats.div_calls[active] += 1
        done = active & ((count >= k) | exhausted)
        driver.stats.exhausted |= active & exhausted & (count < k)
        K = np.where(active & ~done, K + k, K)
        active = active & ~done
    driver.stats.K_final = K.copy()
    res = BatchDiverseResult(out_ids, out_sc, out_sc.sum(axis=1),
                             driver.stats)
    return res, driver, K


# ---------------------------------------------------------------- batch PSS --

def _concat_results(parts: list[BatchDiverseResult]) -> BatchDiverseResult:
    stats = BatchSearchStats(*[
        np.concatenate([getattr(p.stats, f.name) for p in parts])
        for f in dataclasses.fields(BatchSearchStats)])
    return BatchDiverseResult(np.vstack([p.ids for p in parts]),
                              np.vstack([p.scores for p in parts]),
                              np.concatenate([p.totals for p in parts]),
                              stats)


def batch_pss(graph: FlatGraph, qs, k: int, eps: float, ef: int = 40,
              max_iters: int = 64, max_expansions: int = 400_000,
              streams: int = 1) -> BatchDiverseResult:
    """Batched Alg. 4 — the progressive serving engine's default path.

    Phase 1 runs batched PGS (warm start + a size-k diverse set exists among
    the candidates). Each round then builds every active lane's G^eps, runs
    batched div-A*, applies the Theorem-2 certificate per lane, and resumes
    ProgressiveBeamSearch* only for the uncertified lanes. Per-lane results
    are identical to the per-query ``pss`` driver.

    ``streams > 1`` splits the batch into that many sub-batches driven from
    worker threads, overlapping host orchestration with device work (jax
    dispatch releases the GIL). Every lane's trajectory is independent of
    its batch, so streaming changes nothing about the results; ``streams=2``
    is the measured sweet spot on CPU hosts.
    """
    qs = jnp.asarray(qs, jnp.float32)
    if streams > 1 and qs.shape[0] > 1:
        parts = np.array_split(np.arange(qs.shape[0]),
                               min(streams, qs.shape[0]))
        with concurrent.futures.ThreadPoolExecutor(len(parts)) as ex:
            futs = [ex.submit(batch_pss, graph, qs[jnp.asarray(c)], k, eps,
                              ef, max_iters, max_expansions) for c in parts]
            return _concat_results([f.result() for f in futs])
    pgs_res, driver, K = batch_pgs(graph, qs, k, eps, ef)
    B, n = driver.B, graph.size
    best_ids = pgs_res.ids.copy()
    best_sc = pgs_res.scores.copy()
    active = np.ones(B, bool)
    for _ in range(max_iters):
        if not active.any():
            break
        K = np.maximum(k, np.minimum(K, n))
        min_values = np.full(B, -np.inf)
        s_K = np.full(B, -np.inf)
        complete = np.zeros(B, bool)
        for idx, ids, scores in driver.prefix_groups(K, active):
            adj = _batched_adjacency(graph.vectors, ids, eps, graph.metric)
            masked = jnp.where(ids >= 0, scores, -jnp.inf)
            res, mv = _batched_div_astar(masked, adj, k, max_expansions)
            best_scores_np = np.asarray(res.best_scores)
            sets_np = np.asarray(res.best_sets)
            complete[idx] = np.asarray(res.complete)
            min_values[idx] = np.asarray(mv, np.float64)
            ids_np = np.asarray(ids)
            sc_np = np.asarray(scores)
            width = ids_np.shape[1]
            for g, i in enumerate(idx):
                if np.isfinite(best_scores_np[g, k - 1]):
                    s = sets_np[g, k - 1]
                    best_ids[i] = np.where(
                        s >= 0, ids_np[g][np.maximum(s, 0)], -1)
                    best_sc[i] = np.where(
                        s >= 0, sc_np[g][np.maximum(s, 0)], 0.0)
                s_K[i] = sc_np[g, K[i] - 1] if K[i] <= width else -np.inf
        driver.stats.div_calls[active] += 1
        certified = active & (min_values > s_K)
        driver.stats.certified |= certified & complete
        active = active & ~certified
        stop = active & (driver.stats.exhausted | (K >= n))
        active = active & ~stop
        if not active.any():
            break
        stable_before = driver.stable_prefix_len()
        stable = driver.expand_until_below(
            np.asarray(min_values, np.float32), active)
        no_progress = active & (stable <= stable_before)
        driver.stats.exhausted |= no_progress
        hard_stop = no_progress & ((stable >= n)
                                   | (driver.caps >= driver.max_capacity))
        K = np.where(active & hard_stop, np.minimum(stable, n), K)
        K = np.where(active & ~hard_stop,
                     np.maximum(k, stable // driver.ef), K)
    driver.stats.K_final = K.copy()
    return BatchDiverseResult(best_ids, best_sc, best_sc.sum(axis=1),
                              driver.stats)


def batch_progressive_search(graph: FlatGraph, qs, k: int, eps: float,
                             method: str = "pss", ef: int = 40,
                             **kwargs) -> BatchDiverseResult:
    """One entry point for the batched progressive engine."""
    if method == "pss":
        return batch_pss(graph, qs, k, eps, ef, **kwargs)
    if method == "pgs":
        res, _, _ = batch_pgs(graph, qs, k, eps, ef, **kwargs)
        return res
    raise ValueError(f"unknown batched progressive method {method!r}")
