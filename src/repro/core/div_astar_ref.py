"""Pure-python div-A* oracle (Qin et al. [20], as adopted by the paper §II-B-1).

Exact max-total-score independent set of size k on a diversity graph, plus
the optimal sets of every size 1..k (needed by Theorem 2 / PSS).

Implementation: depth-first branch-and-bound over candidates in descending
score order with an admissible bound (current score + sum of the best
remaining scores, conflicts ignored). A state is pruned only when its bound
cannot improve the incumbent of ANY size m in (|S|, k] — pruning on size-k
alone could discard states that improve some smaller-size optimum, which
Theorem 2 consumes.

This file is the test oracle for ``repro.core.div_astar`` (the JAX version)
and the ground-truth generator for recall in the benchmarks.
"""
from __future__ import annotations

import numpy as np


def div_astar_ref(scores: np.ndarray, adj: np.ndarray, k: int,
                  node_budget: int | None = None):
    """Returns (best_sets, best_scores, complete).

    best_sets[m]   : list of local indices, the optimal diverse set of size
                     m+1 (or None if no independent set of that size exists).
    best_scores[m] : its total score (or -inf).
    complete       : False if the node budget was exhausted (results are then
                     best-so-far, not certified optimal).
    """
    scores = np.asarray(scores, np.float64)
    n = scores.shape[0]
    adj = np.asarray(adj, bool)
    k = min(k, n)
    order = np.lexsort((np.arange(n), -scores))  # score desc, id asc
    s_sorted = scores[order]
    adj_sorted = adj[np.ix_(order, order)]
    # suffix cumulative of sorted scores: cum[i] = sum of s_sorted[:i]
    cum = np.concatenate([[0.0], np.cumsum(s_sorted)])

    best_scores = np.full(k, -np.inf)
    best_sets: list[list[int] | None] = [None] * k

    def bound(score: float, cursor: int, add: int) -> float:
        """score + best `add` remaining scores from cursor on (admissible)."""
        hi = cursor + add
        if hi > n:
            return -np.inf
        return score + (cum[hi] - cum[cursor])

    # iterative DFS; frame = (chosen tuple, banned bitset, score, cursor)
    stack = [([], np.zeros(n, bool), 0.0, 0)]
    expansions = 0
    complete = True
    while stack:
        if node_budget is not None and expansions >= node_budget:
            complete = False
            break
        chosen, banned, score, cursor = stack[-1]
        if cursor >= n or len(chosen) >= k:
            stack.pop()
            continue
        stack[-1] = (chosen, banned, score, cursor + 1)
        if banned[cursor]:
            continue
        expansions += 1
        new_score = score + s_sorted[cursor]
        new_chosen = chosen + [cursor]
        m = len(new_chosen)
        if new_score > best_scores[m - 1]:
            best_scores[m - 1] = new_score
            best_sets[m - 1] = list(new_chosen)
        if m >= k:
            continue
        # prune unless some size m' in (m, k] could improve
        new_banned = banned | adj_sorted[cursor]
        new_banned[cursor] = True
        promising = False
        for m2 in range(m + 1, k + 1):
            if bound(new_score, cursor + 1, m2 - m) > best_scores[m2 - 1]:
                promising = True
                break
        if promising:
            stack.append((new_chosen, new_banned, new_score, cursor + 1))

    # map sorted-local indices back to input-local indices
    out_sets = []
    for s in best_sets:
        out_sets.append(None if s is None else sorted(int(order[i]) for i in s))
    return out_sets, best_scores, complete


def brute_force_diverse(scores: np.ndarray, adj: np.ndarray, k: int):
    """Exponential exhaustive oracle for tiny instances (test-only)."""
    import itertools

    n = len(scores)
    best_score = -np.inf
    best = None
    for comb in itertools.combinations(range(n), k):
        ok = True
        for a in range(k):
            for b in range(a + 1, k):
                if adj[comb[a], comb[b]]:
                    ok = False
                    break
            if not ok:
                break
        if ok:
            sc = float(np.sum(np.asarray(scores)[list(comb)]))
            if sc > best_score:
                best_score, best = sc, list(comb)
    return best, best_score
