"""Lane-state layer: fixed-shape per-lane search state for the batched engine.

A *lane* is one slot of the batched progressive engine: a fixed-capacity
candidate queue, a visited set, and a step counter — ``beam_search.SearchState``
with a leading lane axis on every leaf. This module is the bottom of the
serving stack's lane-state / backend / scheduler split: the pure-function
layer under ``core.batch_progressive.ProgressiveEngine`` (the single-host
``core.backend.LaneBackend`` implementation; the mesh-sharded
``sharded_search.engine.ShardedEngine`` keeps its per-lane budgets host-side
instead, because its device state lives sharded across the mesh). It owns
the shape/sentinel conventions and the three lane-slot operations the
engine and the serving scheduler build on:

* ``extract_lane`` / ``inject_lane`` — move one lane between the batched
  pytree and a solo ``SearchState`` (the parity bridge to the per-query
  drivers: an extracted lane *is* a solo driver state).
* ``recycle_lane`` — re-initialize one lane slot for a **new query** in
  place: the slot gets exactly the state ``beam_search.init_state`` would
  produce at the batch's physical capacity, sibling lanes are untouched, and
  the lane index is traced so re-admitting different lanes never recompiles.
  This is what lets the scheduler run continuous batching: a certified
  lane's slot is handed to the next queued request without disturbing the
  in-flight lanes around it.
* ``pad_queue`` / ``pad_lanes`` / ``slice_queue_capacity`` — physical
  capacity moves. All lanes share one physical queue width; each lane's
  *logical* capacity is enforced by the engine's clamp, so padding with the
  empty-slot sentinel (id=-1, score=-inf, stable=True) never changes lane
  semantics.

Everything here is jit-friendly and bit-deterministic; host-side policy
(which lane to recycle, when to grow) lives in the engine and scheduler.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import beam_search as bs
from repro.core import queue as qmod
from repro.core.graph import FlatGraph


class LaneCertificate(NamedTuple):
    """Per-lane Theorem-2 verification snapshot (host-side, one lane)."""
    min_value: float     # Theorem-2 minValue over the lane's candidates
    s_K: float           # K-th candidate score the bound is checked against
    certified: bool      # min_value > s_K (global optimality under the paper)
    complete: bool       # div-A* ran to completion within its budget


# ------------------------------------------------------------- shape ops ----

def pad_queue(queue: qmod.Queue, pad: int) -> qmod.Queue:
    """Extend a queue's last axis with empty-slot sentinels (id=-1,
    score=-inf, stable=True) — the one place the sentinel convention for
    padding lives."""
    if pad == 0:
        return queue
    spec = [(0, 0)] * (queue.ids.ndim - 1) + [(0, pad)]
    return qmod.Queue(
        ids=jnp.pad(queue.ids, spec, constant_values=-1),
        scores=jnp.pad(queue.scores, spec, constant_values=-np.inf),
        stable=jnp.pad(queue.stable, spec, constant_values=True),
    )


def physical_capacity(state: bs.SearchState) -> int:
    return int(state.queue.ids.shape[-1])


def pad_lanes(state: bs.SearchState, new_capacity: int) -> bs.SearchState:
    """Grow the shared physical queue width (logical capacities unchanged)."""
    pad = new_capacity - physical_capacity(state)
    if pad <= 0:
        return state
    return bs.SearchState(pad_queue(state.queue, pad), state.visited,
                          state.steps)


def slice_queue_capacity(state: bs.SearchState, cap: int) -> bs.SearchState:
    """View of the lanes at queue width ``cap`` (<= physical capacity).

    Safe whenever every lane's logical capacity is <= ``cap``: slots past
    the logical capacity hold only the padding sentinel.
    """
    q = state.queue
    return bs.SearchState(
        qmod.Queue(q.ids[..., :cap], q.scores[..., :cap], q.stable[..., :cap]),
        state.visited, state.steps)


# ------------------------------------------------------------- lane init ----

@functools.partial(jax.jit, static_argnames=("capacity",))
def init_lanes(graph: FlatGraph, qs: jnp.ndarray,
               capacity: int) -> bs.SearchState:
    """Batched ``beam_search.init_state`` over a query batch."""
    return jax.vmap(lambda q: bs.init_state(graph, q, capacity))(qs)


# -------------------------------------------------------- lane slot ops ----

def extract_lane(state: bs.SearchState, lane: int) -> bs.SearchState:
    """One lane's state as a solo ``SearchState`` (bit-identical leaves)."""
    return jax.tree_util.tree_map(lambda a: a[lane], state)


def inject_lane(state: bs.SearchState, lane: int,
                lane_state: bs.SearchState) -> bs.SearchState:
    """Replace one lane's state; sibling lanes are untouched."""
    return jax.tree_util.tree_map(lambda b, s: b.at[lane].set(s),
                                  state, lane_state)


@jax.jit
def _recycle(graph: FlatGraph, state: bs.SearchState, lane: jnp.ndarray,
             q: jnp.ndarray) -> bs.SearchState:
    # physical capacity comes from the state's shape -> static under jit;
    # the lane index is traced, so recycling lane 0 vs lane 7 shares one
    # compilation.
    fresh = bs.init_state(graph, q, physical_capacity(state))
    return jax.tree_util.tree_map(lambda b, s: b.at[lane].set(s),
                                  state, fresh)


def recycle_lane(graph: FlatGraph, state: bs.SearchState, lane: int,
                 q) -> bs.SearchState:
    """Re-initialize lane ``lane`` for a new query ``q`` in place.

    The slot's queue/visited/steps become exactly what a fresh solo driver
    would start from (entry point seeded after HNSW descent), at the batch's
    current physical capacity; all other lanes keep their bits. One compile
    per (lane count, physical capacity) — never per lane index or query.
    """
    return _recycle(graph, state, jnp.int32(lane),
                    jnp.asarray(q, jnp.float32))


def select_lanes(state: bs.SearchState, lanes) -> bs.SearchState:
    """Gather a sub-batch of lanes (used for bucketed rebuilds)."""
    idx = jnp.asarray(lanes)
    return jax.tree_util.tree_map(lambda a: a[idx], state)
