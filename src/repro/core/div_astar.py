"""div-A* in JAX: exact diverse-set optimization under jit (DESIGN.md §2).

The paper's div-A* walks a dynamically grown search tree; TPU-side we run the
equivalent depth-first branch-and-bound as a ``lax.while_loop`` over a
fixed-capacity stack (depth <= k+1 thanks to in-place sibling cursors).
Candidates are processed in (score desc, id asc) order; the admissible bound
is current score + sum of the next best remaining scores (conflicts
ignored) — identical to the python oracle ``div_astar_ref``.

Pruning keeps a state alive if it could improve the incumbent of ANY size
m' <= k, so the optimal sets of every size 1..k come out certified (PSS
consumes all of them through Theorem 2).

A step budget bounds the loop for jit; ``complete=False`` signals exhaustion
(drivers then fall back to a larger budget — never observed at
Theorem-1/2-sized K in our benchmarks).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG = jnp.float32(-jnp.inf)


class DivAStarResult(NamedTuple):
    best_sets: jnp.ndarray    # int32[k, k] local indices, -1 padded; row m = size m+1
    best_scores: jnp.ndarray  # f32[k]
    complete: jnp.ndarray     # bool
    expansions: jnp.ndarray   # int32


@functools.partial(jax.jit, static_argnames=("k", "max_expansions"))
def div_astar(scores: jnp.ndarray, adj: jnp.ndarray, k: int,
              max_expansions: int = 200_000) -> DivAStarResult:
    K = scores.shape[0]
    scores = scores.astype(jnp.float32)
    valid = jnp.isfinite(scores)
    order = jnp.lexsort((jnp.arange(K), -jnp.where(valid, scores, NEG)))
    s = jnp.where(valid[order], scores[order], NEG)
    a = adj[order][:, order]
    # cum[i] = sum of the i best (valid) scores
    cum = jnp.concatenate([jnp.zeros(1), jnp.cumsum(jnp.where(s > NEG, s, 0.0))])
    n_valid = jnp.sum(valid).astype(jnp.int32)

    class Carry(NamedTuple):
        t: jnp.ndarray             # stack top (depth == #chosen at top)
        cursor: jnp.ndarray        # int32[k+1]
        score: jnp.ndarray         # f32[k+1]
        banned: jnp.ndarray        # bool[k+1, K]
        chosen: jnp.ndarray        # int32[k+1, k]
        best_scores: jnp.ndarray   # f32[k]
        best_sets: jnp.ndarray     # int32[k, k]
        steps: jnp.ndarray

    init = Carry(
        t=jnp.int32(0),
        cursor=jnp.zeros((k + 1,), jnp.int32),
        score=jnp.zeros((k + 1,), jnp.float32),
        banned=jnp.zeros((k + 1, K), jnp.bool_),
        chosen=jnp.full((k + 1, k), -1, jnp.int32),
        best_scores=jnp.full((k,), NEG),
        best_sets=jnp.full((k, k), -1, jnp.int32),
        steps=jnp.int32(0),
    )

    def cond(c: Carry):
        return (c.t >= 0) & (c.steps < max_expansions)

    def body(c: Carry):
        cur = c.cursor[c.t]
        depth = c.t

        def pop(c: Carry):
            return c._replace(t=c.t - 1, steps=c.steps + 1)

        def advance(c: Carry):
            cand = cur
            cursor = c.cursor.at[c.t].add(1)
            c = c._replace(cursor=cursor, steps=c.steps + 1)
            skip = c.banned[depth, cand] | (s[cand] <= NEG)

            def consider(c: Carry):
                new_score = c.score[depth] + s[cand]
                m = depth + 1  # size of the new set
                new_chosen_row = c.chosen[depth].at[m - 1].set(cand)
                improve = new_score > c.best_scores[m - 1]
                best_scores = c.best_scores.at[m - 1].set(
                    jnp.maximum(c.best_scores[m - 1], new_score))
                best_sets = jnp.where(improve,
                                      c.best_sets.at[m - 1].set(new_chosen_row),
                                      c.best_sets)
                c = c._replace(best_scores=best_scores, best_sets=best_sets)

                # promising for any deeper size m2 in (m, k] ?
                sizes = jnp.arange(1, k + 1)          # candidate m2
                add = sizes - m                        # how many more to pick
                hi = jnp.clip(cand + 1 + add, 0, K)
                feasible = (add > 0) & (cand + 1 + add <= n_valid + 0 * hi) \
                    & (cand + 1 + add <= K)
                bounds = new_score + (cum[hi] - cum[cand + 1])
                promising = jnp.any(jnp.where(
                    feasible, bounds > c.best_scores, False))
                do_push = (m < k) & promising

                def push(c: Carry):
                    nt = c.t + 1
                    new_banned = c.banned[depth] | a[cand]
                    new_banned = new_banned.at[cand].set(True)
                    return c._replace(
                        t=nt,
                        cursor=c.cursor.at[nt].set(cand + 1),
                        score=c.score.at[nt].set(new_score),
                        banned=c.banned.at[nt].set(new_banned),
                        chosen=c.chosen.at[nt].set(new_chosen_row),
                    )

                return jax.lax.cond(do_push, push, lambda c: c, c)

            return jax.lax.cond(skip, lambda c: c, consider, c)

        do_pop = (cur >= K) | (depth >= k)
        return jax.lax.cond(do_pop, pop, advance, c)

    out = jax.lax.while_loop(cond, body, init)
    # map sorted-space indices back to caller-local indices
    safe = jnp.maximum(out.best_sets, 0)
    mapped = jnp.where(out.best_sets >= 0, order[safe].astype(jnp.int32), -1)
    return DivAStarResult(
        best_sets=mapped,
        best_scores=out.best_scores,
        complete=out.t < 0,
        expansions=out.steps,
    )


def optimal_diverse_set(scores, adj, k, max_expansions: int = 200_000):
    """Convenience: (ids_local int32[k] (-1 pad), total_score, complete)."""
    res = div_astar(scores, adj, k, max_expansions)
    return res.best_sets[k - 1], res.best_scores[k - 1], res.complete
