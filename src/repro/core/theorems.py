"""The paper's Theorems 1-3 as executable predicates.

Theorem 1 (degree bound, PDS): if K >= sum_{v in Phi}(phi_v + 1) + 1 where
Phi holds the k-1 highest-degree nodes of G^eps over the top-K candidates,
the top-K candidates suffice to contain the optimal diverse set.

Theorem 2 (score bound, PSS): with optimal sizes-1..k scores S_1..S_k over
the top-K candidates and s_K the K-th candidate score, if
min_{0<i<k} (S_k - S_i)/(k - i) > s_K the current R_k is globally optimal.

Theorem 3 (recall bound): Recall_P >= (1 - K*lambda/(K-k+1))^k.
"""
from __future__ import annotations

import math

import jax.numpy as jnp


def theorem1_K(degrees: jnp.ndarray, k: int,
               valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Sufficient candidate count K from node degrees of G^eps."""
    deg = degrees.astype(jnp.int32)
    if valid is not None:
        deg = jnp.where(valid, deg, -1)
    if k <= 1:
        return jnp.int32(1)
    topk = jnp.sort(deg)[::-1][: k - 1]
    topk = jnp.maximum(topk, 0)  # fewer than k-1 valid nodes: treat as deg 0
    return (jnp.sum(topk + 1) + 1).astype(jnp.int32)


def theorem2_min_value(best_scores: jnp.ndarray, k: int) -> jnp.ndarray:
    """minValue = min_{0<i<k} (S_k - S_i)/(k-i); +inf when k == 1.

    best_scores[i] = optimal total score of size i+1 (may be -inf when that
    size is infeasible within the candidates — those i are skipped, matching
    the paper's assumption that sets of all sizes exist).
    """
    if k <= 1:
        return jnp.float32(jnp.inf)
    s_k = best_scores[k - 1]
    i = jnp.arange(1, k)  # sizes 1..k-1
    s_i = best_scores[: k - 1]
    gaps = (s_k - s_i) / (k - i)
    gaps = jnp.where(jnp.isfinite(s_i), gaps, jnp.inf)
    return jnp.min(gaps)


def theorem2_holds(best_scores: jnp.ndarray, k: int, s_K) -> jnp.ndarray:
    return theorem2_min_value(best_scores, k) > s_K


def theorem3_recall_bound(K: float, k: int, lam: float) -> float:
    """Lower bound on the diverse-search recall given Ak-NNS recall 1-lam."""
    if K - k + 1 <= 0:
        return 0.0
    base = 1.0 - (K * lam) / (K - k + 1)
    return max(0.0, base) ** k


def theorem2_audit(vectors, metric: str, cand_ids, cand_scores, eps,
                   k: int, max_expansions: int = 100_000):
    """Theorem-2 certificate audit returning the certificate's numbers.

    Like :func:`theorem2_recheck` but also reports ``(min_value, s_K)`` so
    callers can measure the certificate's *slack* ``min_value - s_K`` — the
    reusability budget the semantic result cache converts into a query-drift
    threshold (:func:`theorem2_slack_threshold`). Returns
    ``(certified, selected_global_ids, min_value, s_K)``. An empty or
    all-padding frontier is never certified (there is no ``s_K`` to bound).
    """
    import numpy as np

    from repro.core import div_astar as da
    from repro.kernels import ops as kops

    cand_ids = np.asarray(cand_ids)
    cand_scores = np.asarray(cand_scores)
    K = len(cand_ids)
    if K == 0 or not (cand_ids >= 0).any():
        return False, np.full(k, -1, np.int32), -np.inf, np.inf
    vecs = jnp.asarray(vectors)[np.maximum(cand_ids, 0)]
    adj = kops.pairwise_adjacency(vecs, eps, metric,
                                  jnp.asarray(cand_ids >= 0))
    res = da.div_astar(jnp.where(jnp.asarray(cand_ids) >= 0,
                                 jnp.asarray(cand_scores), -jnp.inf),
                       adj, k, max_expansions=max_expansions)
    min_value = float(theorem2_min_value(res.best_scores, k))
    s_K = float(cand_scores[K - 1])
    certified = bool((min_value > s_K) and bool(np.asarray(res.complete)))
    sel = np.asarray(res.best_sets[k - 1])
    sel_ids = np.where(sel >= 0, cand_ids[np.maximum(sel, 0)], -1)
    return certified, sel_ids.astype(np.int32), min_value, s_K


def theorem2_recheck(vectors, metric: str, cand_ids, cand_scores, eps,
                     k: int, max_expansions: int = 100_000):
    """Independent Theorem-2 certificate audit over a candidate frontier.

    Re-runs div-A* from scratch on the recorded ``(cand_ids, cand_scores)``
    (global ids into ``vectors``; -1 rows are padding) and re-evaluates
    ``minValue > s_K`` — engine-free, so it can audit a served result's
    certificate without trusting the engine that produced it. Returns
    ``(certified, selected_global_ids)``; a sound certificate means
    ``certified`` is True and the selected ids equal the served ones.
    """
    certified, sel_ids, _, _ = theorem2_audit(
        vectors, metric, cand_ids, cand_scores, eps, k,
        max_expansions=max_expansions)
    return certified, sel_ids


def theorem2_slack_threshold(slack: float, k: int,
                             lipschitz: float = 1.0) -> float:
    """Max per-query drift under which a Theorem-2 certificate survives.

    Soundness contract (the semantic result cache's revalidation bound):
    let a frontier of K candidates carry a certificate with slack
    ``minValue - s_K > 0`` for query ``q``. Rescore the *same* frontier
    against a new query ``q'`` whose drift ``delta`` (Euclidean distance in
    probe space — raw queries for ``l2``/``ip``, unit-normalized for
    ``cos``) satisfies ``delta <= threshold``. Every candidate's score then
    moves by at most ``Delta = lipschitz * delta`` (``l2``:
    ``|sim - sim'| = | ||q-x|| - ||q'-x|| | <= ||q-q'||``, L=1; ``cos``:
    scores are dots of unit vectors, L=1 on the unit sphere; ``ip``:
    ``|<q-q',x>| <= ||q-q'|| * max_x ||x||``, L = the max corpus norm).
    G^eps depends only on the candidate vectors, not the query, so the
    feasible diverse sets are unchanged and each best size-``i`` total
    ``S_i`` (a max of sums of ``i`` scores) moves by at most ``i*Delta``.
    The worst gap term ``(S_k - S_i)/(k-i)`` therefore drops by at most
    ``(2k-1)*Delta`` (at ``i = k-1``) while ``s_K`` rises by at most
    ``Delta`` — so ``minValue' > s_K'`` still holds whenever
    ``2k * Delta < slack``, i.e. ``delta < slack / (2k * lipschitz)``.

    A revalidated hit's result set thus passes the same
    :func:`theorem2_recheck` a fresh search over that frontier would — and
    the cache *still runs the recheck on every hit* (the threshold is a
    probe filter, never the soundness argument). ``k == 1`` certificates
    have infinite slack (``theorem2_min_value`` is ``+inf``) and return an
    infinite threshold; cap with the cache's ``max_drift`` knob. Returns
    0.0 for non-positive slack (an expired or uncertified entry never
    matches).
    """
    if not slack > 0.0:
        return 0.0
    if not math.isfinite(slack):
        return math.inf
    return slack / (2.0 * max(int(k), 1) * float(lipschitz))
