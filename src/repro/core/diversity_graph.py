"""Diversity-graph construction over a candidate prefix (paper Def. 2).

Thin orchestration over the ``pairwise_adjacency`` kernel, plus the
incremental extension the paper uses in PDS/PSS ("incrementally updates the
diversity graph from the previous iteration, modifying only the newly
discovered nodes"): when the candidate prefix grows from K_old to K_new only
the new rows/cols are computed.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.graph import FlatGraph
from repro.kernels import ops as kops


def build_adjacency(graph: FlatGraph, ids: jnp.ndarray, eps,
                    impl: str | None = None) -> jnp.ndarray:
    """Adjacency bool[K, K] among candidate ids (-1 = padding, masked out)."""
    vecs = graph.vectors[jnp.maximum(ids, 0)]
    valid = ids >= 0
    return kops.pairwise_adjacency(vecs, eps, graph.metric, valid, impl=impl)


def extend_adjacency(graph: FlatGraph, old_adj: jnp.ndarray,
                     old_ids: jnp.ndarray, new_ids: jnp.ndarray, eps,
                     impl: str | None = None) -> jnp.ndarray:
    """Extend a K_old adjacency with newly discovered candidates.

    ``new_ids`` is the FULL new prefix (length K_new >= K_old) whose first
    K_old entries must equal ``old_ids``. Only the (K_new - K_old) new
    rows/cols are computed fresh.
    """
    k_old = old_ids.shape[0]
    k_new = new_ids.shape[0]
    if k_new == k_old:
        return old_adj
    fresh = new_ids[k_old:]
    fresh_vecs = graph.vectors[jnp.maximum(fresh, 0)]
    all_vecs = graph.vectors[jnp.maximum(new_ids, 0)]
    valid_new = new_ids >= 0
    # sims of fresh rows vs ALL candidates (old + fresh)
    sims = kops.batch_similarity_many(fresh_vecs, all_vecs, graph.metric,
                                      impl=impl)
    rows = (sims > eps) & valid_new[None, :] & (fresh >= 0)[:, None]
    # kill diagonal within the fresh block
    diag = jnp.arange(k_new - k_old)[:, None] + k_old == jnp.arange(k_new)[None, :]
    rows = rows & ~diag
    adj = jnp.zeros((k_new, k_new), bool)
    adj = adj.at[:k_old, :k_old].set(old_adj)
    adj = adj.at[k_old:, :].set(rows)
    adj = adj.at[:, k_old:].set(rows.T)
    return adj


def degrees(adj: jnp.ndarray, valid: jnp.ndarray | None = None) -> jnp.ndarray:
    d = jnp.sum(adj, axis=1).astype(jnp.int32)
    if valid is not None:
        d = jnp.where(valid, d, 0)
    return d
