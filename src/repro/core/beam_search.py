"""Beam search (paper Alg. 1) and progressive beam search (paper §III) in JAX.

Both are one ``lax.while_loop`` over a fixed-capacity queue:

  * ``beam_search``            — classic Alg. 1: stop when the first ``L``
                                  candidates are stable, return top-k.
  * ``progressive_beam_search`` — the paper's modification: stop when the
                                  first ``stable_limit`` (= K*ef) candidates
                                  are stable; the queue AND the visited set
                                  are threaded through calls so the search
                                  resumes instead of restarting (queue reuse).
  * the PSS variant (ProgressiveBeamSearch*, Alg. 4 line 6) is the same loop
    with ``min_value``: expansion stops once the best unexpanded candidate's
    score falls below ``min_value``.

TPU adaptation (DESIGN.md §2): neighbor scoring is one gathered (M0, d) block
scored in a single fused similarity op (the Pallas `batch_similarity` kernel
on TPU; its jnp oracle here), not one dot product at a time.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import quant
from repro.core import queue as qmod
from repro.core.graph import FlatGraph, descend
from repro.core.queue import Queue
from repro.kernels import ops as kops


class SearchState(NamedTuple):
    queue: Queue
    visited: jnp.ndarray   # bool[N] — nodes already EXPANDED
    steps: jnp.ndarray     # int32


@functools.partial(jax.jit, static_argnames=("capacity", "use_descent"))
def init_state(graph: FlatGraph, q: jnp.ndarray, capacity: int,
               use_descent: bool = True) -> SearchState:
    """Start state: queue seeded with the entry point (after HNSW descent)."""
    entry = descend(graph, q) if use_descent and graph.num_upper_levels else graph.entry
    if quant.is_quantized(graph.vectors):
        qprep = quant.prepare_query(graph.vectors, q, graph.metric)
        s0 = quant.score_rows(qprep, graph.vectors,
                              entry.astype(jnp.int32)[None], graph.metric)[0]
    else:
        s0 = kops.batch_similarity(q, graph.vectors[entry][None, :],
                                   graph.metric)[0]
    queue = qmod.make_queue(capacity)
    queue = Queue(
        ids=queue.ids.at[0].set(entry.astype(jnp.int32)),
        scores=queue.scores.at[0].set(s0.astype(jnp.float32)),
        stable=queue.stable.at[0].set(False),
    )
    visited = jnp.zeros((graph.size,), dtype=jnp.bool_)
    return SearchState(queue, visited, jnp.int32(0))


@functools.partial(jax.jit, static_argnames=("graph_metric",))
def _search_loop(vectors, neighbors, qvec, state: SearchState,
                 stable_limit, min_value, max_steps, graph_metric: str):
    """Shared while-loop. ``stable_limit``/``min_value``/``max_steps`` traced.

    ``vectors`` is either the float corpus (scored by the batch-similarity
    kernel, byte-identical to the pre-quantization trace) or a quantized
    corpus (``quant.Int8Corpus``/``quant.PQCorpus``), in which case the
    per-search query view is prepared once here, outside the loop, and
    every expansion scores the gathered *compressed* neighbor block.
    The branch is resolved at trace time — the corpus type is part of the
    jit signature.
    """
    compressed = quant.is_quantized(vectors)
    qprep = (quant.prepare_query(vectors, qvec, graph_metric)
             if compressed else None)

    def cond(st: SearchState):
        p, exists = qmod.first_unstable(st.queue, stable_limit)
        score_ok = st.queue.scores[p] >= min_value
        return exists & score_ok & (st.steps < max_steps)

    def body(st: SearchState):
        queue, visited, steps = st
        p, _ = qmod.first_unstable(queue, stable_limit)
        node = queue.ids[p]
        queue = Queue(queue.ids, queue.scores, queue.stable.at[p].set(True))
        visited = visited.at[node].set(True)

        nbrs = neighbors[node]                       # int32[M0]
        safe = jnp.maximum(nbrs, 0)
        fresh = (nbrs >= 0) & ~visited[safe]
        if compressed:
            sims = quant.score_rows(qprep, vectors, safe, graph_metric)
        else:
            vecs = vectors[safe]                     # [M0, d]
            sims = kops.batch_similarity(qvec, vecs, graph_metric)
        queue = qmod.insert(queue, nbrs, sims, fresh)
        return SearchState(queue, visited, steps + 1)

    return jax.lax.while_loop(cond, body, state)


def run_search(graph: FlatGraph, q: jnp.ndarray, state: SearchState,
               stable_limit, min_value=-jnp.inf, max_steps=None) -> SearchState:
    if max_steps is None:
        max_steps = 4 * state.queue.capacity + 64
    return _search_loop(
        graph.vectors, graph.neighbors, q, state,
        jnp.asarray(stable_limit, jnp.int32),
        jnp.asarray(min_value, jnp.float32),
        jnp.asarray(max_steps, jnp.int32),
        graph.metric,
    )


def resume_search(graph: FlatGraph, q: jnp.ndarray, state: SearchState,
                  stable_limit, min_value=-jnp.inf,
                  step_budget=None) -> SearchState:
    """Resume a previous ``run_search`` under a *continued* stable limit.

    The queue and visited set carry over, so expansions from earlier calls
    are never redone: a wider ``stable_limit`` (the budget-doubling ladder's
    next rung) keeps expanding from the previous frontier instead of
    restarting at the entry point. ``step_budget`` is the per-call expansion
    allowance — unlike ``run_search``'s absolute ``max_steps``, it is added
    on top of the steps the state has already accumulated, so a resumed
    round gets the same allowance a fresh one would.

    Widening contract: a queue whose capacity is at least ``stable_limit``
    (or at least the graph's valid-node count) evolves its leading prefix
    identically to any wider queue — entries only ever drop *below* a full
    prefix of better-scored entries, and a dropped entry re-inserted later
    lands below that prefix again. The sharded resume path relies on this:
    it sizes the queue once at the lane's max beam width
    (``ShardedSearchState``), so the first round is bit-exact with a scratch
    search at the narrow width, and later rounds continue exactly where the
    previous rung stopped.
    """
    if step_budget is None:
        step_budget = 4 * state.queue.capacity + 64
    max_steps = state.steps + jnp.asarray(step_budget, jnp.int32)
    return run_search(graph, q, state, stable_limit, min_value,
                      max_steps=max_steps)


def beam_search(graph: FlatGraph, q: jnp.ndarray, k: int, L: int,
                capacity: int | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Paper Alg. 1: plain beam search; returns (ids[k], scores[k])."""
    if capacity is None:
        capacity = L
    state = init_state(graph, q, capacity)
    state = run_search(graph, q, state, stable_limit=L)
    return state.queue.ids[:k], state.queue.scores[:k]


def progressive_beam_search(graph: FlatGraph, q: jnp.ndarray,
                            state: SearchState, K, ef: int,
                            min_value=-jnp.inf) -> SearchState:
    """The paper's ProgressiveBeamSearch: resume until first K*ef stable."""
    return run_search(graph, q, state, stable_limit=K * ef, min_value=min_value)


@functools.partial(jax.jit, static_argnames=("new_capacity",))
def rebuild_for_growth(graph: FlatGraph, q: jnp.ndarray, state: SearchState,
                       new_capacity: int) -> SearchState:
    """Exact queue rebuild when the driver grows capacity.

    Fixed capacity can silently drop (a) unexpanded frontier nodes and
    (b) expanded nodes that fell below the old capacity boundary. Expanded
    nodes are exactly the ``visited`` set, so rebuilding from
    (current queue entries) ∪ (visited nodes, rescored) reproduces the
    unbounded-queue state of the paper exactly. O(|visited|) and only runs on
    the rare growth events.
    """
    visited = state.visited
    n = graph.size
    all_ids = jnp.arange(n, dtype=jnp.int32)
    if quant.is_quantized(graph.vectors):
        qprep = quant.prepare_query(graph.vectors, q, graph.metric)
        vis_scores = quant.score_rows(qprep, graph.vectors, all_ids,
                                      graph.metric)
    else:
        vis_scores = kops.batch_similarity(q, graph.vectors, graph.metric)
    # queue membership of every node (to keep 'unstable' flags of frontier);
    # add-scatter because several empty sentinels all map to slot 0, and a
    # .set scatter with duplicate indices has undefined winner order
    safe = jnp.maximum(state.queue.ids, 0)
    in_queue = jnp.zeros((n,), jnp.int32).at[safe].add(
        (state.queue.ids >= 0).astype(jnp.int32)) > 0
    frontier_unstable = jnp.zeros((n,), jnp.int32).at[safe].add(
        ((state.queue.ids >= 0) & ~state.queue.stable).astype(jnp.int32)) > 0
    member = visited | in_queue
    ids = jnp.where(member, all_ids, -1)
    scores = jnp.where(member, vis_scores, qmod.NEG_INF)
    stable = ~frontier_unstable
    new_queue = qmod.from_entries(ids, scores, stable, new_capacity)
    return SearchState(new_queue, visited, state.steps)
