"""Progressive Greedy Search — paper Algorithm 2.

Greedy diversification inside the progressive framework: stabilize K*ef
candidates, greedily select among the first K, and grow K by k until the
diverse set reaches size k. Greedy over a sorted prefix is prefix-monotone
(selection decisions depend only on earlier selections), so re-running
greedy over the longer prefix reproduces Alg. 2's incremental R exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.diversity_graph import build_adjacency
from repro.core.graph import FlatGraph
from repro.core.progressive import ProgressiveDriver, SearchStats
from repro.kernels import ops as kops


class DiverseResult(NamedTuple):
    ids: np.ndarray      # int32[k], -1 padded
    scores: np.ndarray   # f32[k]
    total: float
    stats: SearchStats


def _greedy_prefix(graph: FlatGraph, driver: ProgressiveDriver, K: int,
                   eps: float, k: int):
    ids, scores = driver.prefix(K)
    adj = build_adjacency(graph, ids, eps)
    sel, count = kops.greedy_diversify(scores, adj, k, valid=ids >= 0)
    driver.stats.div_calls += 1
    return ids, scores, sel, int(count)


def pgs(graph: FlatGraph, q, k: int, eps: float, ef: int = 40,
        driver: ProgressiveDriver | None = None,
        max_iters: int = 64) -> tuple[DiverseResult, ProgressiveDriver, int]:
    """Returns (result, driver, K_final) — PSS reuses the driver and K."""
    if driver is None:
        driver = ProgressiveDriver(graph, q, ef, k)
    K = k
    sel = None
    ids = scores = None
    for _ in range(max_iters):
        stable = driver.ensure_stable(K * ef)
        exhausted = stable < min(K * ef, graph.size)
        if exhausted:
            # graph fully explored: run greedy over everything we have
            K = max(K, stable)
        ids, scores, sel, count = _greedy_prefix(graph, driver, K, eps, k)
        if count >= k:
            break
        if exhausted:
            driver.stats.exhausted = True   # cannot produce k diverse results
            break
        K += k
    sel_np = np.asarray(sel)
    ids_np = np.asarray(ids)
    sc_np = np.asarray(scores)
    out_ids = np.where(sel_np >= 0, ids_np[np.maximum(sel_np, 0)], -1)
    out_sc = np.where(sel_np >= 0, sc_np[np.maximum(sel_np, 0)], 0.0)
    driver.stats.K_final = K
    res = DiverseResult(out_ids.astype(np.int32), out_sc.astype(np.float32),
                        float(out_sc.sum()), driver.stats)
    return res, driver, K
