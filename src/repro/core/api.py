"""Public API: one entry point for approximate diverse k-NN search.

    result = diverse_search(graph, q, k=10, eps=0.8, method="pss", ef=40)

``method``: "pss" (default, paper's best), "pds", "pgs", "greedy"
(fixed-beam baseline), "ip_greedy". The query carries its own (k, eps) as in
the paper's Definition 1 — no index rebuild for new diversification levels.
"""
from __future__ import annotations

from typing import Literal

from repro.core.baselines import greedy_fixed, ip_greedy
from repro.core.graph import FlatGraph
from repro.core.pds import pds
from repro.core.pgs import DiverseResult, pgs
from repro.core.pss import pss

Method = Literal["pss", "pds", "pgs", "greedy", "ip_greedy"]


def diverse_search(graph: FlatGraph, q, k: int, eps: float,
                   method: Method = "pss", ef: int = 40,
                   **kwargs) -> DiverseResult:
    if method == "pss":
        return pss(graph, q, k, eps, ef, **kwargs)
    if method == "pds":
        return pds(graph, q, k, eps, ef, **kwargs)
    if method == "pgs":
        res, _, _ = pgs(graph, q, k, eps, ef, **kwargs)
        return res
    if method == "greedy":
        return greedy_fixed(graph, q, k, eps, **kwargs)
    if method == "ip_greedy":
        lam = kwargs.pop("lam", 0.7)
        return ip_greedy(graph, q, k, lam, **kwargs)
    raise ValueError(f"unknown method {method!r}")
