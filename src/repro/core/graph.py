"""Flat, fixed-shape proximity-graph representation consumed by JAX search.

The numpy HNSW builder (``repro.index.hnsw``) emits:

  vectors    f32[N, d]          the database
  neighbors  int32[N, M0]       level-0 adjacency, -1 padded
  upper      int32[Lu, N, Mu]   upper-level adjacency (rows of non-member
                                nodes are all -1); may have Lu == 0
  entry      int32              entry node at the top level

``metric`` travels as static aux data so jitted searchers specialize on it.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.core.similarity import query_sim


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FlatGraph:
    vectors: jnp.ndarray
    neighbors: jnp.ndarray
    upper: jnp.ndarray
    entry: jnp.ndarray
    metric: str = dataclasses.field(metadata=dict(static=True), default="l2")

    @property
    def size(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]

    @property
    def num_upper_levels(self) -> int:
        return self.upper.shape[0]


def make_flat_graph(vectors: Any, neighbors: Any, upper: Any | None,
                    entry: int, metric: str) -> FlatGraph:
    """``vectors`` may be a float array OR a quantized corpus
    (``quant.Int8Corpus`` / ``quant.PQCorpus``): the beam search scores
    whichever representation the graph carries. Quantized graphs are
    level-0 only (the greedy upper-level descent reads float rows)."""
    if quant.is_quantized(vectors):
        if upper is not None and getattr(upper, "shape", (0,))[0] != 0:
            raise ValueError(
                "quantized corpora do not support upper HNSW levels; "
                "build a level-0 (knng) graph instead")
    else:
        vectors = jnp.asarray(vectors, dtype=jnp.float32)
    neighbors = jnp.asarray(neighbors, dtype=jnp.int32)
    if upper is None or (hasattr(upper, "shape") and upper.shape[0] == 0):
        upper = jnp.zeros((0, vectors.shape[0], 1), dtype=jnp.int32)
    else:
        upper = jnp.asarray(upper, dtype=jnp.int32)
    return FlatGraph(vectors, neighbors, upper,
                     jnp.asarray(entry, dtype=jnp.int32), metric)


def descend(graph: FlatGraph, q: jnp.ndarray) -> jnp.ndarray:
    """Greedy top-down descent through the upper HNSW levels.

    Returns the level-0 entry node for query ``q``. Each level runs a greedy
    walk: move to the best-scoring neighbor while it improves.
    """
    cur = graph.entry
    cur_sim = query_sim(q, graph.vectors[cur][None, :], graph.metric)[0]

    def level_walk(level_nbrs, cur, cur_sim):
        def cond(state):
            _, _, improved, steps = state
            return improved & (steps < graph.size)

        def body(state):
            cur, cur_sim, _, steps = state
            nbrs = level_nbrs[cur]
            valid = nbrs >= 0
            vecs = graph.vectors[jnp.maximum(nbrs, 0)]
            sims = query_sim(q, vecs, graph.metric)
            sims = jnp.where(valid, sims, -jnp.inf)
            j = jnp.argmax(sims)
            better = sims[j] > cur_sim
            new_cur = jnp.where(better, nbrs[j], cur)
            new_sim = jnp.where(better, sims[j], cur_sim)
            return new_cur, new_sim, better, steps + 1

        cur, cur_sim, _, _ = jax.lax.while_loop(
            cond, body, (cur, cur_sim, jnp.bool_(True), jnp.int32(0)))
        return cur, cur_sim

    for lvl in range(graph.num_upper_levels):
        # upper[0] is the TOP level; walk down.
        cur, cur_sim = level_walk(graph.upper[lvl], cur, cur_sim)
    return cur


def to_host(graph: FlatGraph) -> dict:
    return dict(
        vectors=np.asarray(graph.vectors),
        neighbors=np.asarray(graph.neighbors),
        upper=np.asarray(graph.upper),
        entry=int(graph.entry),
        metric=graph.metric,
    )
