"""Progressive Degree Search — paper Algorithm 3 (Theorem 1 stopping rule).

Stabilize K*ef candidates, build G^eps over the first K, recompute
K <- sum over the k-1 highest degrees (phi_v + 1) + 1, and loop until the
first K*ef candidates are already stable. Then one div-A* call returns the
certified-optimal diverse set over the candidates.

The paper reports (its §IV-B, Table IV) that this estimate explodes at high
diversification — the driver honours that with ``max_K`` and flags the query
N/A (exactly how the paper reports those cells).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import div_astar as da
from repro.core.diversity_graph import build_adjacency, degrees, extend_adjacency
from repro.core.graph import FlatGraph
from repro.core.pgs import DiverseResult
from repro.core.progressive import ProgressiveDriver
from repro.core.theorems import theorem1_K


def pds(graph: FlatGraph, q, k: int, eps: float, ef: int = 40,
        max_K: int | None = None, max_iters: int = 64,
        max_expansions: int = 400_000) -> DiverseResult:
    driver = ProgressiveDriver(graph, q, ef, k)
    n = graph.size
    max_K = max_K or n
    K = k
    adj = None
    prev_ids = None
    for _ in range(max_iters):
        stable = driver.ensure_stable(K * ef)
        ids, scores = driver.prefix(K)
        if adj is not None and prev_ids is not None and K >= prev_ids.shape[0] \
                and bool(jnp.all(ids[: prev_ids.shape[0]] == prev_ids)):
            adj = extend_adjacency(graph, adj, prev_ids, ids, eps)
        else:
            adj = build_adjacency(graph, ids, eps)
        prev_ids = ids
        K_new = int(theorem1_K(degrees(adj, ids >= 0), k))
        K_new = min(K_new, n)
        if K_new > max_K:
            driver.stats.exhausted = True
            break
        if stable >= min(K_new * ef, n):
            K = K_new
            break
        K = K_new
        if stable < min(K * ef, n) and stable == driver.stable_prefix_len() \
                and stable >= n:
            break

    ids, scores = driver.prefix(K)
    if prev_ids is not None and K >= prev_ids.shape[0] and \
            bool(jnp.all(ids[: prev_ids.shape[0]] == prev_ids)):
        adj = extend_adjacency(graph, adj, prev_ids, ids, eps)
    else:
        adj = build_adjacency(graph, ids, eps)
    res = da.div_astar(jnp.where(ids >= 0, scores, -jnp.inf), adj, k,
                       max_expansions=max_expansions)
    driver.stats.div_calls += 1
    driver.stats.certified = bool(res.complete) and not driver.stats.exhausted
    driver.stats.K_final = K
    sel = np.asarray(res.best_sets[k - 1])
    ids_np, sc_np = np.asarray(ids), np.asarray(scores)
    out_ids = np.where(sel >= 0, ids_np[np.maximum(sel, 0)], -1)
    out_sc = np.where(sel >= 0, sc_np[np.maximum(sel, 0)], 0.0)
    return DiverseResult(out_ids.astype(np.int32), out_sc.astype(np.float32),
                         float(out_sc.sum()), driver.stats)
