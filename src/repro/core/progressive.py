"""Progressive-search driver: host-side orchestration shared by PGS/PDS/PSS.

The paper's progressive framework alternates device-side search bursts with
host-side diversification decisions (pause / inspect / resume). The driver
owns the capacity policy: the queue is fixed-capacity for jit, and on the
rare growth events the state is rebuilt *exactly* (see
``beam_search.rebuild_for_growth``) so semantics match the unbounded queue.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import beam_search as bs
from repro.core.bucketing import next_pow2 as _next_pow2  # noqa: F401 (re-export)
from repro.core.graph import FlatGraph
from repro.core.queue import stable_count as q_stable_count


@dataclasses.dataclass
class SearchStats:
    expansions: int = 0
    growths: int = 0
    search_calls: int = 0
    div_calls: int = 0
    certified: bool = False
    exhausted: bool = False
    K_final: int = 0


class ProgressiveDriver:
    """Owns one query's progressive search state across pause/resume cycles."""

    def __init__(self, graph: FlatGraph, q, ef: int, k: int,
                 capacity0: int | None = None, max_capacity: int | None = None):
        self.graph = graph
        self.q = jnp.asarray(q, jnp.float32)
        self.ef = ef
        self.k = k
        n = graph.size
        if capacity0 is None:
            capacity0 = min(_next_pow2(max(2 * k * ef, 256)), _next_pow2(n))
        self.max_capacity = max_capacity or _next_pow2(n)
        self.state = bs.init_state(graph, self.q, capacity0)
        self.stats = SearchStats()
        self._last_stable = -1

    @property
    def capacity(self) -> int:
        return self.state.queue.capacity

    def _grow_to(self, cap: int) -> None:
        cap = min(_next_pow2(cap), self.max_capacity)
        if cap <= self.capacity:
            return
        self.state = bs.rebuild_for_growth(self.graph, self.q, self.state, cap)
        self.stats.growths += 1

    def ensure_stable(self, target: int, min_value=-np.inf) -> int:
        """Resume search until the first ``target`` candidates are stable
        (or expansion scores drop below ``min_value`` / graph exhausts).
        Returns the stable prefix length."""
        target = int(min(target, self.graph.size))
        if target + 8 > self.capacity:
            self._grow_to(int(target * 1.5) + 64)
        steps_before = int(self.state.steps)
        self.state = bs.run_search(self.graph, self.q, self.state,
                                   stable_limit=min(target, self.capacity),
                                   min_value=min_value)
        self.stats.search_calls += 1
        self.stats.expansions += int(self.state.steps) - steps_before
        stable = int(q_stable_count(self.state.queue))
        self._last_stable = stable
        return stable

    def expand_until_below(self, min_value: float) -> int:
        """PSS's ProgressiveBeamSearch*: expand while the frontier score is
        >= min_value; grows capacity as needed. Returns stable count."""
        while True:
            stable = self.ensure_stable(self.capacity, min_value=min_value)
            # done if frontier dropped below min_value or graph exhausted
            if stable < self.capacity or self.capacity >= self.max_capacity:
                return stable
            self._grow_to(self.capacity * 2)

    def prefix(self, K: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """First K candidate (ids, scores), padded to a shape bucket.

        Entries beyond K are masked out (id=-1, score=-inf) so downstream
        consumers see exactly the first-K semantics, while the padded shape
        keeps the number of distinct jit signatures logarithmic in K.
        """
        K = int(min(K, self.capacity))
        bucket = min(max(64, _next_pow2(K)), self.capacity)
        ids = self.state.queue.ids[:bucket]
        scores = self.state.queue.scores[:bucket]
        keep = jnp.arange(bucket) < K
        return (jnp.where(keep, ids, -1),
                jnp.where(keep, scores, -jnp.inf))

    def stable_prefix_len(self) -> int:
        return int(q_stable_count(self.state.queue))
