"""Fixed-capacity candidate queue (the paper's ``C``), jit/vmap-safe.

The paper's progressive beam search keeps an *unbounded* sorted candidate
queue (its §III-C-3 names the resulting insert cost as a limitation). On TPU
every shape must be static, so we keep a fixed-capacity queue sorted in
descending score order:

  ids    int32[C]   (-1 = empty slot)
  scores f32[C]     (-inf for empty slots)
  stable bool[C]    (True = already expanded; padding is marked stable)

Capacity growth is handled by the *driver* (host side): the progressive
drivers double the capacity and rebuild the queue exactly (see
``repro.core.progressive``), so fixed capacity never changes the algorithm's
semantics relative to the unbounded queue.

Sorting is deterministic: primary key score (desc), secondary key id (asc),
so ties cannot make tests flaky.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-jnp.inf)


class Queue(NamedTuple):
    ids: jnp.ndarray     # int32[C]
    scores: jnp.ndarray  # float32[C]
    stable: jnp.ndarray  # bool[C]

    @property
    def capacity(self) -> int:
        return self.ids.shape[-1]


def make_queue(capacity: int) -> Queue:
    return Queue(
        ids=jnp.full((capacity,), -1, dtype=jnp.int32),
        scores=jnp.full((capacity,), NEG_INF, dtype=jnp.float32),
        stable=jnp.ones((capacity,), dtype=jnp.bool_),
    )


def _sort_desc(ids: jnp.ndarray, scores: jnp.ndarray, stable: jnp.ndarray):
    """Deterministic descending sort by (score desc, id asc)."""
    # jnp.lexsort: last key is primary. id asc breaks ties; empty slots
    # (id=-1, score=-inf) sort to the back because of -inf scores.
    order = jnp.lexsort((ids, -scores))
    return ids[order], scores[order], stable[order]


def sort_queue(q: Queue) -> Queue:
    i, s, st = _sort_desc(q.ids, q.scores, q.stable)
    return Queue(i, s, st)


def dedup_candidates(q: Queue, new_ids: jnp.ndarray, new_scores: jnp.ndarray,
                     new_mask: jnp.ndarray
                     ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Shared candidate masking for insert implementations.

    Candidates already present in the queue, duplicated within the incoming
    batch (first occurrence wins), masked out, or invalid (< 0) become the
    empty sentinel (-1, -inf, stable). This is the bit-parity contract
    between ``insert`` and the batched engine's merge-based insert — any
    change here affects both identically.
    """
    dup = jnp.any(new_ids[:, None] == q.ids[None, :], axis=1)
    m = new_ids.shape[0]
    earlier = (new_ids[:, None] == new_ids[None, :]) & (
        jnp.arange(m)[None, :] < jnp.arange(m)[:, None])
    dup = dup | jnp.any(earlier & new_mask[None, :], axis=1)
    keep = new_mask & ~dup & (new_ids >= 0)
    return (jnp.where(keep, new_ids, -1).astype(jnp.int32),
            jnp.where(keep, new_scores, NEG_INF).astype(jnp.float32),
            jnp.where(keep, False, True))


def insert(q: Queue, new_ids: jnp.ndarray, new_scores: jnp.ndarray,
           new_mask: jnp.ndarray) -> Queue:
    """Insert a batch of candidates, dedup against queue, truncate to capacity.

    new_ids int32[M], new_scores f32[M], new_mask bool[M] (False = skip).
    New entries arrive unstable. Entries already present in the queue are
    dropped (a node is only scored once per presence; expanded nodes are
    excluded upstream via the visited set).
    """
    cap = q.capacity
    ids, scores, stable = dedup_candidates(q, new_ids, new_scores, new_mask)

    all_ids = jnp.concatenate([q.ids, ids])
    all_scores = jnp.concatenate([q.scores, scores])
    all_stable = jnp.concatenate([q.stable, stable])
    i, s, st = _sort_desc(all_ids, all_scores, all_stable)
    return Queue(i[:cap], s[:cap], st[:cap])


def first_unstable(q: Queue, limit: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Index of the first unstable valid entry among the first ``limit`` slots.

    Returns (p, exists). ``limit`` may be a traced scalar.
    """
    pos = jnp.arange(q.capacity)
    mask = (~q.stable) & (q.ids >= 0) & (pos < limit)
    exists = jnp.any(mask)
    p = jnp.argmax(mask)  # first True (argmax returns first max index)
    return p, exists


def stable_count(q: Queue) -> jnp.ndarray:
    """Number of leading entries that are stable and valid (the paper's K*ef)."""
    ok = q.stable & (q.ids >= 0)
    # length of the leading run of True
    run = jnp.cumprod(ok.astype(jnp.int32))
    return jnp.sum(run)


def valid_count(q: Queue) -> jnp.ndarray:
    return jnp.sum(q.ids >= 0)


def grow(q: Queue, new_capacity: int) -> Queue:
    """Return a copy with larger capacity (host-side driver utility)."""
    assert new_capacity >= q.capacity
    pad = new_capacity - q.capacity
    return Queue(
        ids=jnp.concatenate([q.ids, jnp.full((pad,), -1, jnp.int32)]),
        scores=jnp.concatenate([q.scores, jnp.full((pad,), NEG_INF, jnp.float32)]),
        stable=jnp.concatenate([q.stable, jnp.ones((pad,), jnp.bool_)]),
    )


def from_entries(ids: jnp.ndarray, scores: jnp.ndarray, stable: jnp.ndarray,
                 capacity: int) -> Queue:
    """Build a queue of the given capacity from (possibly unsorted) entries."""
    n = ids.shape[0]
    if n < capacity:
        pad = capacity - n
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)])
        scores = jnp.concatenate([scores, jnp.full((pad,), NEG_INF, jnp.float32)])
        stable = jnp.concatenate([stable, jnp.ones((pad,), jnp.bool_)])
    i, s, st = _sort_desc(ids, scores, stable)
    return Queue(i[:capacity], s[:capacity], st[:capacity])
