"""Power-of-two bucketing: the one home for the repo's compile-signature math.

Every batched dispatch path (the single-host engine's growth rebuilds and
prefix groups, the sharded engine's mesh dispatches) keeps its jit signature
count logarithmic the same way: sizes are rounded up to powers of two, and
variable-size lane groups are padded to a power-of-two length by repeating a
real lane index (the padded rows recompute a real lane's work and are sliced
off on the host, so they never change results). These helpers used to be
re-implemented in ``core/progressive.py``, ``core/batch_progressive.py`` and
``sharded_search/search.py``; they live here now so the padding convention
can't drift between backends.
"""
from __future__ import annotations

import numpy as np


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (1 for x <= 1)."""
    return 1 << max(0, (int(x) - 1)).bit_length()


def pow2_padded_indices(idx) -> np.ndarray:
    """Pad a non-empty lane-index vector to the next power-of-two length by
    repeating ``idx[0]``. The duplicate rows redo a real lane's work, which
    keeps the dispatch semantics unchanged while bounding the distinct group
    sizes (hence compile signatures) to log2(B)."""
    idx = np.asarray(idx)
    m = len(idx)
    if m == 0:
        raise ValueError("cannot pad an empty index group")
    g = next_pow2(m)
    return np.concatenate([idx, np.full(g - m, idx[0], idx.dtype)])


def pow2_group_sizes(b: int) -> list[int]:
    """All power-of-two group sizes up to next_pow2(b) — the grid a prewarm
    pass walks so no mid-serving group size pays a fresh trace."""
    top = next_pow2(b)
    return [1 << i for i in range(top.bit_length()) if (1 << i) <= top]
