"""LaneBackend: the backend contract the continuous-batching scheduler drives.

The paper's progressive search -> diversify -> verify loop is query-owned and
index-free (Definition 1): a request carries its own ``(k, eps)`` and the
index is never rebuilt between diversification levels. That is exactly what
makes continuous batching backend-neutral — the scheduler only needs a fixed
set of *lanes* it can admit requests into, step, and harvest, regardless of
whether a lane is a slot in the single-host batched engine or a query row
replicated across an N-device mesh.

This module defines that contract:

* ``LaneRequest`` — what a backend needs to serve one request: the query
  vector plus its own ``(k, eps, ef, method, max_K)``. The scheduler's
  ``Request`` subclasses it with timing/bookkeeping fields, so scheduler
  requests flow into ``admit`` unwrapped.
* ``LaneBackend`` — the structural protocol. Implementations:
  ``core.batch_progressive.ProgressiveEngine`` (single-host lanes, methods
  ``pss``/``pgs``/``pds``) and ``sharded_search.engine.ShardedEngine`` (mesh
  lanes, method ``sharded``). ``serve.scheduler.LaneScheduler`` runs
  unmodified against either.

Lifecycle of one lane, as the scheduler drives it::

    free_lanes() -> admit(lane, request) -> step() ... step()
        -> harvest() yields (lane, result) once the lane finishes
        -> recycle(lane) returns the slot to free_lanes()

Drivers must ``harvest()`` after every ``step()`` before the next refill: a
finished lane's result is only retrievable until the lane is reused, and
backends differ on what a not-yet-harvested slot admits (``ShardedEngine``
refuses re-admission until ``recycle``; ``ProgressiveEngine`` additionally
reports finished lanes as free and allows direct re-admission — its
pre-protocol lockstep path — which discards the unharvested result).

``step()`` advances *every* occupied lane one round; lanes are independent,
so admission order can never leak into results (each backend documents and
tests its own parity contract against its per-query reference path).

Stats contract: the ``DiverseResult.stats`` a backend hands back from
``harvest`` must carry *real* per-lane counters — ``expansions`` is the
work actually performed for that request (cumulative under beam resumption,
re-counted restarts under scratch) and ``search_calls`` its progressive
round count. These are not just telemetry: the serving layer's
``ExpansionCostModel`` (``serve.policies``) learns per-``(k, eps, method)``
cost from them, and cost-aware admission (``drr``/``slo_cost``) schedules
by those predictions — a backend reporting fake counters would skew
multi-tenant fairness, not just a dashboard. Pinned for the mesh backend by
``tests/test_sharded_resume.py``
(``test_multiround_beam_fewer_expansions_same_budget``).
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np


@dataclasses.dataclass(eq=False)
class LaneRequest:
    """One diverse-search request, the way a backend sees it.

    ``ef`` <= 0 means "backend default" (the sharded backend has no beam-ef
    knob at all — its beam width follows the candidate budget). ``max_K``
    caps the progressive candidate budget (the paper's N/A guard).
    Compares by identity (``eq=False``): ``q`` is an array, so generated
    field equality would be ill-defined, and the scheduler's policy layer
    tracks requests by object identity through its queues.
    """
    q: np.ndarray
    k: int
    eps: float
    ef: int = 0
    method: str = "pss"
    max_K: int | None = None


@runtime_checkable
class LaneBackend(Protocol):
    """Structural protocol — duck-typed, checked by tests via isinstance."""

    num_lanes: int
    max_k: int
    default_ef: int
    #: methods this backend can serve; methods[0] is the scheduler default
    methods: tuple
    #: True when search rounds score a compressed (quantized) corpus — the
    #: exact-rerank stage then guards every certificate (contract 13). The
    #: serving layer's ``ExpansionCostModel`` keys its buckets on this flag
    #: so quantized and float tenants are priced separately.
    compressed: bool

    @property
    def bytes_per_vector(self) -> float:
        """Stored corpus bytes per vector on a device (f32: ``4 * d``;
        quantized: codes + amortized sidecars) — the memory-scaling stat
        surfaced through ``LaneScheduler.latency_stats()`` and the
        ``quant@`` bench-trend points."""
        ...

    @property
    def signature_log(self):
        """The backend's ``SignatureLog`` (compile-budget auditing)."""
        ...

    def free_lanes(self) -> np.ndarray:
        """Indices of lanes a new request may be admitted into."""
        ...

    def active_count(self) -> int:
        """Number of occupied (not yet harvested) lanes."""
        ...

    def admit(self, lane: int, request: LaneRequest) -> None:
        """Hand a free lane to ``request`` (fresh per-lane state; siblings
        untouched)."""
        ...

    def step(self):
        """Advance every occupied lane one progressive round."""
        ...

    def harvest(self) -> list:
        """Drain finished lanes: ``[(lane, DiverseResult), ...]`` for every
        lane that finished since the last harvest. The lane stays reserved
        until ``recycle``."""
        ...

    def recycle(self, lane: int) -> None:
        """Return a harvested lane's slot to the free pool."""
        ...

    def prewarm(self, *, max_capacity: int | None = None, ks: tuple = (),
                widths: tuple = ()):
        """Compile the backend's signature ladder ahead of serving."""
        ...


@runtime_checkable
class RescalableBackend(LaneBackend, Protocol):
    """A ``LaneBackend`` whose capacity can follow traffic (contract 16).

    Implemented by ``ShardedEngine`` (and delegated through
    ``index.mutable.MutableBackend``); the single-host ``ProgressiveEngine``
    is not rescalable, so the scheduler's elastic trigger feature-detects
    this protocol and stays inert otherwise. The contract mirrors the
    epoch swap's two-phase shape, but the barrier is quiesce-FREE:

    * ``prepare_rescale`` pays the expensive halves (repartitioning the
      corpus, compiling the target mesh's dispatch ladder) ahead of load;
    * ``rescale`` then migrates every in-flight lane's search state to the
      prepared mesh *between rounds* — occupied lanes resume their budget
      ladder on the new topology, nothing drains, and a migrated lane's
      certified result still passes ``theorem2_recheck``. Resharding is a
      capacity knob, never a results knob.
    """

    @property
    def num_shards(self) -> int:
        """Shard count of the mesh currently serving."""
        ...

    def prepare_rescale(self, shards: int, mesh, index=None, *,
                        prewarm: bool = True):
        """Build + prewarm an elastic target mesh ahead of the scale
        event."""
        ...

    def rescale_options(self) -> tuple[int, ...]:
        """Shard counts servable right now (current + prepared targets)."""
        ...

    def rescale(self, shards: int) -> bool:
        """Migrate corpus + in-flight lanes to the prepared ``shards``
        mesh; False if already there."""
        ...
