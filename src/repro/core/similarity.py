"""Similarity spaces from the paper (§IV-A, Eqs. 5-7).

The paper defines three similarity functions — higher is more similar:

  sim_L2(u, v)  = 1 - ||u - v||_2                       (Deep1M)
  sim_ip(u, v)  = <u, v>                                 (Txt2img)
  sim_cos(u, v) = <u, v> / (||u|| * ||v||)               (LAION-art)

All public entry points are pure jnp and jit/vmap-safe. The Pallas kernel in
``repro.kernels.batch_similarity`` implements the same math for the hot path;
these functions double as its oracle.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Metric = Literal["l2", "ip", "cos"]

METRICS: tuple[str, ...] = ("l2", "ip", "cos")

_EPS = 1e-12


def _l2_sim(dots: jnp.ndarray, u_sq: jnp.ndarray, v_sq: jnp.ndarray) -> jnp.ndarray:
    # sim = 1 - sqrt(||u||^2 - 2<u,v> + ||v||^2); clamp for numerical safety.
    d2 = jnp.maximum(u_sq + v_sq - 2.0 * dots, 0.0)
    return 1.0 - jnp.sqrt(d2)


def query_sim(q: jnp.ndarray, x: jnp.ndarray, metric: Metric) -> jnp.ndarray:
    """Similarity of one query ``q``[d] against rows of ``x``[..., d].

    The dot products are a multiply+reduce rather than a matvec: XLA's gemv
    changes accumulation order under vmap, while the last-axis reduce is
    bitwise batch-invariant — the batched progressive engine relies on this
    for exact per-lane parity with the per-query drivers.
    """
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    dots = jnp.sum(x * q, axis=-1)
    if metric == "ip":
        return dots
    if metric == "cos":
        qn = jnp.sqrt(jnp.maximum(jnp.sum(q * q), _EPS))
        xn = jnp.sqrt(jnp.maximum(jnp.sum(x * x, axis=-1), _EPS))
        return dots / (qn * xn)
    if metric == "l2":
        return _l2_sim(dots, jnp.sum(q * q), jnp.sum(x * x, axis=-1))
    raise ValueError(f"unknown metric {metric!r}")


def pairwise_sim(x: jnp.ndarray, y: jnp.ndarray, metric: Metric) -> jnp.ndarray:
    """Pairwise similarity matrix between rows of ``x``[m, d] and ``y``[n, d]."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    dots = x @ y.T
    if metric == "ip":
        return dots
    if metric == "cos":
        xn = jnp.sqrt(jnp.maximum(jnp.sum(x * x, axis=-1), _EPS))
        yn = jnp.sqrt(jnp.maximum(jnp.sum(y * y, axis=-1), _EPS))
        return dots / (xn[:, None] * yn[None, :])
    if metric == "l2":
        return _l2_sim(
            dots,
            jnp.sum(x * x, axis=-1)[:, None],
            jnp.sum(y * y, axis=-1)[None, :],
        )
    raise ValueError(f"unknown metric {metric!r}")


def self_sim(x: jnp.ndarray, metric: Metric) -> jnp.ndarray:
    """Pairwise similarity among rows of ``x``[n, d] (diagonal = self-sim)."""
    return pairwise_sim(x, x, metric)


@functools.partial(jax.jit, static_argnames=("metric",))
def query_sim_jit(q: jnp.ndarray, x: jnp.ndarray, metric: Metric) -> jnp.ndarray:
    return query_sim(q, x, metric)


def sim_one(u: jnp.ndarray, v: jnp.ndarray, metric: Metric) -> jnp.ndarray:
    """Scalar similarity between two vectors."""
    return query_sim(u, v[None, :], metric)[0]
