"""qwen1.5-4b [dense]: MHA (kv=20), QKV bias. hf:Qwen/Qwen1.5-4B."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
    d_ff=6912, vocab_size=151936, qkv_bias=True, mlp_act="silu",
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)
