"""llama-3.2-vision-90b [vlm]: cross-attn image layers every 5th layer.

hf:meta-llama/Llama-3.2-11B-Vision (90B variant; unverified). The vision
encoder is a STUB per the shape card: input_specs() supplies precomputed
patch embeddings [B, num_frontend_tokens, d_model].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, mlp_act="silu", rope_theta=5e5,
    frontend="vision", num_frontend_tokens=1024, cross_attn_every=5,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
