"""recurrentgemma-9b [hybrid]: RG-LRU + local attn at 1:2. arXiv:2402.19427.

38 layers in repeating (R, R, A) pattern; MQA local attention window 2048;
GeGLU MLP; head_dim 256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    head_dim=256, d_ff=12288, vocab_size=256000, mlp_act="gelu",
    block_pattern="RRA", local_window=2048, lru_width=4096,
    tie_embeddings=True,
    source="arXiv:2402.19427; unverified",
)
