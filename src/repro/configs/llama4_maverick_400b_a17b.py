"""llama4-maverick-400b-a17b [moe]: 128 experts top-1, early fusion stub.

hf:meta-llama/Llama-4-Scout-17B-16E (maverick variant; unverified).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, mlp_act="silu", rope_theta=5e5,
    num_experts=128, experts_per_token=1,
    frontend="vision", num_frontend_tokens=0,  # early-fusion stub: tokens only
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
