"""whisper-small [audio]: enc-dec, conv frontend stubbed. arXiv:2212.04356."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    mlp_act="gelu_mlp",        # whisper uses plain GELU MLP (non-gated)
    qkv_bias=True,
    encoder_layers=12, frontend="audio", num_frontend_tokens=1500,
    source="arXiv:2212.04356; unverified",
)
