"""moonshot-v1-16b-a3b [moe]: 64 experts top-6 (kimi/moonlight).

hf:moonshotai/Moonlight-16B-A3B.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163840, mlp_act="silu",
    num_experts=64, experts_per_token=6,
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)
