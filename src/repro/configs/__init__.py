"""Config registry: one module per assigned architecture (+ paper's own)."""
from repro.configs.base import SHAPES, ModelConfig, ShapeCard, shape_applicable

_ARCH_MODULES = {
    "whisper-small": "whisper_small",
    "qwen2-1.5b": "qwen2_1_5b",
    "gemma-2b": "gemma_2b",
    "qwen2-7b": "qwen2_7b",
    "qwen1.5-4b": "qwen1_5_4b",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-370m": "mamba2_370m",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    import importlib

    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


__all__ = ["ARCH_NAMES", "SHAPES", "ModelConfig", "ShapeCard", "get_config",
           "shape_applicable"]
