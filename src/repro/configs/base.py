"""Architecture config schema + the shape cards assigned to this paper."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    # attention / projections
    qkv_bias: bool = False
    mlp_act: str = "silu"       # silu (SwiGLU) | gelu (GeGLU)
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # hybrid (recurrentgemma): pattern of R=recurrent, A=local-attention
    block_pattern: str = ""     # e.g. "RRA" repeated
    local_window: int = 0
    lru_width: int = 0          # 0 -> d_model
    # enc-dec / frontend
    encoder_layers: int = 0
    frontend: str = "none"      # none | audio | vision
    num_frontend_tokens: int = 0
    cross_attn_every: int = 0   # vlm: one cross-attn layer per this many
    # numerics
    dtype: str = "bfloat16"
    # provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k (bounded per-token state)?"""
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ModelConfig":
        """Smoke-test config: same family/wiring, tiny sizes."""
        if self.family == "hybrid":
            layers = len(self.block_pattern) or 3     # one full pattern
        elif self.family == "vlm":
            layers = 4                                # 2 cross-attn at every=2
        else:
            layers = 2
        return dataclasses.replace(
            self,
            num_layers=layers,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=16,
            d_ff=96,
            vocab_size=503,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else self.ssm_headdim,
            ssm_chunk=8,
            local_window=min(self.local_window, 8) if self.local_window else 0,
            lru_width=64 if self.family == "hybrid" else 0,
            encoder_layers=min(self.encoder_layers, 2),
            num_frontend_tokens=(12 if self.num_frontend_tokens else 0),
            cross_attn_every=2 if self.cross_attn_every else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCard:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: dict[str, ShapeCard] = {
    "train_4k": ShapeCard("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCard("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCard("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCard("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's shape card rules."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 512k-token KV decode is "
                       "quadratic-prefill-gated; skipped per shape card "
                       "(runs only for ssm/hybrid)")
    return True, ""
