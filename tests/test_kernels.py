"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracle (ref.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)
METRICS = ("l2", "ip", "cos")


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("shape", [(1, 7, 5), (3, 150, 37), (2, 129, 64),
                                   (5, 600, 24)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batch_similarity_sweep(metric, shape, dtype):
    b, n, d = shape
    qs = jnp.asarray(RNG.normal(size=(b, d)), dtype)
    x = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    got = ops.batch_similarity_many(qs, x, metric, impl="interpret")
    want = ref.batch_similarity_many(qs.astype(jnp.float32),
                                     x.astype(jnp.float32), metric)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("k", [5, 70, 129, 256])
def test_pairwise_adjacency_sweep(metric, k):
    x = jnp.asarray(RNG.normal(size=(k, 19)), jnp.float32)
    eps = float(RNG.normal()) * 0.3
    got = ops.pairwise_adjacency(x, eps, metric, impl="interpret")
    want = ref.pairwise_adjacency(x, eps, metric)
    # threshold comparisons can flip on ties within fp error: allow <=0.5%
    assert np.mean(np.asarray(got) != np.asarray(want)) < 5e-3
    assert not np.any(np.diag(np.asarray(got)))


def test_pairwise_adjacency_valid_mask():
    x = jnp.asarray(RNG.normal(size=(40, 8)), jnp.float32)
    valid = jnp.asarray(np.arange(40) < 25)
    got = ops.pairwise_adjacency(x, 0.0, "cos", valid, impl="interpret")
    assert not np.any(np.asarray(got)[25:, :])
    assert not np.any(np.asarray(got)[:, 25:])


@pytest.mark.parametrize("n", [8, 64, 100, 128])
def test_topk_merge_sweep(n):
    sa = np.sort(RNG.normal(size=n))[::-1].astype(np.float32)
    sb = np.sort(RNG.normal(size=n))[::-1].astype(np.float32)
    ia = np.arange(n, dtype=np.int32)
    ib = np.arange(1000, 1000 + n, dtype=np.int32)
    gi, gs = ops.topk_merge(jnp.asarray(ia), jnp.asarray(sa),
                            jnp.asarray(ib), jnp.asarray(sb),
                            impl="interpret")
    ri, rs = ref.topk_merge(jnp.asarray(ia), jnp.asarray(sa),
                            jnp.asarray(ib), jnp.asarray(sb))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(gs), np.asarray(rs))


def test_topk_merge_ties_deterministic():
    s = np.zeros(16, np.float32)
    ia = np.arange(16, dtype=np.int32) * 2
    ib = np.arange(16, dtype=np.int32) * 2 + 1
    gi, _ = ops.topk_merge(jnp.asarray(ia), jnp.asarray(s),
                           jnp.asarray(ib), jnp.asarray(s),
                           impl="interpret")
    np.testing.assert_array_equal(np.asarray(gi), np.arange(16))


@pytest.mark.parametrize("k,K", [(3, 20), (5, 64), (10, 130)])
def test_greedy_diversify_sweep(k, K):
    x = jnp.asarray(RNG.normal(size=(K, 16)), jnp.float32)
    scores = jnp.asarray(RNG.normal(size=K), jnp.float32)
    adj = ref.pairwise_adjacency(x, 0.2, "cos")
    gs, gc = ops.greedy_diversify(scores, adj, k, impl="interpret")
    rs, rc = ref.greedy_diversify(scores, adj, k)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(rs))
    assert int(gc) == int(rc)
    # result is an independent set
    sel = np.asarray(gs)
    sel = sel[sel >= 0]
    a = np.asarray(adj)
    for i in sel:
        for j in sel:
            if i != j:
                assert not a[i, j]


# ---------------------------------------------------- impl dispatch (ops) ----

def _op_calls():
    """One representative call per public op, as (name, fn(impl))."""
    q = jnp.asarray(RNG.normal(size=12), jnp.float32)
    qs = jnp.asarray(RNG.normal(size=(3, 12)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(50, 12)), jnp.float32)
    scores = jnp.asarray(RNG.normal(size=50), jnp.float32)
    adj = ref.pairwise_adjacency(x, 0.2, "cos")
    bsc = jnp.asarray(RNG.normal(size=(2, 50)), jnp.float32)
    badj = jnp.stack([adj, adj])
    sa = jnp.asarray(np.sort(RNG.normal(size=16))[::-1].copy(), jnp.float32)
    sb = jnp.asarray(np.sort(RNG.normal(size=16))[::-1].copy(), jnp.float32)
    ia = jnp.arange(16, dtype=jnp.int32)
    ib = jnp.arange(100, 116, dtype=jnp.int32)
    fids = np.full((2, 50), -1, np.int32)
    fids[:, :40] = np.stack([RNG.choice(50, 40, replace=False)
                             for _ in range(2)])
    fsc = np.full((2, 50), -np.inf, np.float32)
    fsc[:, :40] = np.sort(RNG.normal(size=(2, 40)))[:, ::-1]
    fKs = np.asarray([40, 25], np.int32)
    feps = np.asarray([0.4, 0.6], np.float32)
    return [
        ("batch_similarity",
         lambda impl: ops.batch_similarity(q, x, "cos", impl=impl)),
        ("batch_similarity_many",
         lambda impl: ops.batch_similarity_many(qs, x, "cos", impl=impl)),
        ("pairwise_adjacency",
         lambda impl: ops.pairwise_adjacency(x, 0.2, "cos", impl=impl)),
        ("topk_merge",
         lambda impl: ops.topk_merge(ia, sa, ib, sb, impl=impl)),
        ("greedy_diversify",
         lambda impl: ops.greedy_diversify(scores, adj, 5, impl=impl)),
        ("greedy_diversify_batch",
         lambda impl: ops.greedy_diversify_batch(bsc, badj, 5, impl=impl)),
        ("fused_round",
         lambda impl: ops.fused_round_batch(x, fids, fsc, fKs, feps, 5,
                                            "cos", impl=impl)),
    ]


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_set_default_impl_sweep(impl):
    """Every op honors the process default: calling with no impl= under
    set_default_impl(impl) matches an explicit impl="ref" call (bit-exact
    for the index-valued ops; allclose for the similarity scores)."""
    calls = _op_calls()
    try:
        ops.set_default_impl(impl)
        defaulted = [(name, fn(None)) for name, fn in calls]
    finally:
        ops.set_default_impl(None)
    for (name, got), (_, want) in zip(defaulted,
                                      [(n, f("ref")) for n, f in calls]):
        got = got if isinstance(got, tuple) else (got,)
        want = want if isinstance(want, tuple) else (want,)
        for g, w in zip(got, want):
            g, w = np.asarray(g), np.asarray(w)
            if np.issubdtype(g.dtype, np.floating):
                np.testing.assert_allclose(g, w, rtol=2e-5, atol=2e-5,
                                           err_msg=name)
            else:
                np.testing.assert_array_equal(g, w, err_msg=name)


def test_unknown_impl_raises():
    """_resolve rejects unknown impl strings instead of falling through."""
    for name, fn in _op_calls():
        with pytest.raises(ValueError, match="unknown kernel impl"):
            fn("jnp")


def test_set_default_impl_rejects_unknown():
    with pytest.raises(ValueError, match="unknown kernel impl"):
        ops.set_default_impl("cuda")
    assert ops._DEFAULT_IMPL is None
