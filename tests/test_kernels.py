"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracle (ref.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)
METRICS = ("l2", "ip", "cos")


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("shape", [(1, 7, 5), (3, 150, 37), (2, 129, 64),
                                   (5, 600, 24)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batch_similarity_sweep(metric, shape, dtype):
    b, n, d = shape
    qs = jnp.asarray(RNG.normal(size=(b, d)), dtype)
    x = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    got = ops.batch_similarity_many(qs, x, metric, impl="interpret")
    want = ref.batch_similarity_many(qs.astype(jnp.float32),
                                     x.astype(jnp.float32), metric)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("k", [5, 70, 129, 256])
def test_pairwise_adjacency_sweep(metric, k):
    x = jnp.asarray(RNG.normal(size=(k, 19)), jnp.float32)
    eps = float(RNG.normal()) * 0.3
    got = ops.pairwise_adjacency(x, eps, metric, impl="interpret")
    want = ref.pairwise_adjacency(x, eps, metric)
    # threshold comparisons can flip on ties within fp error: allow <=0.5%
    assert np.mean(np.asarray(got) != np.asarray(want)) < 5e-3
    assert not np.any(np.diag(np.asarray(got)))


def test_pairwise_adjacency_valid_mask():
    x = jnp.asarray(RNG.normal(size=(40, 8)), jnp.float32)
    valid = jnp.asarray(np.arange(40) < 25)
    got = ops.pairwise_adjacency(x, 0.0, "cos", valid, impl="interpret")
    assert not np.any(np.asarray(got)[25:, :])
    assert not np.any(np.asarray(got)[:, 25:])


@pytest.mark.parametrize("n", [8, 64, 100, 128])
def test_topk_merge_sweep(n):
    sa = np.sort(RNG.normal(size=n))[::-1].astype(np.float32)
    sb = np.sort(RNG.normal(size=n))[::-1].astype(np.float32)
    ia = np.arange(n, dtype=np.int32)
    ib = np.arange(1000, 1000 + n, dtype=np.int32)
    gi, gs = ops.topk_merge(jnp.asarray(ia), jnp.asarray(sa),
                            jnp.asarray(ib), jnp.asarray(sb),
                            impl="interpret")
    ri, rs = ref.topk_merge(jnp.asarray(ia), jnp.asarray(sa),
                            jnp.asarray(ib), jnp.asarray(sb))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(gs), np.asarray(rs))


def test_topk_merge_ties_deterministic():
    s = np.zeros(16, np.float32)
    ia = np.arange(16, dtype=np.int32) * 2
    ib = np.arange(16, dtype=np.int32) * 2 + 1
    gi, _ = ops.topk_merge(jnp.asarray(ia), jnp.asarray(s),
                           jnp.asarray(ib), jnp.asarray(s),
                           impl="interpret")
    np.testing.assert_array_equal(np.asarray(gi), np.arange(16))


@pytest.mark.parametrize("k,K", [(3, 20), (5, 64), (10, 130)])
def test_greedy_diversify_sweep(k, K):
    x = jnp.asarray(RNG.normal(size=(K, 16)), jnp.float32)
    scores = jnp.asarray(RNG.normal(size=K), jnp.float32)
    adj = ref.pairwise_adjacency(x, 0.2, "cos")
    gs, gc = ops.greedy_diversify(scores, adj, k, impl="interpret")
    rs, rc = ref.greedy_diversify(scores, adj, k)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(rs))
    assert int(gc) == int(rc)
    # result is an independent set
    sel = np.asarray(gs)
    sel = sel[sel >= 0]
    a = np.asarray(adj)
    for i in sel:
        for j in sel:
            if i != j:
                assert not a[i, j]
