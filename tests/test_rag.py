"""RAG pipeline: diverse retrieval feeding decode (paper's motivating app)."""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.similarity import pairwise_sim
from repro.index.flat import build_knn_graph
from repro.models import model as M
from repro.serve.rag import RagPipeline

import jax.numpy as jnp


def test_rag_pipeline_end_to_end(clustered_data):
    cfg = get_config("qwen2-1.5b").reduced()
    params = M.init_params(cfg, jax.random.key(0))
    # index built over vectors padded/truncated to d_model? retrieval is
    # independent of the LM dims; use the raw data graph.
    graph = build_knn_graph(clustered_data, metric="l2", M=8)
    pipe = RagPipeline(cfg, params, graph, k=4, eps=0.0, K_budget=32, ef=4)
    qs = clustered_data[:3]
    ids, cert = pipe.retrieve(qs)
    assert ids.shape == (3, 4)
    for i in range(3):
        sel = ids[i][ids[i] >= 0]
        assert len(sel) == 4
        sims = np.asarray(pairwise_sim(jnp.asarray(clustered_data[sel]),
                                       jnp.asarray(clustered_data[sel]),
                                       "l2"))
        off = sims[~np.eye(len(sel), dtype=bool)]
        assert np.all(off < 0.0 + 1e-5)
    prompts = np.ones((3, 2), np.int32)
    out, ids2, cert2 = pipe.generate(qs, prompts, steps=3)
    assert out.shape == (3, 3)
