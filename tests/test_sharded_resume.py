"""Resumable shard-local beams: the ShardedEngine resumption contract.

``resume="beam"`` carries a fixed-shape ``ShardedSearchState`` across budget
rounds. The contract under test:

* a lane that finishes in its first round is bit-exact with
  ``sharded_diverse_search`` at its final K-budget (both resume modes);
* a multi-round lane under ``"beam"`` does strictly fewer cumulative shard
  expansions than ``"scratch"`` at the same final K-budget;
* every certified ``"beam"`` lane passes an independent Theorem-2 re-check
  against its final candidate frontier;
* recall vs the exact diverse oracle is no worse than the scratch path on
  the 10k test graph (slow);
* the prewarm ladder covers ``max_capacity > K0`` and repeat mixed-eps
  traffic triggers zero recompiles.

The 4-forced-host-device variant of the expansion/recall/certificate checks
lives in ``tests/dist_scripts/sharded_scheduler_check.py``.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core.backend import LaneRequest
from repro.core.theorems import theorem2_recheck
from repro.sharded_search import (ShardedEngine, build_sharded_index,
                                  resume_jit_cache_sizes,
                                  sharded_diverse_search,
                                  sharded_progressive_diverse, sharded_topk)


@pytest.fixture(scope="module")
def world():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(256, 12)).astype(np.float32)
    index = build_sharded_index(x, 1, "ip", M=8)
    mesh = make_mesh((1,), ("data",))
    qs = rng.normal(size=(6, 12)).astype(np.float32)
    return x, index, mesh, qs


def _drive(eng, qs, k, epss, max_K=None):
    """Admit one request per lane, run to completion, return lane results."""
    for lane in range(qs.shape[0]):
        eng.admit(lane, LaneRequest(q=qs[lane], k=k, eps=float(epss[lane]),
                                    method="sharded", max_K=max_K))
    out = {}
    while eng.active_count():
        eng.step()
        for lane, res in eng.harvest():
            out[lane] = res
            eng.recycle(lane)
    return out


# -------------------------------------------------- single-round parity ----

@pytest.mark.parametrize("resume", ["scratch", "beam"])
def test_single_round_lanes_bit_exact(world, resume):
    """Either resume mode: a lane certified in round 1 equals
    sharded_diverse_search at its K_final, bit for bit — and its expansion
    count equals the scratch reference's (the seeded round IS the scratch
    computation)."""
    x, index, mesh, qs = world
    eng = ShardedEngine(index, x, mesh, num_lanes=6, K0=16, max_k=8,
                        resume=resume)
    out = _drive(eng, qs, 4, np.full(6, 4.0))
    single = [lane for lane, r in out.items() if r.stats.search_calls == 1]
    assert single, "fixture produced no single-round lane"
    for lane in single:
        r = out[lane]
        ids, sc, cert, exp = sharded_diverse_search(
            index, jnp.asarray(x), jnp.asarray(qs[lane][None]), 4, 4.0,
            int(r.stats.K_final), mesh, with_expansions=True)
        np.testing.assert_array_equal(np.asarray(ids)[0], r.ids)
        np.testing.assert_array_equal(np.asarray(sc)[0], r.scores)
        assert bool(np.asarray(cert)[0]) == r.stats.certified
        assert int(np.asarray(exp)[0]) == r.stats.expansions


def test_scratch_mode_full_ladder_parity(world):
    """resume="scratch" is the lockstep-parity mode: EVERY lane (multi-round
    included) equals sharded_diverse_search at its K_final."""
    x, index, mesh, qs = world
    eng = ShardedEngine(index, x, mesh, num_lanes=6, K0=16, max_k=8,
                        resume="scratch")
    out = _drive(eng, qs, 4, np.full(6, 4.0))
    assert any(r.stats.search_calls > 1 for r in out.values())
    for lane, r in out.items():
        ids, sc, cert = sharded_diverse_search(
            index, jnp.asarray(x), jnp.asarray(qs[lane][None]), 4, 4.0,
            int(r.stats.K_final), mesh)
        np.testing.assert_array_equal(np.asarray(ids)[0], r.ids)
        np.testing.assert_array_equal(np.asarray(sc)[0], r.scores)
        assert bool(np.asarray(cert)[0]) == r.stats.certified


# ------------------------------------------------ multi-round resumption ----

def test_multiround_beam_fewer_expansions_same_budget(world):
    """The tentpole measurement: capped at two rounds, both modes retire
    uncertified lanes at the same K-budget, and every multi-round beam lane
    reports strictly fewer cumulative shard expansions than its scratch
    twin. Real counters, not the old hardcoded expansions=0."""
    x, index, mesh, qs = world
    epss = np.full(6, 4.0)
    outs = {}
    for mode in ("scratch", "beam"):
        eng = ShardedEngine(index, x, mesh, num_lanes=6, K0=16, max_k=8,
                            resume=mode, max_rounds=2)
        outs[mode] = _drive(eng, qs, 4, epss)
    multi = [lane for lane, r in outs["scratch"].items()
             if r.stats.search_calls > 1]
    assert multi, "fixture produced no multi-round lane"
    for lane in multi:
        s, b = outs["scratch"][lane], outs["beam"][lane]
        # round-1 results are bit-exact across modes, so the survivor sets
        # match and the capped ladder pins both to the same final budget
        assert b.stats.search_calls == s.stats.search_calls
        assert b.stats.K_final == s.stats.K_final
        assert b.stats.growths == s.stats.growths == 1
        assert 0 < b.stats.expansions < s.stats.expansions


def test_state_capacity_below_floor_rejected(world):
    """A beam-state queue narrower than beam_state_capacity would silently
    drop candidates and void the parity/soundness contract — the engine
    must refuse it at construction."""
    x, index, mesh, qs = world
    with pytest.raises(ValueError, match="resumable-beam floor"):
        ShardedEngine(index, x, mesh, num_lanes=2, K0=16, max_k=8,
                      resume="beam", state_capacity=8)
    # at or above the floor is fine
    ShardedEngine(index, x, mesh, num_lanes=2, K0=16, max_k=8,
                  resume="beam", state_capacity=512)


def test_exhausted_flag_semantics(world):
    """exhausted marks a ladder that hit its max_K cap without certifying;
    a round-limited retirement is truncated, not exhausted."""
    x, index, mesh, qs = world
    # eps so low the diversity graph is complete (sim > eps everywhere):
    # only singleton sets are diverse, so no certificate can ever fire
    eng = ShardedEngine(index, x, mesh, num_lanes=2, K0=16, max_k=8,
                        resume="beam", max_rounds=2)
    out = _drive(eng, qs[:2], 4, np.full(2, -1e6))
    for r in out.values():
        assert not r.stats.certified and not r.stats.exhausted  # truncated
    eng = ShardedEngine(index, x, mesh, num_lanes=2, K0=16, max_k=8,
                        resume="beam")
    out = _drive(eng, qs[:2], 4, np.full(2, -1e6), max_K=32)
    for r in out.values():
        assert not r.stats.certified and r.stats.exhausted
        assert r.stats.K_final == 32


# ------------------------------------------------- certificate soundness ----

@pytest.mark.parametrize("resume", ["scratch", "beam"])
def test_certified_lanes_pass_independent_recheck(world, resume):
    """A certified lane's result must survive a Theorem-2 re-check run
    independently (host-side div-A* over the lane's recorded final
    candidate frontier) — the soundness half of the resumption contract."""
    x, index, mesh, qs = world
    eng = ShardedEngine(index, x, mesh, num_lanes=6, K0=16, max_k=8,
                        resume=resume, record_candidates=True)
    out = _drive(eng, qs, 4, np.full(6, 4.0))
    certified = [lane for lane, r in out.items() if r.stats.certified]
    assert certified
    for lane in certified:
        r = out[lane]
        if resume == "scratch":
            assert eng.last_candidates[lane] is None
            cand_ids, cand_sc, _ = (np.asarray(a)[0] for a in sharded_topk(
                index, jnp.asarray(qs[lane][None]), int(r.stats.K_final),
                int(r.stats.K_final) * eng.L_factor, mesh,
                with_expansions=True))
        else:
            cand_ids, cand_sc = eng.last_candidates[lane]
        ok, sel_ids = theorem2_recheck(x, index.metric, cand_ids, cand_sc,
                                       4.0, 4)
        assert ok, f"lane {lane}: certificate does not re-verify"
        np.testing.assert_array_equal(sel_ids, r.ids)


# ------------------------------------------- scheduler over beam (default) --

def test_scheduler_over_beam_backend(world):
    """The shipped default path: LaneScheduler continuous batching over a
    resume="beam" ShardedEngine, more requests than lanes so freed slots
    are re-admitted (re-seeding recycled beam state). Single-round results
    keep bit-exact parity; every result carries real counters and satisfies
    the lane's K-budget ladder."""
    from repro.serve.scheduler import LaneScheduler

    x, index, mesh, qs = world
    eng = ShardedEngine(index, x, mesh, num_lanes=2, K0=16, max_k=8,
                        resume="beam")
    sched = LaneScheduler(backend=eng, prewarm=False, max_pending=8)
    reqs = [sched.submit(qs[i], 4, 4.0) for i in range(6)]  # 6 reqs, 2 lanes
    sched.drain()
    ladder = {min(16 << j, 256) for j in range(10)}
    solo = _drive(ShardedEngine(index, x, mesh, num_lanes=6, K0=16, max_k=8,
                                resume="beam"), qs, 4, np.full(6, 4.0))
    for i, r in enumerate(reqs):
        st = r.result.stats
        assert st.expansions > 0 and st.K_final in ladder
        # scheduler admission order must not leak into per-lane results:
        # each request equals the same query driven solo through a beam lane
        np.testing.assert_array_equal(r.result.ids, solo[i].ids)
        np.testing.assert_array_equal(r.result.scores, solo[i].scores)
        assert st.certified == solo[i].stats.certified
        assert st.K_final == solo[i].stats.K_final
        assert st.expansions == solo[i].stats.expansions
        if st.search_calls == 1:
            ids, sc, _ = sharded_diverse_search(
                index, jnp.asarray(x), jnp.asarray(qs[i][None]), 4, 4.0,
                int(st.K_final), mesh)
            np.testing.assert_array_equal(np.asarray(ids)[0], r.result.ids)
            np.testing.assert_array_equal(np.asarray(sc)[0], r.result.scores)
    assert sched.latency_stats()["completed"] == 6


# ------------------------------------------------------------- wrapper -----

def test_wrapper_resume_modes(world):
    """sharded_progressive_diverse threads the resume mode through: scratch
    keeps every-lane parity, beam keeps single-round parity and dispatched
    K_final budgets."""
    x, index, mesh, qs = world
    ladder = {min(16 << j, 256) for j in range(10)}
    for mode in ("scratch", "beam"):
        ids, sc, cert, K_final = sharded_progressive_diverse(
            index, np.asarray(x), qs, k=4, eps=4.0, mesh=mesh, K0=16,
            resume=mode)
        assert set(int(K) for K in K_final) <= ladder
        for i in range(qs.shape[0]):
            if mode == "beam" and int(K_final[i]) > 16:
                continue          # multi-round beam lanes: soundness, not bits
            rids, rsc, rcert = sharded_diverse_search(
                index, jnp.asarray(x), jnp.asarray(qs[i][None]), 4, 4.0,
                int(K_final[i]), mesh)
            np.testing.assert_array_equal(np.asarray(rids)[0], ids[i])
            np.testing.assert_array_equal(np.asarray(rsc)[0], sc[i])
            assert bool(np.asarray(rcert)[0]) == bool(cert[i])


# ------------------------------------------------- prewarm / recompiles ----

@pytest.mark.parametrize("resume", ["scratch", "beam"])
def test_prewarm_walks_full_ladder_and_freezes(world, resume):
    """prewarm(max_capacity > K0) walks every budget rung × pow2 group × k;
    repeat mixed-(k, eps) traffic after freeze() triggers zero unplanned
    signatures and zero new resume-dispatch compilations."""
    x, index, mesh, qs = world
    eng = ShardedEngine(index, x, mesh, num_lanes=4, K0=16, max_k=8,
                        resume=resume)
    warmed = eng.prewarm(max_capacity=64, ks=(4, 8))
    rungs = {(g, K, k) for _, g, K, k in warmed}
    assert rungs == {(g, K, k) for g in (1, 2, 4) for K in (16, 32, 64)
                     for k in (4, 8)}
    eng.signature_log.freeze()
    sizes_after_warm = resume_jit_cache_sizes()
    rng = np.random.default_rng(0)
    for repeat in range(2):
        reqs = list(rng.permutation(8))
        ks = [4 if i % 2 else 8 for i in range(8)]
        epss = [3.5 if i % 3 else 4.5 for i in range(8)]
        lane_req = 0
        served = 0
        while served < len(reqs):
            for lane in eng.free_lanes():
                if lane_req >= len(reqs):
                    break
                i = reqs[lane_req]
                eng.admit(int(lane), LaneRequest(
                    q=qs[i % 6], k=ks[lane_req], eps=epss[lane_req],
                    method="sharded", max_K=64))
                lane_req += 1
            eng.step()
            for lane, _ in eng.harvest():
                eng.recycle(lane)
                served += 1
        if resume == "beam":
            assert resume_jit_cache_sizes() == sizes_after_warm, repeat
    assert eng.signature_log.unplanned == [], eng.signature_log.unplanned


# ------------------------------------------------------ 10k recall (slow) --

@pytest.mark.slow
def test_resume_recall_no_worse_than_scratch_10k():
    """On the 10k test graph, beam-resumed lanes must reach recall vs the
    exact diverse oracle no worse than the scratch path, at strictly fewer
    cumulative expansions over the multi-round lanes."""
    from repro.core.baselines import div_astar_oracle

    rng = np.random.default_rng(5)
    n, d = 10_000, 32
    centers = rng.normal(size=(64, d)) * 0.25
    x = centers[rng.integers(0, 64, n)] + rng.normal(size=(n, d))
    x = (x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True),
                        1e-9)).astype(np.float32)
    index = build_sharded_index(x, 1, "cos", M=8)
    mesh = make_mesh((1,), ("data",))
    qs = x[rng.integers(0, n, 6)] + 0.05 * rng.normal(size=(6, d))
    qs = (qs / np.maximum(np.linalg.norm(qs, axis=1, keepdims=True),
                          1e-9)).astype(np.float32)
    k, eps = 5, 0.35   # dense enough G^eps that lanes need 3-4 rounds
    outs = {}
    for mode in ("scratch", "beam"):
        eng = ShardedEngine(index, x, mesh, num_lanes=6, K0=16, max_k=8,
                            resume=mode, max_rounds=4)
        outs[mode] = _drive(eng, qs, k, np.full(6, eps))
    multi = [lane for lane, r in outs["scratch"].items()
             if r.stats.search_calls > 1]
    assert multi, "10k fixture produced no multi-round lane"

    def mean_recall(out):
        recs = []
        for lane, r in out.items():
            o = div_astar_oracle(x, "cos", qs[lane], k, eps, X=512)
            truth = set(int(i) for i in o.ids if i >= 0)
            got = set(int(i) for i in r.ids if i >= 0)
            recs.append(len(got & truth) / max(len(truth), 1))
        return float(np.mean(recs))

    assert mean_recall(outs["beam"]) >= mean_recall(outs["scratch"])
    for lane in multi:
        assert (outs["beam"][lane].stats.expansions
                < outs["scratch"][lane].stats.expansions)
