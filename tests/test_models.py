"""Per-arch smoke tests (reduced configs) + decode/teacher-forcing
consistency + component references (SSD scan, RG-LRU, MoE)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import model as M

pytestmark = pytest.mark.slow  # compile-heavy; CI runs these in the slow job

RNG = jax.random.key(0)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_and_decode(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, RNG)
    batch = M.make_batch(cfg, batch=2, seq=12, rng=RNG)
    logits, aux = M.forward(cfg, params, batch)
    assert logits.shape == (2, 12, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss = M.loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    cache = M.init_cache(cfg, batch=2, max_seq=16)
    tok = jnp.zeros((2, 1), jnp.int32)
    lg, cache = M.decode_step(cfg, params, cache, tok)
    assert lg.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
    assert int(cache["cache_len"][0]) == 1


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma-2b", "mamba2-370m",
                                  "recurrentgemma-9b"])
def test_decode_matches_teacher_forcing(arch):
    """Token-by-token decode logits == full forward logits (same prefix)."""
    cfg = get_config(arch).reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = M.init_params(cfg, RNG)
    toks = jax.random.randint(jax.random.key(7), (2, 9), 0,
                              cfg.vocab_size, jnp.int32)
    full_logits, _ = M.forward(cfg, params, dict(tokens=toks), remat=False)
    cache = M.init_cache(cfg, batch=2, max_seq=16)
    outs = []
    for t in range(9):
        lg, cache = M.decode_step(cfg, params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunked_matches_sequential():
    """Mamba-2 chunked SSD == naive sequential recurrence."""
    from repro.models.ssm import ssd_chunked

    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 40, 3, 4, 8
    xh = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B_ = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    C_ = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    y, h_last = ssd_chunked(xh, dt, A, B_, C_, chunk=8)

    # sequential reference
    hs = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        dA = np.exp(np.asarray(dt[:, t]) * np.asarray(A))      # [b, h]
        upd = np.einsum("bn,bh,bhp->bhpn", np.asarray(B_[:, t]),
                        np.asarray(dt[:, t]), np.asarray(xh[:, t]))
        hs = hs * dA[..., None, None] + upd
        ys[:, t] = np.einsum("bn,bhpn->bhp", np.asarray(C_[:, t]), hs)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_last), hs, rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_sequential():
    from repro.models.rglru import rg_lru, rg_lru_step

    rng = np.random.default_rng(1)
    w = 6
    params = dict(
        w_r=jnp.asarray(rng.normal(size=(w, w)) * 0.3, jnp.float32),
        w_i=jnp.asarray(rng.normal(size=(w, w)) * 0.3, jnp.float32),
        b_r=jnp.zeros(w), b_i=jnp.zeros(w),
        lam=jnp.full((w,), 0.5),
    )
    x = jnp.asarray(rng.normal(size=(2, 12, w)), jnp.float32)
    y, h_last = rg_lru(params, x)
    h = jnp.zeros((2, w))
    for t in range(12):
        yt, h = rg_lru_step(params, x[:, t:t + 1], h)
        np.testing.assert_allclose(np.asarray(yt[:, 0]), np.asarray(y[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_moe_matches_dense_reference_when_capacity_ample():
    from repro.models.moe import moe_ffn

    rng = np.random.default_rng(2)
    b, s, d, f, e, topk = 2, 6, 8, 16, 4, 2
    params = dict(
        wr=jnp.asarray(rng.normal(size=(d, e)), jnp.float32),
        wg=jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32),
        wu=jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32),
        wd=jnp.asarray(rng.normal(size=(e, f, d)) * 0.2, jnp.float32),
    )
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    y, aux = moe_ffn(params, x, num_experts=e, experts_per_token=topk,
                     capacity_factor=8.0)  # ample: nothing dropped

    # dense reference: every token through its top-k experts
    logits = np.asarray(x).reshape(-1, d) @ np.asarray(params["wr"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    top = np.argsort(-probs, axis=-1)[:, :topk]
    xt = np.asarray(x).reshape(-1, d)
    want = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        gates = probs[t, top[t]]
        gates = gates / gates.sum()
        for j, ex in enumerate(top[t]):
            hidden = (xt[t] @ np.asarray(params["wg"][ex]))
            hidden = hidden / (1 + np.exp(-hidden)) \
                * (xt[t] @ np.asarray(params["wu"][ex]))
            want[t] += gates[j] * (hidden @ np.asarray(params["wd"][ex]))
    np.testing.assert_allclose(np.asarray(y).reshape(-1, d), want,
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_overflow():
    from repro.models.moe import moe_ffn

    rng = np.random.default_rng(3)
    d, f, e = 4, 8, 2
    params = dict(
        wr=jnp.asarray(np.stack([np.ones(d), -np.ones(d)], 1), jnp.float32),
        wg=jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32),
        wu=jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32),
        wd=jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32),
    )
    # all tokens positive -> all route to expert 0 -> capacity saturates
    x = jnp.ones((1, 8, d), jnp.float32)
    y, _ = moe_ffn(params, x, num_experts=e, experts_per_token=1,
                   capacity_factor=0.5)
    outs = np.asarray(y)[0]
    n_zero = int((np.abs(outs).sum(-1) < 1e-9).sum())
    assert n_zero >= 4  # overflow tokens got dropped (residual carries them)
