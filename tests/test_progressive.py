"""End-to-end ADk-NNS: PGS/PDS/PSS vs the exact oracle, paper properties."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import diverse_search
from repro.core.baselines import div_astar_oracle, greedy_fixed, ip_greedy
from repro.core.similarity import pairwise_sim


def _queries(data, n=6, seed=3):
    rng = np.random.default_rng(seed)
    return [data[rng.integers(len(data))]
            + rng.normal(size=data.shape[1]).astype(np.float32) * 0.05
            for _ in range(n)]


@pytest.mark.parametrize("method", ["pgs", "pds", "pss", "greedy"])
def test_exact_k_and_diversification_condition(clustered_data, small_graph,
                                               method):
    eps = 0.0  # l2-sim: bans pairs closer than distance 1
    for q in _queries(clustered_data, 4):
        res = diverse_search(small_graph, q, k=5, eps=eps, method=method,
                             ef=10)
        ids = res.ids[res.ids >= 0]
        if method != "greedy":  # greedy with fixed L may return < k
            assert len(ids) == 5
        # diversification condition (paper Def. 1)
        sims = np.asarray(pairwise_sim(
            jnp.asarray(clustered_data[ids]), jnp.asarray(clustered_data[ids]),
            "l2"))
        off = sims[~np.eye(len(ids), dtype=bool)]
        assert np.all(off < eps + 1e-5)


def test_pss_matches_oracle(clustered_data, small_graph):
    agree = 0
    qs = _queries(clustered_data, 6)
    for q in qs:
        r = diverse_search(small_graph, q, k=5, eps=0.0, method="pss", ef=20)
        o = div_astar_oracle(clustered_data, "l2", q, 5, 0.0, X=256)
        agree += abs(r.total - o.total) < 1e-3
    assert agree >= 5  # beam-recall assumption can cost at most one query


def test_pss_beats_or_matches_greedy(clustered_data, small_graph):
    """The paper's core claim: PSS total >= greedy total (high div)."""
    wins = ties = losses = 0
    for q in _queries(clustered_data, 6, seed=11):
        g = diverse_search(small_graph, q, k=5, eps=0.0, method="greedy")
        p = diverse_search(small_graph, q, k=5, eps=0.0, method="pss", ef=20)
        if p.total > g.total + 1e-4:
            wins += 1
        elif p.total < g.total - 1e-4:
            losses += 1
        else:
            ties += 1
    assert losses == 0


def test_pds_certifies_on_easy_queries(clustered_data, small_graph):
    res = diverse_search(small_graph, clustered_data[5], k=3, eps=-3.0,
                         method="pds", ef=10)
    assert res.stats.certified
    assert (res.ids >= 0).all()


def test_cosine_metric_end_to_end(clustered_data, small_graph_cos):
    q = clustered_data[17]
    r = diverse_search(small_graph_cos, q, k=4, eps=0.9, method="pss", ef=15)
    o = div_astar_oracle(clustered_data, "cos", q, 4, 0.9, X=256)
    assert abs(r.total - o.total) < 5e-3


def test_ip_greedy_runs(clustered_data, small_graph_cos):
    res = ip_greedy(small_graph_cos, clustered_data[3], k=5, lam=0.7, L=64)
    assert (res.ids >= 0).sum() == 5


def test_greedy_missing_results_scored_zero(clustered_data, small_graph):
    # eps so strict nothing fits: greedy returns < k, missing slots = 0
    res = greedy_fixed(small_graph, clustered_data[0], k=5, eps=-50.0, L=32)
    n_found = (res.ids >= 0).sum()
    assert res.total == pytest.approx(res.scores[res.ids >= 0].sum())
    assert n_found <= 5
