"""Online mutable index (contract 15): delta flat-oracle parity, deletion
bitmap filtering, write backpressure, certificate soundness after writes,
and the epoch-swap straddle with in-flight multi-round lanes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import theorems
from repro.db import DiverseVectorDB, Query
from repro.index.mutable import DeltaFull, MutableIndex
from repro.kernels import ops as kops
from repro.serve.scheduler import RequestDeferred, SchedulerSaturated


@pytest.mark.parametrize("quantized", [None, "int8"])
def test_delta_bitmatches_flat_oracle(clustered_data, quantized):
    """Delta-segment scoring is a flat scan through kernels/ops: ids and
    scores bit-match ``batch_similarity`` over exactly the live tail rows
    (the int8 corpus still returns exact float scores — contract 13)."""
    x = clustered_data
    idx = MutableIndex(x, "l2", M=8, delta_capacity=64, background=False,
                       quantized=quantized)
    rng = np.random.default_rng(1)
    new = rng.normal(size=(7, x.shape[1])).astype(np.float32)
    ids = idx.upsert(new)
    assert np.array_equal(ids, np.arange(len(x), len(x) + 7))
    q = x[0] + np.float32(0.01)
    d_ids, d_sc = idx.score_delta(q)
    assert np.array_equal(d_ids, ids)
    ref = np.asarray(kops.batch_similarity(
        jnp.asarray(q), jnp.asarray(new), "l2"), np.float32)
    np.testing.assert_array_equal(d_sc, ref)
    # a deleted delta row leaves the live scan; the rest stay bit-equal
    idx.delete(ids[2:3])
    d_ids2, d_sc2 = idx.score_delta(q)
    keep = np.arange(7) != 2
    assert np.array_equal(d_ids2, ids[keep])
    np.testing.assert_array_equal(d_sc2, ref[keep])


def test_delete_validates_and_counts(clustered_data):
    idx = MutableIndex(clustered_data, "l2", M=8, background=False)
    with pytest.raises(KeyError):
        idx.delete([len(clustered_data)])
    with pytest.raises(KeyError):
        idx.delete([-1])
    assert idx.delete([3, 5]) == 2
    assert idx.delete([5, 7]) == 1      # 5 already tombstoned
    assert idx.live_count == len(clustered_data) - 3
    assert idx.deleted[[3, 5, 7]].all()


def test_delta_full_backpressure(clustered_data):
    """Past four delta capacities with the rebuild not yet swapped in,
    upsert raises ``DeltaFull`` instead of growing without bound."""
    idx = MutableIndex(clustered_data, "l2", M=8, delta_capacity=4,
                       background=False)
    rng = np.random.default_rng(2)
    for _ in range(16):
        idx.upsert(rng.normal(size=(1, clustered_data.shape[1]))
                   .astype(np.float32))
    assert idx.delta_count == 16
    with pytest.raises(DeltaFull):
        idx.upsert(rng.normal(size=(1, clustered_data.shape[1]))
                   .astype(np.float32))
    # the rebuild auto-requested at the first capacity crossing (n=604) is
    # ready; installing it keeps only the 12 rows written after that
    # snapshot in the delta
    assert idx.swap_ready()
    idx.install_swap()
    assert idx.delta_count == 12 and idx.epoch == 1
    idx.upsert(rng.normal(size=(1, clustered_data.shape[1]))
               .astype(np.float32))   # accepts writes again


def _submit(db, q, reqs):
    while True:
        try:
            reqs.append(db.scheduler.submit(q))
            return
        except (SchedulerSaturated, RequestDeferred):
            db.scheduler.pump()


def _poll(db, reqs, metas, frontiers):
    """Capture each completed request's harvest-time snapshot tag and
    merged frontier (per-lane slots are stable until the next harvest on
    that lane, so polling after every pump sees them first)."""
    for r in reqs:
        if (r.result is not None and r.lane is not None
                and id(r) not in metas):
            metas[id(r)] = db.backend.last_meta[r.lane]
            frontiers[id(r)] = db.backend.last_candidates[r.lane]


def test_epoch_swap_straddle_flat(clustered_data):
    """Contract 15 on the single-host engine: upserts/deletes interleave
    with in-flight multi-round lanes; the delta fills mid-run and the
    rebuilt graph swaps in between rounds. Every result must be valid
    against exactly one corpus version — served ids inside that version's
    row range, never tombstoned there — and every certified lane must pass
    an independent Theorem-2 recheck of its merged frontier."""
    x = clustered_data
    rng = np.random.default_rng(3)
    db = DiverseVectorDB(x, "l2", M=8, num_lanes=3, max_k=8, default_ef=12,
                         delta_capacity=8, background_rebuild=False,
                         prewarm=False)
    qs = (x[rng.integers(0, len(x), 12)]
          + 0.05 * rng.normal(size=(12, x.shape[1]))).astype(np.float32)
    # version -> (n_total, deleted bitmap) after every write we perform —
    # the only events that change the live set (swaps bump version only)
    snaps = {}

    def snap():
        snaps[db.index.version] = (db.index.n_total,
                                   db.index.deleted.copy())

    snap()
    reqs, metas, frontiers = [], {}, {}
    deleted_ever = set()
    for i in range(6):
        _submit(db, Query(qs[i], k=5, eps=0.0, ef=12), reqs)
    db.scheduler.pump()
    _poll(db, reqs, metas, frontiers)
    # writes land while lanes are mid-flight / requests are queued
    assert db.scheduler.inflight or db.scheduler.pending
    db.upsert(qs[:3] + np.float32(0.01))
    snap()
    deleted_ever.update((17, 23))
    db.delete([17, 23])
    snap()
    for i in range(6, 9):
        _submit(db, Query(qs[i], k=5, eps=0.0, ef=12), reqs)
    db.scheduler.pump()
    _poll(db, reqs, metas, frontiers)
    db.upsert(rng.normal(size=(6, x.shape[1]))
              .astype(np.float32))          # crosses capacity -> rebuild
    snap()
    assert db.index.swap_ready()            # inline rebuild is ready
    for i in range(9, 12):
        _submit(db, Query(qs[i], k=5, eps=0.0, ef=12), reqs)
    while any(r.result is None for r in reqs):
        db.scheduler.pump()
        _poll(db, reqs, metas, frontiers)
    assert db.backend.swaps == 1 and db.index.epoch == 1
    epochs = set()
    for r in reqs:
        meta = metas[id(r)]
        epochs.add(meta["epoch"])
        v = max(ver for ver in snaps if ver <= meta["version"])
        n_at, dele_at = snaps[v]
        ids = np.asarray(r.result.ids)
        ids = ids[ids >= 0]
        assert ids.size and (ids < n_at).all(), \
            f"result holds rows from a newer version than its tag {meta}"
        assert not dele_at[ids].any(), \
            f"tombstoned id served (version {v})"
        assert not deleted_ever.intersection(ids.tolist())
        if r.result.stats.certified:
            m_ids, m_sc = frontiers[id(r)][0], frontiers[id(r)][1]
            ok, sel = theorems.theorem2_recheck(
                db.index.float_view()[:n_at], "l2", m_ids, m_sc, 0.0, 5)
            assert ok and np.array_equal(
                np.asarray(sel), np.asarray(r.result.ids))
    assert epochs == {0, 1}, f"results straddle the swap: {epochs}"
    assert any(r.result.stats.certified for r in reqs)
    # post-swap service is clean: fresh searches certify on epoch 1
    r = db.search(Query(qs[0], k=5, eps=0.0, ef=12))
    assert 600 in r.ids.tolist()            # upserted near-dup of qs[0]


def test_swap_preserves_signature_budget(clustered_data):
    """The epoch swap re-notes compile signatures on the carried-over log
    instead of resetting it (compile-budget accounting survives swaps)."""
    db = DiverseVectorDB(clustered_data, "l2", M=8, num_lanes=2, max_k=8,
                         default_ef=12, delta_capacity=4,
                         background_rebuild=False, prewarm=False)
    db.search(clustered_data[0], k=3, eps=0.0)
    before = len(db.backend.signature_log.counts)
    db.upsert(np.zeros((4, clustered_data.shape[1]), np.float32))
    assert db.rebuild(wait=True) or db.backend.swaps  # swap installed
    log = db.backend.signature_log
    assert len(log.counts) >= before                  # log carried across
    assert any(sig[0] == "swap" for sig in log.counts)
    assert db.index.epoch >= 1


def test_certificates_reaudited_against_live_corpus(clustered_data):
    """After a write, a harvested certificate is only kept if the merged
    frontier (graph candidates + delta, bitmap-filtered) re-certifies via
    Theorem 2 — and the served set equals the audit's selection."""
    x = clustered_data
    db = DiverseVectorDB(x, "l2", M=8, num_lanes=2, max_k=8, default_ef=12,
                         prewarm=False)
    q = (x[7] + 0.02 * np.random.default_rng(5).normal(size=x.shape[1])
         ).astype(np.float32)
    base = db.search(Query(q, k=4, eps=0.0, ef=12))
    # upsert two near-duplicates of the query: they dominate the top of
    # the merged frontier, so the served set must include them
    new_ids = db.upsert(np.stack([q, q]) + np.float32(1e-3))
    res = db.search(Query(q, k=4, eps=0.0, ef=12))
    assert int(new_ids[0]) in res.ids.tolist()
    lane = None
    for ln, fr in enumerate(db.backend.last_candidates):
        if fr is not None and np.isin(res.ids, fr[0]).all():
            lane = ln
    assert lane is not None
    m_ids, m_sc = db.backend.last_candidates[lane][:2]
    ok, sel = theorems.theorem2_recheck(
        db.index.float_view(), "l2", m_ids, m_sc, 0.0, 4)
    assert ok == res.stats.certified
    if ok:
        assert np.array_equal(np.asarray(sel), np.asarray(res.ids))
    # the write changed the served set (the near-dup outranks base's top)
    assert int(new_ids[0]) not in np.asarray(base.ids).tolist()
