"""Multi-device tests (subprocesses: each needs its own XLA device count)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # compile-heavy; CI runs these in the slow job

SCRIPTS = os.path.join(os.path.dirname(__file__), "dist_scripts")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(name, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    out = subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, name)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"{name}:\n{out.stdout}\n{out.stderr}"
    assert "OK" in out.stdout


def test_sharded_search_4dev():
    _run("sharded_search_check.py")


def test_sharded_scheduler_4dev():
    """LaneScheduler over ShardedEngine: scratch-path budget parity +
    mid-run admission into freed mesh lanes, plus the resumable-beam
    acceptance checks (fewer cumulative expansions at the same final
    budget, oracle recall no worse, certificates independently re-checked
    via Theorem 2) — the script drives four engines plus the oracle, hence
    the longer timeout."""
    _run("sharded_scheduler_check.py", timeout=900)


def test_compressed_psum_4dev():
    _run("compression_check.py")


def test_ring_collective_matmul_4dev():
    _run("ring_matmul_check.py")


def test_elastic_reshard_8to4():
    _run("elastic_check.py")


def test_elastic_scale_straddle_4dev():
    """Contract 16 end-to-end: engine-direct lanes straddle a grow
    (2 -> 4) and a shrink (4 -> 2) mid-ladder and finish bit-matching the
    fixed final mesh at the same K-budget or independently Theorem-2
    re-checked (0 violations); a DiverseVectorDB with an ElasticPolicy
    performs one grow + one shrink under a burst, admits a queued request
    into a lane on the NEW mesh mid-run, and the frozen SignatureLog /
    resume-dispatch jit cache stay flat across the scale events."""
    _run("elastic_scale_check.py", timeout=900)


def test_small_mesh_dryrun_multifamily():
    _run("small_mesh_dryrun.py", timeout=560)


def test_mutable_epoch_swap_straddle_4dev():
    """Contract 15 on the mesh backend: upserts/deletes interleaved with
    in-flight multi-round lanes on a 4-shard DiverseVectorDB; the delta
    fills mid-run and the rebuilt sharded index swaps in between rounds.
    Every result must be valid against exactly one corpus version (no
    mixed-epoch sets, no tombstoned id served) and every certified lane's
    merged frontier passes an independent Theorem-2 recheck."""
    _run("mutable_straddle_check.py", timeout=900)
