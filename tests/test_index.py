"""Index builders: HNSW + fast KNN-graph; reachability/recall/determinism."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.beam_search import beam_search
from repro.index.flat import (_directed_reachable, build_knn_graph,
                              exact_topk)
from repro.index.hnsw import build_hnsw


def test_knng_fully_reachable(clustered_data):
    g = build_knn_graph(clustered_data, metric="l2", M=8)
    reached = _directed_reachable(np.asarray(g.neighbors), int(g.entry))
    assert reached.all()


def test_knng_deterministic(clustered_data):
    g1 = build_knn_graph(clustered_data[:300], metric="cos", M=6)
    g2 = build_knn_graph(clustered_data[:300], metric="cos", M=6)
    np.testing.assert_array_equal(np.asarray(g1.neighbors),
                                  np.asarray(g2.neighbors))


def test_hnsw_recall(clustered_data):
    x = clustered_data[:400]
    g = build_hnsw(x, metric="l2", M=8, ef_construction=60)
    rng = np.random.default_rng(0)
    recs = []
    for _ in range(8):
        q = x[rng.integers(len(x))] + \
            rng.normal(size=x.shape[1]).astype(np.float32) * 0.05
        ids, _ = beam_search(g, jnp.asarray(q), k=5, L=60)
        gt, _ = exact_topk(q[None], x, 5, "l2")
        recs.append(len(set(np.asarray(ids).tolist())
                        & set(gt[0].tolist())) / 5)
    assert np.mean(recs) >= 0.95


def test_hnsw_has_upper_levels(clustered_data):
    g = build_hnsw(clustered_data[:500], metric="l2", M=8,
                   ef_construction=40)
    assert g.num_upper_levels >= 1


def test_exact_topk_tie_break():
    x = np.zeros((5, 3), np.float32)
    ids, _ = exact_topk(np.zeros((1, 3), np.float32), x, 3, "ip")
    np.testing.assert_array_equal(ids[0], [0, 1, 2])


@pytest.mark.parametrize("metric", ["l2", "ip", "cos"])
def test_exact_topk_matches_numpy(metric, clustered_data):
    x = clustered_data[:200]
    q = clustered_data[201]
    ids, scores = exact_topk(q[None], x, 10, metric)
    from repro.core.similarity import query_sim
    sims = np.asarray(query_sim(jnp.asarray(q), jnp.asarray(x), metric))
    order = np.lexsort((np.arange(len(x)), -sims))[:10]
    np.testing.assert_array_equal(ids[0], order)
