"""Semantic result cache (serve/cache.py + the scheduler's probe-on-submit
path): exact-duplicate hits bit-equal to fresh searches, zero-duplicate
parity with the cache off, per-hit independent Theorem-2 soundness,
slack-derived probe thresholds, slack-aware LRU eviction, and the cost
model's hit-rate learning — contract 14 in docs/ARCHITECTURE.md: the
cache is a latency knob, never a results-soundness knob."""
import numpy as np
import pytest

from repro.core import theorems
from repro.core.pgs import DiverseResult
from repro.core.progressive import SearchStats
from repro.core.similarity import query_sim
from repro.index.flat import build_knn_graph
from repro.serve.cache import SemanticResultCache
from repro.serve.scheduler import LaneScheduler


@pytest.fixture(scope="module")
def graph_and_queries():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(12, 24)) * 2.0
    x = (centers[rng.integers(0, 12, 600)]
         + rng.normal(size=(600, 24)) * 0.3).astype(np.float32)
    graph = build_knn_graph(x, metric="l2", M=8)
    qs = (x[rng.integers(0, 600, 10)]
          + rng.normal(size=(10, 24)).astype(np.float32) * 0.05)
    return graph, qs.astype(np.float32)


MIX_KS = [5, 3, 5, 3, 5, 3, 5, 3, 5, 3]
MIX_EPS = [0.0, -0.5, 0.0, -0.5, 0.0, -0.5, 0.0, -0.5, 0.0, -0.5]


def _certified_result(k: int = 3) -> DiverseResult:
    stats = SearchStats(expansions=10, growths=0, search_calls=1,
                        div_calls=1, certified=True, exhausted=False,
                        K_final=k)
    ids = np.arange(k, dtype=np.int32)
    sc = np.linspace(1.0, 0.5, k).astype(np.float32)
    return DiverseResult(ids, sc, float(sc.sum()), stats)


def _oracle_recheck(graph, entry, q):
    """Independent per-query recheck of a cached entry's frontier: oracle
    scoring (core.similarity, not the cache's kernel path) + theorems."""
    valid = entry.cand_ids >= 0
    vecs = np.asarray(graph.vectors)[np.maximum(entry.cand_ids, 0)]
    sc = np.asarray(query_sim(q, vecs, graph.metric), np.float32)
    sc = np.where(valid, sc, -np.inf).astype(np.float32)
    order = np.argsort(-sc, kind="stable")
    return theorems.theorem2_recheck(
        np.asarray(graph.vectors), graph.metric, entry.cand_ids[order],
        sc[order], entry.eps, entry.k)


# ------------------------------------------------- scheduler integration ----

def test_exact_duplicate_hits_bit_equal(graph_and_queries):
    """A repeated trace is served from cache (no lane) with results
    bit-identical to the cold pass."""
    graph, qs = graph_and_queries
    sched = LaneScheduler(graph, num_lanes=4, max_k=16, cache_size=32)
    cold = sched.run(qs, MIX_KS, MIX_EPS)
    admitted = sched.cache.admitted
    assert admitted > 0 and sched.total_cache_hits == 0
    warm = sched.run(qs, MIX_KS, MIX_EPS)
    assert sched.total_cache_hits == admitted     # every cached query hits
    hits = [r for r in sched.completed if r.cache_hit]
    assert len(hits) == admitted
    for r in hits:
        assert r.t_admit == r.t_done              # completed at submit
        assert r.result.stats.certified           # re-proved, never inherited
    for a, b in zip(cold, warm):
        assert np.array_equal(a.ids, b.ids)
    st = sched.latency_stats()
    assert st["cache_hits"] == admitted
    assert st["cache_hit_rate"] == pytest.approx(admitted / 20)
    assert st["hit_p50_latency"] >= 0.0 and st["hit_p99_latency"] >= 0.0
    assert st["cache"]["revalidation_failures"] == 0
    assert st["cache"]["size"] == admitted


def test_zero_duplicate_parity_cache_invisible(graph_and_queries):
    """On a trace with no duplicates the cache must be bit-invisible:
    zero hits and identical results vs a cache-off scheduler."""
    graph, qs = graph_and_queries
    plain = LaneScheduler(graph, num_lanes=4, max_k=16)
    cached = LaneScheduler(graph, num_lanes=4, max_k=16, cache_size=32)
    ra = plain.run(qs, MIX_KS, MIX_EPS)
    rb = cached.run(qs, MIX_KS, MIX_EPS)
    assert cached.total_cache_hits == 0
    for a, b in zip(ra, rb):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.scores, b.scores)
        assert a.stats.certified == b.stats.certified


def test_every_hit_survives_independent_recheck(graph_and_queries):
    """Soundness: each served hit's set must re-certify under an
    *independent* per-query oracle recheck against the hit's own query."""
    graph, qs = graph_and_queries
    rng = np.random.default_rng(5)
    sched = LaneScheduler(graph, num_lanes=4, max_k=16, cache_size=32)
    sched.run(qs, MIX_KS, MIX_EPS)
    # replay with tiny perturbations: near-hits, not just exact duplicates
    jitter = rng.normal(size=qs.shape).astype(np.float32) * 1e-3
    sched.run(qs + jitter, MIX_KS, MIX_EPS)
    hits = [r for r in sched.completed if r.cache_hit]
    assert hits, "fixture must produce at least one near-hit"
    for r in hits:
        cert, sel = _oracle_recheck(graph, r.cache_entry, r.q)
        assert cert, "served hit failed its independent recheck"
        assert set(map(int, sel[sel >= 0])) \
            == set(map(int, r.result.ids[r.result.ids >= 0]))


def test_near_hit_threshold_boundary(graph_and_queries):
    """A probe within the slack-derived drift threshold hits (and still
    revalidates); one beyond it misses without attempting revalidation."""
    graph, qs = graph_and_queries
    rng = np.random.default_rng(11)
    sched = LaneScheduler(graph, num_lanes=2, max_k=16, cache_size=8)
    sched.run(qs[:1], 5, 0.0)
    cache = sched.cache
    assert len(cache) == 1
    entry = next(iter(cache._entries.values()))
    assert 0.0 < entry.threshold < np.inf

    def probe_at(dist):
        delta = rng.normal(size=qs.shape[1])
        delta = (delta / np.linalg.norm(delta) * dist).astype(np.float32)
        return cache.lookup(entry.q + delta, entry.k, entry.eps,
                            entry.method)

    inside = probe_at(entry.threshold * 0.5)
    assert inside is not None
    result, hit_entry = inside
    assert hit_entry is entry and result.stats.certified
    fails_before = cache.revalidation_failures
    assert probe_at(entry.threshold * 1.5) is None
    assert cache.revalidation_failures == fails_before  # filtered at probe


# ------------------------------------------------------ cache unit tests ----

def _tiny_cache(capacity=2, **kw):
    rng = np.random.default_rng(2)
    X = rng.normal(size=(16, 4)).astype(np.float32)
    return SemanticResultCache(X, "l2", capacity, **kw), rng


def test_uncertified_or_frontierless_results_rejected():
    cache, rng = _tiny_cache()
    q = rng.normal(size=4).astype(np.float32)
    cand = np.array([0, 1, 2, 3], np.int32)
    sc = np.array([1.0, 0.9, 0.8, 0.7], np.float32)
    res = _certified_result()
    res.stats.certified = False
    assert not cache.admit_request(q, 3, 0.0, "pss", res, cand, sc,
                                   slack=1.0)
    good = _certified_result()
    assert not cache.admit_request(q, 3, 0.0, "pss", good, None, None,
                                   slack=1.0)
    assert not cache.admit_request(      # all-padding frontier
        q, 3, 0.0, "pss", good, np.full(4, -1, np.int32), sc, slack=1.0)
    assert not cache.admit_request(q, 3, 0.0, "pss", good, cand, sc,
                                   slack=0.0)   # non-positive slack
    assert len(cache) == 0 and cache.rejected == 4 and cache.admitted == 0


def test_slack_aware_lru_eviction():
    """LRU restricted to residents no more reusable than the newcomer: a
    narrow-slack newcomer never displaces wide-slack residents."""
    cache, rng = _tiny_cache(capacity=2)
    cand = np.array([0, 1, 2, 3], np.int32)
    sc = np.array([1.0, 0.9, 0.8, 0.7], np.float32)

    def admit(slack):
        q = rng.normal(size=4).astype(np.float32)
        return cache.admit_request(q, 3, 0.0, "pss", _certified_result(),
                                   cand, sc, slack=slack)

    assert admit(2.0)                       # A: threshold 2/(2*3) = 1/3
    assert admit(4.0)                       # B: threshold 2/3
    assert len(cache) == 2
    # C is strictly less reusable than both residents: declined, no churn
    assert not admit(0.4)
    assert len(cache) == 2 and cache.evicted == 0 and cache.rejected == 1
    assert sorted(e.slack for e in cache._entries.values()) == [2.0, 4.0]
    # D's threshold covers A's: the LRU eligible resident (A) is evicted
    assert admit(3.0)
    assert cache.evicted == 1
    assert sorted(e.slack for e in cache._entries.values()) == [3.0, 4.0]


def test_k1_infinite_slack_capped_by_max_drift():
    """k=1 certificates have infinite slack; max_drift bounds the probe."""
    cache, rng = _tiny_cache(capacity=4, max_drift=0.05)
    q = rng.normal(size=4).astype(np.float32)
    sc = np.asarray(query_sim(q, cache.vectors, "l2"), np.float32)
    order = np.argsort(-sc, kind="stable")[:6].astype(np.int32)
    stats = SearchStats(expansions=1, growths=0, search_calls=1, div_calls=1,
                        certified=True, exhausted=False, K_final=6)
    res = DiverseResult(order[:1], sc[order[:1]], float(sc[order[0]]), stats)
    assert cache.admit_request(q, 1, 0.0, "pss", res, order, sc[order])
    entry = next(iter(cache._entries.values()))
    assert entry.threshold == np.inf        # the stored proven bound
    hit = cache.lookup(q, 1, 0.0, "pss")
    assert hit is not None and int(hit[0].ids[0]) == int(order[0])
    delta = rng.normal(size=4)
    delta = (delta / np.linalg.norm(delta) * 0.2).astype(np.float32)
    assert cache.lookup(q + delta, 1, 0.0, "pss") is None   # beyond cap


def test_key_mismatch_never_hits():
    """A hit must share (k, eps, method) exactly — Definition 1's
    query-owned parameters are part of the identity of a result."""
    cache, rng = _tiny_cache(capacity=4)
    q = rng.normal(size=4).astype(np.float32)
    cand = np.array([0, 1, 2, 3], np.int32)
    sc = np.array([1.0, 0.9, 0.8, 0.7], np.float32)
    assert cache.admit_request(q, 3, 0.5, "pss", _certified_result(),
                               cand, sc, slack=10.0)
    assert cache.lookup(q, 2, 0.5, "pss") is None
    assert cache.lookup(q, 3, 0.6, "pss") is None
    assert cache.lookup(q, 3, 0.5, "pds") is None
    assert cache.lookup(q, 3, 0.5, "pss") is not None


def test_for_backend_refuses_missing_corpus():
    class Bare:
        pass
    with pytest.raises(ValueError, match="float corpus"):
        SemanticResultCache.for_backend(Bare())


def test_cost_model_learns_hit_rate(graph_and_queries):
    """The scheduler feeds every probe outcome to the cost model; warm
    traffic raises the learned hit probability and discounts *offered*
    (pre-admission) pricing, never admitted pricing."""
    graph, qs = graph_and_queries
    sched = LaneScheduler(graph, num_lanes=4, max_k=16, cache_size=32)
    sched.run(qs, 5, 0.0)
    cm = sched.cost_model
    assert cm.predict_hit_rate(5, 0.0, "pss") == 0.0
    sched.run(qs, 5, 0.0)
    rate = cm.predict_hit_rate(5, 0.0, "pss")
    assert rate > 0.0
    full = cm.predict_expansions(5, 0.0, "pss")
    disc = cm.predict_expansions(5, 0.0, "pss", offered=True)
    assert disc == pytest.approx(full * (1.0 - rate))
