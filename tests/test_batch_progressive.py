"""Batched progressive engine: exact per-lane parity with the per-query
drivers, bucketed capacity growth, lane recycling, and certificates."""
import numpy as np
import pytest

from repro.core.batch_progressive import (BatchProgressiveDriver,
                                          ProgressiveEngine,
                                          SignatureBudgetExceeded, batch_pds,
                                          batch_pgs, batch_pss)
from repro.core.pds import pds
from repro.core.pgs import pgs
from repro.core.progressive import ProgressiveDriver
from repro.core.pss import pss
from repro.index.flat import build_knn_graph


def _normalize(v):
    return (v / np.maximum(np.linalg.norm(v, axis=-1, keepdims=True),
                           1e-9)).astype(np.float32)


def _queries(x, num, seed=3, noise=0.05, unit=False):
    rng = np.random.default_rng(seed)
    qs = (x[rng.integers(0, x.shape[0], num)]
          + rng.normal(size=(num, x.shape[1])).astype(np.float32) * noise)
    return _normalize(qs) if unit else qs.astype(np.float32)


@pytest.fixture(scope="module")
def big_graph():
    """~10k-point cosine-space graph with mild clustering."""
    rng = np.random.default_rng(5)
    n, d = 10_000, 32
    centers = rng.normal(size=(64, d)) * 0.25
    x = _normalize(centers[rng.integers(0, 64, n)]
                   + rng.normal(size=(n, d)).astype(np.float32))
    return build_knn_graph(x, metric="cos", M=8), x


def _assert_lane_matches(r, bres, i):
    np.testing.assert_array_equal(np.asarray(r.ids), bres.ids[i])
    np.testing.assert_array_equal(np.asarray(r.scores), bres.scores[i])
    assert r.stats.certified == bool(bres.stats.certified[i])
    assert r.stats.exhausted == bool(bres.stats.exhausted[i])
    assert r.stats.K_final == int(bres.stats.K_final[i])
    assert r.stats.growths == int(bres.stats.growths[i])


# ------------------------------------------------------- 10k parity (slow) --

@pytest.mark.slow
@pytest.mark.parametrize("eps", [0.5, 0.8])
@pytest.mark.parametrize("k", [5, 10])
def test_batch_pss_matches_per_query_10k(big_graph, eps, k):
    graph, x = big_graph
    qs = _queries(x, 6, unit=True)
    bres = batch_pss(graph, qs, k, eps, ef=10)
    for i in range(qs.shape[0]):
        _assert_lane_matches(pss(graph, qs[i], k, eps, ef=10), bres, i)


@pytest.mark.slow
@pytest.mark.parametrize("eps", [0.5, 0.8])
def test_batch_pgs_matches_per_query_10k(big_graph, eps):
    graph, x = big_graph
    qs = _queries(x, 6, unit=True)
    bres, _, K = batch_pgs(graph, qs, 5, eps, ef=10)
    for i in range(qs.shape[0]):
        r, _, K_i = pgs(graph, qs[i], 5, eps, ef=10)
        np.testing.assert_array_equal(np.asarray(r.ids), bres.ids[i])
        np.testing.assert_array_equal(np.asarray(r.scores), bres.scores[i])
        assert K_i == int(K[i])


@pytest.mark.slow
@pytest.mark.parametrize("eps", [0.5, 0.8])
def test_batch_pds_matches_per_query_10k(big_graph, eps):
    graph, x = big_graph
    qs = _queries(x, 6, unit=True)
    # max_K bounds the Theorem-1 blow-up at high diversification (the paper's
    # N/A cells) identically in both drivers, exercising the exhausted path
    bres = batch_pds(graph, qs, 5, eps, ef=10, max_K=2000)
    for i in range(qs.shape[0]):
        r = pds(graph, qs[i], 5, eps, ef=10, max_K=2000)
        np.testing.assert_array_equal(np.asarray(r.ids), bres.ids[i])
        np.testing.assert_array_equal(np.asarray(r.scores), bres.scores[i])
        assert r.stats.certified == bool(bres.stats.certified[i])
        assert r.stats.exhausted == bool(bres.stats.exhausted[i])
        assert r.stats.K_final == int(bres.stats.K_final[i])


# ------------------------------------------------- lane recycling (slow) ----

def _serve_continuously(graph, qs, ks, epss, num_lanes, ef=10, max_k=10):
    """Drive the engine directly: admit whenever a lane frees (so later
    queries land on recycled lanes), return per-query results."""
    eng = ProgressiveEngine(graph, num_lanes=num_lanes, max_k=max_k)
    pending = list(range(len(qs)))
    inflight, results = {}, {}
    while pending or inflight:
        for lane in eng.free_lanes():
            if not pending:
                break
            qi = pending.pop(0)
            eng.admit(int(lane), qs[qi], k=int(ks[qi]), eps=float(epss[qi]),
                      ef=ef)
            inflight[int(lane)] = qi
        for lane in eng.step():
            results[inflight.pop(lane)] = eng.result(lane)
    return [results[i] for i in range(len(qs))], eng


@pytest.mark.slow
@pytest.mark.parametrize("eps", [0.5, 0.8])
@pytest.mark.parametrize("k", [5, 10])
def test_lane_recycle_parity_10k(big_graph, eps, k):
    """A certified lane re-admitted with a new query must be bit-identical
    to a fresh solo driver for that query — 2 lanes serving 4 queries means
    every later query runs on a recycled slot."""
    graph, x = big_graph
    qs = _queries(x, 4, unit=True)
    results, eng = _serve_continuously(graph, qs, np.full(4, k),
                                       np.full(4, eps), num_lanes=2)
    assert eng.driver.B == 2  # queries 2..3 necessarily recycled a lane
    for i, r in enumerate(results):
        solo = pss(graph, qs[i], k, eps, ef=10)
        np.testing.assert_array_equal(np.asarray(solo.ids), r.ids)
        np.testing.assert_array_equal(np.asarray(solo.scores), r.scores)
        assert solo.stats.certified == r.stats.certified
        assert solo.stats.exhausted == r.stats.exhausted
        assert solo.stats.K_final == r.stats.K_final
        assert solo.stats.growths == r.stats.growths
        assert solo.stats.search_calls == r.stats.search_calls
        assert solo.stats.div_calls == r.stats.div_calls


# ------------------------------------------------ small-graph parity (fast) --

@pytest.fixture(scope="module")
def small_graph_l2():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(12, 24)) * 2.0
    x = (centers[rng.integers(0, 12, 600)]
         + rng.normal(size=(600, 24)) * 0.3).astype(np.float32)
    return build_knn_graph(x, metric="l2", M=8), x


def test_batch_pss_small_parity(small_graph_l2):
    graph, x = small_graph_l2
    qs = _queries(x, 6)
    bres = batch_pss(graph, qs, 5, 0.0, ef=10)
    for i in range(qs.shape[0]):
        _assert_lane_matches(pss(graph, qs[i], 5, 0.0, ef=10), bres, i)


def test_batch_pds_small_parity(small_graph_l2):
    graph, x = small_graph_l2
    qs = _queries(x, 5)
    bres = batch_pds(graph, qs, 5, 0.0, ef=10)
    for i in range(qs.shape[0]):
        r = pds(graph, qs[i], 5, 0.0, ef=10)
        np.testing.assert_array_equal(np.asarray(r.ids), bres.ids[i])
        np.testing.assert_array_equal(np.asarray(r.scores), bres.scores[i])
        assert r.stats.certified == bool(bres.stats.certified[i])
        assert r.stats.K_final == int(bres.stats.K_final[i])


def test_lane_recycle_mixed_k_eps_parity(small_graph_l2):
    """Continuous serving over 2 lanes with per-request (k, eps): every
    recycled lane must reproduce a fresh solo pss driver bit-for-bit."""
    graph, x = small_graph_l2
    qs = _queries(x, 6, seed=7)
    ks = np.array([5, 3, 4, 5, 3, 4])
    epss = np.array([0.0, -0.5, 0.0, -0.5, 0.0, -0.5])
    results, _ = _serve_continuously(graph, qs, ks, epss, num_lanes=2,
                                     max_k=8)
    for i, r in enumerate(results):
        solo = pss(graph, qs[i], int(ks[i]), float(epss[i]), ef=10)
        np.testing.assert_array_equal(np.asarray(solo.ids), r.ids)
        np.testing.assert_array_equal(np.asarray(solo.scores), r.scores)
        assert solo.stats.certified == r.stats.certified
        assert solo.stats.K_final == r.stats.K_final
        assert solo.stats.search_calls == r.stats.search_calls


def test_signature_budget_cap(small_graph_l2):
    graph, x = small_graph_l2
    qs = _queries(x, 2)
    driver = BatchProgressiveDriver(graph, qs, ef=10, k=5, capacity0=64,
                                    max_signatures=2)
    driver.ensure_stable(np.full(2, 40))   # "init" + "search" fill the budget
    with pytest.raises(SignatureBudgetExceeded):
        driver._grow_lanes(np.array([200, 200]), np.ones(2, bool))


def test_batch_pss_certificates_fire(small_graph_l2):
    graph, x = small_graph_l2
    qs = _queries(x, 4)
    bres = batch_pss(graph, qs, 3, -3.0, ef=10)
    assert bres.stats.certified.all()
    assert (bres.ids >= 0).all()


# --------------------------------------------------------- growth coverage --

def test_bucketed_growth_exact_rebuild(small_graph_l2):
    """Lanes growing to different targets are rebuilt per power-of-two
    bucket; each lane's queue must equal a solo driver grown the same way."""
    graph, x = small_graph_l2
    qs = _queries(x, 3)
    driver = BatchProgressiveDriver(graph, qs, ef=10, k=5, capacity0=64)
    driver.ensure_stable(np.full(3, 40))
    driver._grow_lanes(np.array([100, 300, 700]), np.ones(3, bool))
    assert driver.caps.tolist() == [128, 512, 1024]
    assert (driver.stats.growths == 1).all()
    for i, tgt in enumerate([100, 300, 700]):
        solo = ProgressiveDriver(graph, qs[i], 10, 5, capacity0=64)
        solo.ensure_stable(40)
        solo._grow_to(tgt)
        assert solo.capacity == driver.caps[i]
        np.testing.assert_array_equal(
            np.asarray(driver.state.queue.ids[i][:solo.capacity]),
            np.asarray(solo.state.queue.ids))
        np.testing.assert_array_equal(
            np.asarray(driver.state.queue.scores[i][:solo.capacity]),
            np.asarray(solo.state.queue.scores))
        np.testing.assert_array_equal(
            np.asarray(driver.state.queue.stable[i][:solo.capacity]),
            np.asarray(solo.state.queue.stable))


def test_growth_path_parity(small_graph_l2):
    """A small initial capacity forces at least one rebuild inside the
    engine loop; results must still match solo drivers started the same."""
    graph, x = small_graph_l2
    qs = _queries(x, 4, seed=11)
    bdriver = BatchProgressiveDriver(graph, qs, ef=10, k=5, capacity0=32)
    bres, bdriver, K = batch_pgs(graph, qs, 5, 0.0, ef=10, driver=bdriver)
    assert (bdriver.stats.growths >= 1).all()
    for i in range(qs.shape[0]):
        solo = ProgressiveDriver(graph, qs[i], 10, 5, capacity0=32)
        r, solo, K_i = pgs(graph, qs[i], 5, 0.0, ef=10, driver=solo)
        np.testing.assert_array_equal(np.asarray(r.ids), bres.ids[i])
        np.testing.assert_array_equal(np.asarray(r.scores), bres.scores[i])
        assert solo.stats.growths == int(bdriver.stats.growths[i])
        assert K_i == int(K[i])
