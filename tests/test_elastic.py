"""Elastic resharding properties (contract 16), host-side.

Everything here runs on a single device: ``reshard_tree``/``reshard_index``
accept a bare ``shards=`` count and ``migrate_sharded_state`` is pure host
numpy when no mesh is given, so the bit-exactness properties of the scale
path are checked without a multi-device mesh. The in-flight straddle runs
(lanes migrated mid-ladder across a real grow/shrink) live in
``tests/dist_scripts/elastic_scale_check.py`` with forced host devices.
"""
import types

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.distributed.elastic import plan, reshard_tree
from repro.sharded_search.search import (ShardedSearchState,
                                         build_sharded_index,
                                         migrate_sharded_state,
                                         reshard_index)

_INDEX_FIELDS = ("vectors", "neighbors", "entries", "bases", "codes",
                 "scales", "codebooks")


def _assert_index_equal(a, b):
    assert (a.metric, a.scheme, a.scale_rows) == (b.metric, b.scheme,
                                                  b.scale_rows)
    for f in _INDEX_FIELDS:
        x, y = getattr(a, f), getattr(b, f)
        assert (x is None) == (y is None), f
        if x is not None:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f)


def _corpus(seed, n=128, d=8):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _rand_state(rng, p, B, C, ns) -> ShardedSearchState:
    """A synthetic in-flight state obeying the queue conventions: per
    (shard, lane) queue canonically sorted (score desc, global id asc),
    empty slots (-1, -inf, True)."""
    ids = np.full((p, B, C), -1, np.int32)
    scores = np.full((p, B, C), -np.inf, np.float32)
    stable = np.ones((p, B, C), bool)
    for s in range(p):
        for b in range(B):
            m = int(rng.integers(0, min(C, ns) + 1))
            loc = rng.choice(ns, size=m, replace=False)
            sc = rng.normal(size=m).astype(np.float32)
            order = np.lexsort((loc + s * ns, -sc))
            ids[s, b, :m] = loc[order].astype(np.int32)
            scores[s, b, :m] = sc[order]
            stable[s, b, :m] = rng.random(m) < 0.5
    return ShardedSearchState(
        ids=ids, scores=scores, stable=stable,
        visited=rng.random((p, B, ns)) < 0.3,
        steps=rng.integers(0, 50, size=(p, B)).astype(np.int32))


# -- reshard_tree / reshard_index ------------------------------------------


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_reshard_tree_index_roundtrip_bit_identical(seed):
    """4 -> 8 -> 4 restores every array field of the corpus exactly, for
    float, int8, and pq corpora alike (global ids never move; graphs are
    rebuilt deterministically from the same rows)."""
    x = _corpus(seed)
    for quantized in (None, "int8", "pq"):
        idx4 = build_sharded_index(x, 4, "l2", M=4, quantized=quantized,
                                   scale_rows=2, pq_m=4)
        av = x if quantized else None
        idx8 = reshard_tree(idx4, shards=8, all_vectors=av)
        assert idx8.num_shards == 8 and idx8.shard_size == 16
        back = reshard_tree(idx8, shards=4, all_vectors=av)
        _assert_index_equal(idx4, back)


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_reshard_quantized_codes_scales_exact(seed):
    """Quantized reshard is a pure re-blocking: the flattened code rows and
    scale blocks are bytewise-identical — no requantization ever happens on
    a scale event."""
    x = _corpus(seed)
    i8 = build_sharded_index(x, 4, "l2", M=4, quantized="int8", scale_rows=2)
    i8r = reshard_index(i8, 8, x)
    np.testing.assert_array_equal(
        np.asarray(i8.codes).reshape(len(x), -1),
        np.asarray(i8r.codes).reshape(len(x), -1))
    np.testing.assert_array_equal(np.asarray(i8.scales).reshape(-1),
                                  np.asarray(i8r.scales).reshape(-1))
    pq = build_sharded_index(x, 4, "l2", M=4, quantized="pq", pq_m=4)
    pqr = reshard_index(pq, 2, x)
    np.testing.assert_array_equal(
        np.asarray(pq.codes).reshape(len(x), -1),
        np.asarray(pqr.codes).reshape(len(x), -1))
    np.testing.assert_array_equal(np.asarray(pq.codebooks),
                                  np.asarray(pqr.codebooks))


def test_reshard_index_validation():
    x = _corpus(0, n=64)
    idx = build_sharded_index(x, 4, "l2", M=4)
    with pytest.raises(ValueError):
        reshard_index(idx, 3, x)                    # not a power of two
    with pytest.raises(ValueError):
        reshard_index(idx, 128, x)                  # rows don't divide
    i8 = build_sharded_index(x, 4, "l2", M=4, quantized="int8",
                             scale_rows=16)
    with pytest.raises(ValueError):
        reshard_index(i8, 8, x)                     # scale blocks would split
    with pytest.raises(ValueError):
        reshard_index(i8, 2, None)                  # quantized needs floats
    assert reshard_index(idx, 4, x) is idx          # same count: no-op
    with pytest.raises(ValueError):
        reshard_tree(idx)                           # needs mesh or shards=


# -- plan ------------------------------------------------------------------


def _mesh_stub(sizes: dict):
    return types.SimpleNamespace(
        axis_names=tuple(sizes), devices=np.zeros(tuple(sizes.values())))


@given(st.integers(0, 3), st.integers(0, 3), st.integers(0, 2),
       st.integers(0, 2))
@settings(max_examples=30, deadline=None)
def test_plan_inverses(d0, d1, m0, m1):
    a = _mesh_stub({"data": 2 ** d0, "model": 2 ** m0})
    b = _mesh_stub({"data": 2 ** d1, "model": 2 ** m1})
    fwd, rev = plan(a, b), plan(b, a)
    assert fwd["old"] == rev["new"] and fwd["new"] == rev["old"]
    assert fwd["dp_change"] == 2.0 ** (d1 - d0)
    assert fwd["tp_change"] == 2.0 ** (m1 - m0)
    for ax, r in fwd["axis_changes"].items():
        assert rev["axis_changes"][ax] == pytest.approx(1.0 / r)


# -- migrate_sharded_state -------------------------------------------------


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_migrate_state_roundtrip_bit_identical(seed):
    """Grow 4 -> 8 then shrink back restores the state exactly: queues
    re-bucket by global id and re-sort canonically, visited bits follow
    their rows, per-lane step totals ride the split/merge."""
    rng = np.random.default_rng(seed)
    st4 = _rand_state(rng, p=4, B=3, C=8, ns=32)
    st8 = migrate_sharded_state(st4, 8)
    back = migrate_sharded_state(st8, 4)
    for name in ShardedSearchState._fields:
        np.testing.assert_array_equal(np.asarray(getattr(st4, name)),
                                      np.asarray(getattr(back, name)),
                                      err_msg=name)
    # the lane's cumulative budget baseline is shard-summed expansions —
    # preserved through both directions, so resume_search's relative
    # max_steps stays exact for migrated lanes
    tot = np.asarray(st4.steps).sum(axis=0)
    np.testing.assert_array_equal(np.asarray(st8.steps).sum(axis=0), tot)
    np.testing.assert_array_equal(np.asarray(back.steps).sum(axis=0), tot)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_migrate_state_preserves_entries_and_visited(seed):
    """Every (global id, score, stable) queue entry and every visited
    global row survives migration verbatim, per lane."""
    rng = np.random.default_rng(seed)
    p, ns = 4, 32
    state = _rand_state(rng, p=p, B=2, C=8, ns=ns)
    for p_new in (8, 2):
        # a shrink merges queues: size the target like callers do
        cap = 8 * max(1, p // p_new)
        out = migrate_sharded_state(state, p_new, capacity=cap)
        ns_new = p * ns // p_new
        for b in range(2):
            def entries(ids, sc, stbl, width):
                es = set()
                for s in range(ids.shape[0]):
                    for c in range(ids.shape[2]):
                        i = int(ids[s, b, c])
                        if i >= 0:
                            es.add((i + s * width, float(sc[s, b, c]),
                                    bool(stbl[s, b, c])))
                return es
            assert (entries(np.asarray(state.ids), np.asarray(state.scores),
                            np.asarray(state.stable), ns)
                    == entries(np.asarray(out.ids), np.asarray(out.scores),
                               np.asarray(out.stable), ns_new))
            old_v = np.asarray(state.visited)[:, b, :].reshape(-1)
            new_v = np.asarray(out.visited)[:, b, :].reshape(-1)
            np.testing.assert_array_equal(old_v, new_v)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_migrate_state_lane_scaling(seed):
    """Serving capacity follows the mesh: ``num_lanes`` pads new lanes
    empty on a grow and keeps the surviving prefix verbatim on a shrink
    (the engine only ever drops LANE_FREE tails)."""
    rng = np.random.default_rng(seed)
    state = _rand_state(rng, p=2, B=2, C=8, ns=32)
    wide = migrate_sharded_state(state, 4, num_lanes=4)
    assert np.asarray(wide.ids).shape[1] == 4
    # appended lanes are empty/unseeded
    np.testing.assert_array_equal(np.asarray(wide.ids)[:, 2:], -1)
    assert not np.asarray(wide.visited)[:, 2:].any()
    np.testing.assert_array_equal(np.asarray(wide.steps)[:, 2:], 0)
    # surviving lanes round-trip bit-identically through the lane shrink
    back = migrate_sharded_state(wide, 2, capacity=8, num_lanes=2)
    for name in ShardedSearchState._fields:
        np.testing.assert_array_equal(np.asarray(getattr(state, name)),
                                      np.asarray(getattr(back, name)),
                                      err_msg=name)


def test_migrate_state_capacity_overflow_raises():
    """A shrink that would merge more candidates than the target queue
    holds must refuse loudly (silent truncation would void the widening
    contract), and succeeds once the capacity is sized up."""
    rng = np.random.default_rng(0)
    p, B, C, ns = 4, 2, 8, 64
    ids = np.zeros((p, B, C), np.int32)
    scores = np.zeros((p, B, C), np.float32)
    for s in range(p):
        for b in range(B):
            loc = rng.choice(ns, size=C, replace=False)
            sc = rng.normal(size=C).astype(np.float32)
            order = np.lexsort((loc + s * ns, -sc))
            ids[s, b] = loc[order]
            scores[s, b] = sc[order]
    full = ShardedSearchState(
        ids=ids, scores=scores, stable=np.ones((p, B, C), bool),
        visited=np.zeros((p, B, ns), bool),
        steps=np.zeros((p, B), np.int32))
    with pytest.raises(ValueError, match="capacity"):
        migrate_sharded_state(full, 2)
    out = migrate_sharded_state(full, 2, capacity=16)
    assert out.ids.shape == (2, 2, 16)


# -- protocol / facade gates ----------------------------------------------


def test_rescalable_protocol_detection():
    """The scheduler's elastic trigger feature-detects RescalableBackend:
    a single-host ProgressiveEngine (wrapped or not) must NOT satisfy it,
    and asking for elastic= over one is a loud constructor error."""
    from repro.core.backend import RescalableBackend
    from repro.core.batch_progressive import ProgressiveEngine
    from repro.index.flat import build_knn_graph
    from repro.index.mutable import MutableBackend, MutableIndex
    from repro.serve.scheduler import LaneScheduler

    x = _corpus(1, n=64)
    eng = ProgressiveEngine(build_knn_graph(x, metric="l2", M=4), 2,
                            max_k=4)
    assert not isinstance(eng, RescalableBackend)
    mi = MutableIndex(x, "l2", M=4)
    wrapped = MutableBackend(ProgressiveEngine(mi.graph, 2, max_k=4), mi)
    assert not isinstance(wrapped, RescalableBackend)
    with pytest.raises(ValueError, match="elastic"):
        LaneScheduler(backend=wrapped, prewarm=False, elastic=True)


def test_db_elastic_single_device_raises():
    """elastic= needs >= 2 visible devices (there is nothing to scale
    between on one); shards='auto' alone resolves to the device count."""
    import jax

    from repro.db import DiverseVectorDB

    x = _corpus(2, n=64)
    if jax.device_count() >= 2:
        pytest.skip("requires a single-device process")
    with pytest.raises(ValueError, match="devices"):
        DiverseVectorDB(x, "l2", shards="auto", elastic=True, prewarm=False)
    db = DiverseVectorDB(x, "l2", shards="auto", M=4, num_lanes=2,
                         max_k=4, prewarm=False)
    assert db.backend.num_shards == 1
    assert db.backend.rescale_options() == (1,)
    r = db.search(x[3], k=3, eps=2.0)
    assert r.stats.certified
