import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh, shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import allgather_matmul, ring_allgather_matmul

mesh = make_mesh((4,), ("model",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
w = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)

def base(xs, w):
    return allgather_matmul(xs, w, "model")
def ring(xs, w):
    return ring_allgather_matmul(xs, w, "model")

fb = jax.jit(shard_map(base, mesh, in_specs=(P("model"), P()), out_specs=P()))
fr = jax.jit(shard_map(ring, mesh, in_specs=(P("model"), P()), out_specs=P()))
want = np.asarray(x) @ np.asarray(w)
np.testing.assert_allclose(np.asarray(fb(x, w)), want, rtol=1e-5, atol=1e-5)
np.testing.assert_allclose(np.asarray(fr(x, w)), want, rtol=1e-5, atol=1e-5)
print("OK")
