import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.compat import make_mesh
from repro.configs import get_config
from repro.models import model as M
from repro.distributed import sharding as sh
from repro.distributed.elastic import reshard_tree, plan
from repro.train import checkpoint as ckpt
import tempfile

cfg = get_config("qwen2-1.5b").reduced()
mesh8 = make_mesh((4, 2), ("data", "model"))
mesh4 = make_mesh((2, 2), ("data", "model"))
params = M.init_params(cfg, jax.random.key(0))

# shrink 8 -> 4 through a checkpoint round trip (logical keys, layout-free)
p8 = reshard_tree(params, mesh8, cfg)
with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 1, p8)
    restored = ckpt.restore(d, 1, p8, shardings=sh.to_named(
        sh.param_spec_tree(cfg, p8, mesh4), mesh4))
a = np.asarray(jax.tree.leaves(params)[0])
np.testing.assert_array_equal(a, np.asarray(jax.tree.leaves(restored)[0]))

# grow 4 -> 8 in memory: reshard_tree re-places the restored tree directly
p8b = reshard_tree(restored, mesh8, cfg)
np.testing.assert_array_equal(a, np.asarray(jax.tree.leaves(p8b)[0]))

# plan() is a pure mesh diff; plan(a, b) and plan(b, a) are exact inverses
down, up = plan(mesh8, mesh4), plan(mesh4, mesh8)
assert down["dp_change"] == 0.5 and up["dp_change"] == 2.0
assert down["old"] == up["new"] and down["new"] == up["old"]
for ax, r in down["axis_changes"].items():
    assert up["axis_changes"][ax] == 1.0 / r, (ax, r)

# loss identical on every placement
batch = M.make_batch(cfg, batch=4, seq=8, rng=jax.random.key(1))
l8 = float(M.loss_fn(cfg, p8, batch))
l4 = float(M.loss_fn(cfg, restored, batch))
l8b = float(M.loss_fn(cfg, p8b, batch))
assert abs(l8 - l4) < 1e-4 and abs(l8 - l8b) < 1e-4, (l8, l4, l8b)
print("OK")
