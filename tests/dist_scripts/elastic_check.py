import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.compat import make_mesh
from repro.configs import get_config
from repro.models import model as M
from repro.distributed import sharding as sh
from repro.distributed.elastic import reshard_tree, plan
from repro.train import checkpoint as ckpt
import tempfile

cfg = get_config("qwen2-1.5b").reduced()
mesh8 = make_mesh((4, 2), ("data", "model"))
mesh4 = make_mesh((2, 2), ("data", "model"))
params = M.init_params(cfg, jax.random.key(0))
p8 = reshard_tree(params, cfg, mesh8)
with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 1, p8)
    restored = ckpt.restore(d, 1, p8, shardings=sh.to_named(sh.param_spec_tree(cfg, p8, mesh4), mesh4))
# values identical after 8-dev -> 4-dev move
a = np.asarray(jax.tree.leaves(params)[0]); b = np.asarray(jax.tree.leaves(restored)[0])
np.testing.assert_array_equal(a, b)
info = plan(cfg, mesh8, mesh4)
assert info["dp_change"] == 0.5
# loss identical on both meshes
batch = M.make_batch(cfg, batch=4, seq=8, rng=jax.random.key(1))
l8 = float(M.loss_fn(cfg, p8, batch))
l4 = float(M.loss_fn(cfg, restored, batch))
assert abs(l8 - l4) < 1e-4, (l8, l4)
print("OK")
