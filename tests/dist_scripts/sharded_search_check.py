import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.sharded_search import (build_sharded_index, sharded_topk,
                                  sharded_diverse_search,
                                  sharded_progressive_diverse)
from repro.index.flat import exact_topk
from repro.core.similarity import pairwise_sim

rng = np.random.default_rng(0)
N, d = 2048, 16
X = rng.normal(size=(N, d)).astype(np.float32)
idx = build_sharded_index(X, 4, "ip", M=8)
mesh = make_mesh((4,), ("data",))
qs = jnp.asarray(rng.normal(size=(8, d)), jnp.float32)
ids, scores = sharded_topk(idx, qs, k=10, L=64, mesh=mesh)
gt_ids, _ = exact_topk(np.asarray(qs), X, 10, "ip")
rec = np.mean([len(set(np.asarray(ids[i]).tolist()) & set(gt_ids[i].tolist()))/10 for i in range(8)])
assert rec >= 0.95, rec
ids2, _ = sharded_topk(idx, qs, k=10, L=64, mesh=mesh, merge="allgather")
assert bool(jnp.all(ids == ids2)), "tournament != allgather merge"
dids, dsc, cert = sharded_diverse_search(idx, jnp.asarray(X), qs, k=5, eps=4.0, K=64, mesh=mesh)
dids = np.asarray(dids)
for i in range(8):
    sel = dids[i][dids[i] >= 0]
    assert len(sel) == 5, (i, sel)
    sims = np.asarray(pairwise_sim(jnp.asarray(X[sel]), jnp.asarray(X[sel]), "ip"))
    off = sims[~np.eye(len(sel), dtype=bool)]
    assert np.all(off < 4.0 + 1e-4)
# progressive entry point: per-lane budgets grow until each lane certifies
pids, psc, pcert, K_final = sharded_progressive_diverse(
    idx, jnp.asarray(X), qs, k=5, eps=4.0, mesh=mesh, K0=16)
pids = np.asarray(pids)
K_final = np.asarray(K_final)
assert K_final.shape == (8,) and K_final.min() >= 16
# per-lane budgets walk the doubling ladder from K0 (clamped to N)
ladder = {min(16 << j, N) for j in range(20)}
assert set(K_final.tolist()) <= ladder, K_final
for i in range(8):
    sel = pids[i][pids[i] >= 0]
    assert len(sel) == 5, (i, sel)
    sims = np.asarray(pairwise_sim(jnp.asarray(X[sel]), jnp.asarray(X[sel]), "ip"))
    off = sims[~np.eye(len(sel), dtype=bool)]
    assert np.all(off < 4.0 + 1e-4)
print("OK")
