"""Scheduler-through-ShardedEngine on a forced 4-device host mesh.

Asserts the PR's two mesh-serving acceptance criteria:
1. parity — every request served by the unmodified LaneScheduler over a
   ShardedEngine equals sharded_diverse_search for that query at the lane's
   final K-budget (ids/scores exactly, certificate flag too);
2. continuous batching — at least one queued request is admitted into a
   mesh lane freed by an earlier request *while other lanes are still
   mid-flight* (the freed-slot refill the old host loop never did).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.serve.scheduler import LaneScheduler
from repro.sharded_search import (ShardedEngine, build_sharded_index,
                                  sharded_diverse_search)

rng = np.random.default_rng(0)
N, d = 2048, 16
X = rng.normal(size=(N, d)).astype(np.float32)
index = build_sharded_index(X, 4, "ip", M=8)
mesh = make_mesh((4,), ("data",))
qs = rng.normal(size=(8, d)).astype(np.float32)

engine = ShardedEngine(index, jnp.asarray(X), mesh, num_lanes=3, K0=16,
                       max_k=8)
sched = LaneScheduler(backend=engine, prewarm=False, max_pending=8)
reqs = [sched.submit(qs[i], 5, 4.0) for i in range(8)]   # 8 reqs > 3 lanes

lane_history: dict[int, list[int]] = {}
mid_run_admission = False
while sched.pending or sched.inflight:
    inflight_before = {lane: req.rid for lane, req in sched.inflight.items()}
    sched.pump()
    for lane, req in sched.inflight.items():
        if inflight_before.get(lane) == req.rid:
            continue                       # not admitted this pump
        # admission happens before the step, so everything in
        # inflight_before was still mid-flight when this lane was refilled
        if lane in lane_history and inflight_before:
            mid_run_admission = True
        lane_history.setdefault(lane, []).append(req.rid)

assert mid_run_admission, \
    "no queued request was admitted into a freed mesh lane mid-run"
assert sum(len(v) for v in lane_history.values()) == 8, lane_history
assert max(len(v) for v in lane_history.values()) >= 2   # lanes recycled

for req in reqs:
    assert req.result is not None and req.method == "sharded"
    Kf = int(req.result.stats.K_final)
    assert Kf in {min(16 << j, N) for j in range(20)}, Kf
    ids, sc, cert = sharded_diverse_search(
        index, jnp.asarray(X), jnp.asarray(req.q[None]), 5, 4.0, Kf, mesh)
    assert np.array_equal(np.asarray(ids)[0], req.result.ids), req.rid
    assert np.array_equal(np.asarray(sc)[0], req.result.scores), req.rid
    assert bool(np.asarray(cert)[0]) == req.result.stats.certified, req.rid

stats = sched.latency_stats()
assert stats["completed"] == 8 and stats["inflight"] == 0
assert stats["signatures"] > 0 and stats["certified_frac"] > 0
print("OK")
