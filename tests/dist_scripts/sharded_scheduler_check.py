"""Scheduler-through-ShardedEngine on a forced 4-device host mesh.

Asserts the mesh-serving acceptance criteria:
1. parity — every request served by the unmodified LaneScheduler over a
   resume="scratch" ShardedEngine equals sharded_diverse_search for that
   query at the lane's final K-budget (ids/scores exactly, certificate flag
   too) — the scratch path keeps its bit-exact contract;
2. continuous batching — at least one queued request is admitted into a
   mesh lane freed by an earlier request *while other lanes are still
   mid-flight* (the freed-slot refill the old host loop never did);
3. resumption — at the same capped budget ladder, every multi-round
   resume="beam" lane reports strictly fewer cumulative shard expansions
   than its resume="scratch" twin, recall vs the exact diverse oracle is no
   worse, and every certified beam lane passes an independent Theorem-2
   re-check against its recorded final candidate frontier.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.backend import LaneRequest
from repro.core.theorems import theorem2_recheck
from repro.serve.scheduler import LaneScheduler
from repro.sharded_search import (ShardedEngine, build_sharded_index,
                                  sharded_diverse_search)

rng = np.random.default_rng(0)
N, d = 2048, 16
X = rng.normal(size=(N, d)).astype(np.float32)
index = build_sharded_index(X, 4, "ip", M=8)
mesh = make_mesh((4,), ("data",))
qs = rng.normal(size=(8, d)).astype(np.float32)

engine = ShardedEngine(index, jnp.asarray(X), mesh, num_lanes=3, K0=16,
                       max_k=8, resume="scratch")
sched = LaneScheduler(backend=engine, prewarm=False, max_pending=8)
reqs = [sched.submit(qs[i], 5, 4.0) for i in range(8)]   # 8 reqs > 3 lanes

lane_history: dict[int, list[int]] = {}
mid_run_admission = False
while sched.pending or sched.inflight:
    inflight_before = {lane: req.rid for lane, req in sched.inflight.items()}
    sched.pump()
    for lane, req in sched.inflight.items():
        if inflight_before.get(lane) == req.rid:
            continue                       # not admitted this pump
        # admission happens before the step, so everything in
        # inflight_before was still mid-flight when this lane was refilled
        if lane in lane_history and inflight_before:
            mid_run_admission = True
        lane_history.setdefault(lane, []).append(req.rid)

assert mid_run_admission, \
    "no queued request was admitted into a freed mesh lane mid-run"
assert sum(len(v) for v in lane_history.values()) == 8, lane_history
assert max(len(v) for v in lane_history.values()) >= 2   # lanes recycled

for req in reqs:
    assert req.result is not None and req.method == "sharded"
    Kf = int(req.result.stats.K_final)
    assert Kf in {min(16 << j, N) for j in range(20)}, Kf
    ids, sc, cert = sharded_diverse_search(
        index, jnp.asarray(X), jnp.asarray(req.q[None]), 5, 4.0, Kf, mesh)
    assert np.array_equal(np.asarray(ids)[0], req.result.ids), req.rid
    assert np.array_equal(np.asarray(sc)[0], req.result.scores), req.rid
    assert bool(np.asarray(cert)[0]) == req.result.stats.certified, req.rid

stats = sched.latency_stats()
assert stats["completed"] == 8 and stats["inflight"] == 0
assert stats["signatures"] > 0 and stats["certified_frac"] > 0

# --- resumable shard-local beams: beam vs scratch on the same ladder --------
# Capped at two rounds, round-1 results are bit-exact across modes, so the
# survivor sets match and every retiring lane stops at the same K-budget:
# the clean setting for "strictly fewer cumulative expansions, same budget".


def drive(mode, max_rounds=2):
    eng = ShardedEngine(index, jnp.asarray(X), mesh, num_lanes=8, K0=16,
                        max_k=8, resume=mode, max_rounds=max_rounds,
                        record_candidates=True)
    for lane in range(8):
        eng.admit(lane, LaneRequest(q=qs[lane], k=5, eps=4.0,
                                    method="sharded"))
    out = {}
    while eng.active_count():
        eng.step()
        for lane, res in eng.harvest():
            out[lane] = res
            eng.recycle(lane)
    return out, eng


scratch, _ = drive("scratch")
beam, beam_eng = drive("beam")
multi = [lane for lane, r in scratch.items() if r.stats.search_calls > 1]
assert multi, "no multi-round lane; the expansion check needs one"
for lane in multi:
    s, b = scratch[lane], beam[lane]
    assert b.stats.K_final == s.stats.K_final, lane
    assert 0 < b.stats.expansions < s.stats.expansions, (
        f"lane {lane}: resume must cut cumulative shard expansions "
        f"(beam {b.stats.expansions} vs scratch {s.stats.expansions})")
for lane, r in beam.items():
    if r.stats.search_calls == 1:   # single-round: bit-exact with scratch
        assert np.array_equal(r.ids, scratch[lane].ids), lane
        assert np.array_equal(r.scores, scratch[lane].scores), lane

# certified beam lanes must survive an independent Theorem-2 re-check over
# their recorded final candidate frontier (certificate soundness); the
# two-round cap above retires lanes uncertified, so certificates come from
# an uncapped beam run of the same requests
beam_full, beam_eng = drive("beam", max_rounds=8)
checked = 0
for lane, r in beam_full.items():
    if not r.stats.certified:
        continue
    cand_ids, cand_sc = beam_eng.last_candidates[lane]
    ok, sel_ids = theorem2_recheck(X, "ip", cand_ids, cand_sc, 4.0, 5)
    assert ok, f"lane {lane}: certificate does not re-verify"
    assert np.array_equal(sel_ids, r.ids), lane
    checked += 1
assert checked, "no certified beam lane to re-check"

# recall vs the exact diverse oracle: resumption must not cost quality
# (compared on the uncapped runs, where lanes certify instead of truncating)
from repro.core.baselines import div_astar_oracle

scratch_full, _ = drive("scratch", max_rounds=8)


def mean_recall(out):
    recs = []
    for lane, r in out.items():
        o = div_astar_oracle(X, "ip", qs[lane], 5, 4.0, X=512)
        truth = set(int(i) for i in o.ids if i >= 0)
        got = set(int(i) for i in r.ids if i >= 0)
        recs.append(len(got & truth) / max(len(truth), 1))
    return float(np.mean(recs))


r_beam, r_scratch = mean_recall(beam_full), mean_recall(scratch_full)
assert r_beam >= r_scratch, (r_beam, r_scratch)
print(f"resume check: {len(multi)} multi-round lanes, {checked} certificates "
      f"re-verified, recall beam {r_beam:.3f} vs scratch {r_scratch:.3f}")
print("OK")
