import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from repro.compat import make_mesh
from repro.configs import get_config, ShapeCard
from repro.launch.steps import build_train_step, build_serve_step, input_specs
from repro.launch.hlo_analysis import analyze

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
for arch in ("qwen2-1.5b", "moonshot-v1-16b-a3b", "mamba2-370m", "whisper-small"):
    cfg = get_config(arch).reduced()
    shape = ShapeCard("t", 32, 8, "train")
    specs = input_specs(cfg, shape, mesh)
    step, _ = build_train_step(cfg, mesh)
    with mesh:
        comp = step.lower(specs["params"], specs["opt_state"], specs["batch"]).compile()
    res = analyze(comp.as_text())
    assert res["flops"] > 0
    sshape = ShapeCard("d", 64, 8, "decode")
    sspecs = input_specs(cfg, sshape, mesh)
    sstep, _ = build_serve_step(cfg, mesh)
    with mesh:
        comp2 = sstep.lower(sspecs["params"], sspecs["cache"], sspecs["token"]).compile()
    print(arch, "train+serve compile OK, flops=%.2e" % res["flops"])
print("OK")
