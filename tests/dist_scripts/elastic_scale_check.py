"""Elastic mesh serving on a forced 4-device host (contract 16).

Asserts the PR-10 acceptance criteria:

1. straddle parity — engine-direct lanes admitted mid-ladder straddle a
   grow (2 -> 4 shards) and a shrink (4 -> 2): every straddling lane
   finishes bit-identical to a fixed-mesh run of the final topology at the
   same final K-budget, or certified with an independent Theorem-2 recheck
   over its recorded candidate frontier (0 violations), and mean oracle
   recall is no worse than the fixed-mesh twin that never migrated;
2. elastic scheduling — a DiverseVectorDB with an ElasticPolicy under a
   traffic burst performs >= 1 grow and >= 1 shrink, admits at least one
   queued request into a lane on the NEW mesh mid-run, and completes every
   request certified;
3. recompile budget — with both targets prepared at construction, the
   frozen SignatureLog stays clean across the scale events (a scale event
   adds only planned signatures) and ``resume_jit_cache_sizes()`` is flat
   between the post-prewarm audit and the end of serving.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.backend import LaneRequest, RescalableBackend
from repro.core.baselines import div_astar_oracle
from repro.core.theorems import theorem2_recheck
from repro.db import DiverseVectorDB
from repro.serve.scheduler import ElasticPolicy
from repro.sharded_search import (ShardedEngine, build_sharded_index,
                                  sharded_diverse_search)
from repro.sharded_search.engine import LANE_RUN
from repro.sharded_search.search import resume_jit_cache_sizes

rng = np.random.default_rng(0)
N, d, k, eps = 2048, 16, 5, 4.0
X = rng.normal(size=(N, d)).astype(np.float32)
qs = rng.normal(size=(8, d)).astype(np.float32)
mesh2 = make_mesh((2,), ("data",))
mesh4 = make_mesh((4,), ("data",))
index2 = build_sharded_index(X, 2, "ip", M=8)

# --- 1. engine-direct straddles: grow 2 -> 4, then shrink 4 -> 2 ------------


def drive_straddle(start_index, start_mesh, to_shards, to_mesh):
    eng = ShardedEngine(start_index, jnp.asarray(X), start_mesh, num_lanes=4,
                        K0=16, max_k=8, resume="beam", record_candidates=True)
    eng.prepare_rescale(to_shards, to_mesh, prewarm=False)
    for lane in range(4):
        eng.admit(lane, LaneRequest(q=qs[lane], k=k, eps=eps,
                                    method="sharded"))
    eng.step()                                   # round 1 on the old mesh
    eng.harvest()
    straddled = [int(x) for x in np.flatnonzero(eng.status == LANE_RUN)]
    assert eng.rescale(to_shards), "rescale must report a topology change"
    assert eng.num_shards == to_shards
    out = {}
    while eng.active_count():
        eng.step()
        for lane, res in eng.harvest():
            out[lane] = res
            # lane stays un-recycled so last_candidates survives below
    return eng, out, straddled


def check_straddle(eng, out, straddled, final_index, final_mesh):
    """Every straddling lane: bit-match with the fixed final mesh at the
    same budget, or a certified result whose recorded frontier re-verifies
    under Theorem 2 (resharding is a capacity knob, never a results knob)."""
    violations = 0
    for lane in straddled:
        r = out[lane]
        Kf = int(r.stats.K_final)
        ids, sc, cert = sharded_diverse_search(
            final_index, jnp.asarray(X), jnp.asarray(qs[lane][None]),
            k, eps, Kf, final_mesh)
        bit_match = (np.array_equal(np.asarray(ids)[0], r.ids)
                     and np.array_equal(np.asarray(sc)[0], r.scores))
        if not bit_match:
            cand_ids, cand_sc = eng.last_candidates[lane]
            ok, sel_ids = theorem2_recheck(X, "ip", cand_ids, cand_sc,
                                           eps, k)
            if not (r.stats.certified and ok
                    and np.array_equal(sel_ids, r.ids)):
                violations += 1
    assert violations == 0, f"{violations} straddle parity violations"
    return [out[lane] for lane in straddled]


eng_g, out_g, straddled_g = drive_straddle(index2, mesh2, 4, mesh4)
assert len(straddled_g) >= 2, "grow straddle needs in-flight lanes"
index4 = eng_g.index
grow_res = check_straddle(eng_g, out_g, straddled_g, index4, mesh4)

eng_s, out_s, straddled_s = drive_straddle(index4, mesh4, 2, mesh2)
assert len(straddled_s) >= 2, "shrink straddle needs in-flight lanes"
shrink_res = check_straddle(eng_s, out_s, straddled_s, eng_s.index, mesh2)
assert any(r.stats.certified for r in grow_res + shrink_res)

# recall vs a fixed-mesh twin that never migrated: no worse
fixed = ShardedEngine(index2, jnp.asarray(X), mesh2, num_lanes=4, K0=16,
                      max_k=8, resume="beam")
for lane in range(4):
    fixed.admit(lane, LaneRequest(q=qs[lane], k=k, eps=eps,
                                  method="sharded"))
fixed_out = {}
while fixed.active_count():
    fixed.step()
    for lane, res in fixed.harvest():
        fixed_out[lane] = res
        fixed.recycle(lane)


def mean_recall(out):
    recs = []
    for lane, r in out.items():
        o = div_astar_oracle(X, "ip", qs[lane], k, eps, X=512)
        truth = set(int(i) for i in o.ids if i >= 0)
        got = set(int(i) for i in r.ids if i >= 0)
        recs.append(len(got & truth) / max(len(truth), 1))
    return float(np.mean(recs))


r_elastic, r_fixed = mean_recall(out_g), mean_recall(fixed_out)
assert r_elastic >= r_fixed, (r_elastic, r_fixed)
print(f"straddles: grow={len(straddled_g)} shrink={len(straddled_s)} lanes, "
      f"recall elastic {r_elastic:.3f} vs fixed {r_fixed:.3f}")

# --- 2. scheduler-driven scale events through the facade --------------------

policy = ElasticPolicy(grow_depth=2, shrink_depth=0, sustain=2,
                       shrink_sustain=3, cooldown=3)
db = DiverseVectorDB(X, "ip", shards="auto", elastic=policy, num_lanes=2,
                     max_k=8, M=8, prewarm=True,
                     backend_kw=dict(K0=16, resume="beam"),
                     scheduler_kw=dict(max_pending=32, prewarm_capacity=N,
                                       prewarm_ks=(k,)))
assert isinstance(db.backend, RescalableBackend)
assert db.backend.num_shards == 2 and set(db.backend.rescale_options()) == \
    {2, 4}

# 3. recompile-budget audit: freeze now — every signature a scale event
# needs must already be planned, and the resume dispatch cache must not
# grow once both targets are prewarmed
sig = db.engine.signature_log
sig.freeze()
sizes0 = resume_jit_cache_sizes()

sched = db.scheduler
burst = rng.normal(size=(24, d)).astype(np.float32)
reqs, i, admitted_on_new = [], 0, False
while i < len(burst) or sched.pending or sched.inflight:
    while i < len(burst) and len(sched.pending) < 4:
        reqs.append(sched.submit(burst[i], k, eps))
        i += 1
    before = {lane: r.rid for lane, r in sched.inflight.items()}
    sched.pump()
    if db.backend.num_shards == 4 and sched.scale_events:
        if any(before.get(lane) != r.rid
               for lane, r in sched.inflight.items()):
            admitted_on_new = True   # refilled AFTER the grow, on the new mesh
for _ in range(24):                  # idle pumps: let the shrink trigger fire
    sched.pump()
    if any(e["to_shards"] < e["from_shards"] for e in sched.scale_events):
        break

grows = [e for e in sched.scale_events if e["to_shards"] > e["from_shards"]]
shrinks = [e for e in sched.scale_events if e["to_shards"] < e["from_shards"]]
assert grows, "burst never triggered a grow"
assert shrinks, "idle queue never triggered a shrink"
assert admitted_on_new, "no request was admitted into a lane on the new mesh"
assert all(r.result is not None for r in reqs)
assert all(r.result.stats.certified for r in reqs)
stats = sched.latency_stats()
assert stats["completed"] == len(burst) and stats["inflight"] == 0
assert stats["shards"] == db.backend.num_shards
assert stats["scale_events"] == len(grows) + len(shrinks)

assert sig.unplanned == [], f"unplanned signatures: {sig.unplanned}"
sizes1 = resume_jit_cache_sizes()
assert sizes1 == sizes0, f"resume jit cache grew: {sizes0} -> {sizes1}"
print(f"scale events: {len(grows)} grow + {len(shrinks)} shrink, "
      f"pause p max {max(e['pause_s'] for e in sched.scale_events):.3f}s, "
      f"jit cache {sizes1}")
print("OK")
