"""Contract 15 on the mesh backend: a 4-shard DiverseVectorDB serving
multi-round lanes while upserts/deletes land mid-run, the delta fills, and
the rebuilt sharded index swaps in between rounds.

Asserts, for every request:
1. single-epoch validity — served ids all lie inside the corpus version
   the harvest tagged the result with (``MutableBackend.last_meta``), and
   none was tombstoned at that version (no mixed-epoch result set, no
   deleted id served);
2. certificate soundness — every certified lane's merged frontier passes
   an independent Theorem-2 recheck against its version's corpus rows and
   reselects exactly the served ids;
3. the run actually straddles: results from both epoch 0 and epoch 1,
   with at least one swap installed while requests were queued.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np

from repro.core import theorems
from repro.db import DiverseVectorDB, Query
from repro.serve.scheduler import RequestDeferred, SchedulerSaturated

rng = np.random.default_rng(0)
N, d = 1024, 16
X = rng.normal(size=(N, d)).astype(np.float32)
db = DiverseVectorDB(X, "ip", shards=4, num_lanes=3, max_k=8,
                     default_ef=12, M=8, delta_capacity=8,
                     background_rebuild=False, prewarm=False)
qs = (X[rng.integers(0, N, 10)]
      + 0.05 * rng.normal(size=(10, d))).astype(np.float32)

snaps = {}


def snap():
    snaps[db.index.version] = (db.index.n_total, db.index.deleted.copy())


def submit(i, k=5, eps=4.0):
    while True:
        try:
            reqs.append(db.scheduler.submit(Query(qs[i], k=k, eps=eps,
                                                  ef=12)))
            return
        except (SchedulerSaturated, RequestDeferred):
            db.scheduler.pump()


def poll():
    for r in reqs:
        if (r.result is not None and r.lane is not None
                and id(r) not in metas):
            metas[id(r)] = db.backend.last_meta[r.lane]
            frontiers[id(r)] = db.backend.last_candidates[r.lane]


snap()
reqs, metas, frontiers = [], {}, {}
for i in range(5):
    submit(i)
db.scheduler.pump()
poll()
assert db.scheduler.inflight or db.scheduler.pending
db.upsert(qs[:3] + np.float32(0.01))
snap()
db.delete([17, 23])
snap()
for i in range(5, 8):
    submit(i)
db.scheduler.pump()
poll()
db.upsert(rng.normal(size=(6, d)).astype(np.float32))  # crosses capacity
snap()
assert db.index.swap_ready()
for i in range(8, 10):
    submit(i)
while any(r.result is None for r in reqs):
    db.scheduler.pump()
    poll()

assert db.backend.swaps == 1 and db.index.epoch == 1, db.stats()["index"]
epochs = set()
for r in reqs:
    meta = metas[id(r)]
    epochs.add(meta["epoch"])
    v = max(ver for ver in snaps if ver <= meta["version"])
    n_at, dele_at = snaps[v]
    ids = np.asarray(r.result.ids)
    ids = ids[ids >= 0]
    assert ids.size and (ids < n_at).all(), (meta, ids)
    assert not dele_at[ids].any(), (meta, ids)
    assert not {17, 23}.intersection(ids.tolist())
    if r.result.stats.certified:
        m_ids, m_sc = frontiers[id(r)][0], frontiers[id(r)][1]
        ok, sel = theorems.theorem2_recheck(
            db.index.float_view()[:n_at], "ip", m_ids, m_sc, 4.0, 5)
        assert ok and np.array_equal(np.asarray(sel),
                                     np.asarray(r.result.ids))
assert epochs == {0, 1}, epochs
# post-swap service: the delta emptied into the new epoch's structure and
# the upserted near-dup of qs[0] (id N) is reachable through it — it must
# surface in the serving lane's candidate frontier (the diverse selection
# itself may legitimately trade the top scorer away at this eps)
st = db.stats()["index"]
assert st["delta"] == 0 and st["epoch"] == 1, st
r = db.search(Query(qs[0], k=5, eps=4.0, ef=12))
assert any(fr is not None and int(N) in np.asarray(fr[0]).tolist()
           for fr in db.backend.last_candidates), \
    "upserted row absent from every post-swap frontier"
print("OK")
