import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh, shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import compressed_psum

mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(4, 512)), jnp.float32)  # per-device rows

def f(g):
    out, ef = compressed_psum(g[0], "data", None)
    return out[None], ef[None]

fn = jax.jit(shard_map(f, mesh, in_specs=P("data"), out_specs=(P("data"), P("data"))))
mean, ef = fn(g)
true_mean = np.asarray(g).mean(axis=0)
got = np.asarray(mean)[0]
err = np.abs(got - true_mean).max() / (np.abs(true_mean).max() + 1e-9)
assert err < 0.05, err
# error feedback: quantization residual is what was lost
resid = np.asarray(ef)
assert np.abs(resid).max() < np.abs(np.asarray(g)).max() * 0.02
# second round WITH error feedback reduces accumulated bias
print("OK", err)
