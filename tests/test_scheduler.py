"""Continuous-batching lane scheduler: admission, recycling parity,
backpressure, latency stats, and the pre-warmed compile ladder."""
import numpy as np
import pytest

from repro.core.batch_progressive import jit_cache_sizes
from repro.core.pds import pds
from repro.core.pss import pss
from repro.index.flat import build_knn_graph
from repro.serve.scheduler import (LaneScheduler, SchedulerSaturated,
                                   jain_fairness)


@pytest.fixture(scope="module")
def graph_and_queries():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(12, 24)) * 2.0
    x = (centers[rng.integers(0, 12, 600)]
         + rng.normal(size=(600, 24)) * 0.3).astype(np.float32)
    graph = build_knn_graph(x, metric="l2", M=8)
    qs = (x[rng.integers(0, 600, 10)]
          + rng.normal(size=(10, 24)).astype(np.float32) * 0.05)
    return graph, qs.astype(np.float32)


MIX_KS = [5, 3, 5, 3, 5, 3, 5, 3, 5, 3]
MIX_EPS = [0.0, -0.5, 0.0, -0.5, 0.0, -0.5, 0.0, -0.5, 0.0, -0.5]


def test_scheduler_matches_solo_pss(graph_and_queries):
    """More requests than lanes with mixed per-request (k, eps): every
    result — including those served on recycled lanes — must equal a fresh
    per-query PSS driver bit-for-bit."""
    graph, qs = graph_and_queries
    sched = LaneScheduler(graph, num_lanes=3, max_k=8, default_ef=10,
                          prewarm=False)
    results = sched.run(qs, MIX_KS, MIX_EPS)
    assert len(results) == len(qs)
    for i, r in enumerate(results):
        solo = pss(graph, qs[i], MIX_KS[i], MIX_EPS[i], ef=10)
        np.testing.assert_array_equal(np.asarray(solo.ids), r.ids)
        np.testing.assert_array_equal(np.asarray(solo.scores), r.scores)
        assert solo.stats.certified == r.stats.certified
        assert solo.stats.K_final == r.stats.K_final


def test_lockstep_and_continuous_agree(graph_and_queries):
    """Admission policy changes latency, never results."""
    graph, qs = graph_and_queries
    a = LaneScheduler(graph, num_lanes=3, max_k=8, default_ef=10,
                      admission="continuous", prewarm=False)
    b = LaneScheduler(graph, num_lanes=3, max_k=8, default_ef=10,
                      admission="lockstep", prewarm=False)
    ra = a.run(qs, MIX_KS, MIX_EPS)
    rb = b.run(qs, MIX_KS, MIX_EPS)
    for x, y in zip(ra, rb):
        np.testing.assert_array_equal(x.ids, y.ids)
        np.testing.assert_array_equal(x.scores, y.scores)


def test_explicit_backend_matches_graph_construction(graph_and_queries):
    """LaneScheduler(backend=ProgressiveEngine(...)) is the same scheduler
    as the graph-convenience constructor — bit-identical results."""
    from repro.core.batch_progressive import ProgressiveEngine

    graph, qs = graph_and_queries
    eng = ProgressiveEngine(graph, num_lanes=3, max_k=8, default_ef=10)
    a = LaneScheduler(backend=eng, prewarm=False)
    assert a.backend is eng and a.num_lanes == 3
    b = LaneScheduler(graph, num_lanes=3, max_k=8, default_ef=10,
                      prewarm=False)
    ra = a.run(qs, MIX_KS, MIX_EPS)
    rb = b.run(qs, MIX_KS, MIX_EPS)
    for x, y in zip(ra, rb):
        np.testing.assert_array_equal(x.ids, y.ids)
        np.testing.assert_array_equal(x.scores, y.scores)


def test_scheduler_runs_pds_requests(graph_and_queries):
    graph, qs = graph_and_queries
    sched = LaneScheduler(graph, num_lanes=2, max_k=8, default_ef=10,
                          prewarm=False)
    reqs = [sched.submit(qs[i], 4, 0.0, ef=10, method="pds")
            for i in range(4)]
    sched.drain()
    for i, req in enumerate(reqs):
        solo = pds(graph, qs[i], 4, 0.0, ef=10)
        np.testing.assert_array_equal(np.asarray(solo.ids), req.result.ids)
        assert solo.stats.certified == req.result.stats.certified


def test_backpressure(graph_and_queries):
    graph, qs = graph_and_queries
    sched = LaneScheduler(graph, num_lanes=2, max_pending=2, prewarm=False)
    sched.submit(qs[0], 3, 0.0)
    sched.submit(qs[1], 3, 0.0)
    with pytest.raises(SchedulerSaturated):
        sched.submit(qs[2], 3, 0.0)
    assert sched.try_submit(qs[2], 3, 0.0) is None
    sched.pump()                       # admits into lanes, queue drains
    assert sched.try_submit(qs[2], 3, 0.0) is not None
    sched.drain()
    assert len(sched.completed) == 3


def test_latency_stats_and_fairness(graph_and_queries):
    graph, qs = graph_and_queries
    sched = LaneScheduler(graph, num_lanes=3, max_k=8, default_ef=10,
                          prewarm=False)
    sched.run(qs, 5, 0.0)
    st = sched.latency_stats()
    assert st["completed"] == len(qs)
    assert st["pending"] == 0 and st["inflight"] == 0
    assert st["p99_latency"] >= st["p50_latency"] >= 0
    assert st["p99_wait"] >= 0 and st["p99_service"] > 0
    assert 0 < st["fairness"] <= 1
    assert st["throughput"] > 0
    for r in sched.completed:
        assert r.t_submit <= r.t_admit <= r.t_done
    assert jain_fairness([1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert jain_fairness([]) == 1.0


def test_prewarm_ladder_no_unplanned_recompiles(graph_and_queries):
    """The scheduler pre-warms the capacity ladder at start; after one
    serving pass populated the diversify-stage signatures, a second pass
    over the same request shapes must not trace anything new — neither in
    the engine's signature log nor in the jitted functions' caches."""
    graph, qs = graph_and_queries
    sched = LaneScheduler(graph, num_lanes=3, max_k=8, default_ef=10,
                          prewarm=True, prewarm_capacity=1024)
    sched.run(qs, MIX_KS, MIX_EPS)
    sched.engine.signatures.freeze()
    before = jit_cache_sizes()
    sched.run(qs.copy(), list(MIX_KS), list(MIX_EPS))  # repeat traffic
    assert sched.engine.signatures.unplanned == []
    assert jit_cache_sizes() == before
    assert sched.latency_stats()["unplanned_signatures"] == 0
