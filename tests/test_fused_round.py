"""Fused round kernel: bit-parity vs the jnp oracle (interpret mode), the
old per-stage dispatch chain, and the engine (ARCHITECTURE.md contract #12).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batch_progressive import (_batched_adjacency, _mask_prefix,
                                          batch_pss)
from repro.index.flat import build_knn_graph
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _normalize(v):
    return (v / np.maximum(np.linalg.norm(v, axis=-1, keepdims=True),
                           1e-9)).astype(np.float32)


def _lane_batch(n=600, d=24, B=8, W=96, seed=11):
    """Random sorted queue-prefix rows with ragged fill and budgets."""
    rng = np.random.default_rng(seed)
    vectors = jnp.asarray(_normalize(rng.normal(size=(n, d))))
    ids = np.full((B, W), -1, np.int32)
    scores = np.full((B, W), -np.inf, np.float32)
    Ks = rng.integers(8, W + 1, size=B).astype(np.int32)
    for b in range(B):
        m = int(rng.integers(5, W + 1))
        ids[b, :m] = rng.choice(n, size=m, replace=False)
        scores[b, :m] = np.sort(rng.normal(size=m))[::-1]
    return vectors, ids, scores, Ks


def _assert_rounds_equal(got, want):
    for name, g, w in zip(("sel_ids", "sel_scores", "count", "cert"),
                          got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


@pytest.mark.parametrize("eps", [0.5, 0.8])
@pytest.mark.parametrize("k", [5, 10])
def test_fused_round_interpret_bit_parity(eps, k):
    """The ISSUE-6 acceptance sweep: interpret-mode kernel == jnp oracle,
    bit-exact, for eps in {0.5, 0.8} x k in {5, 10}."""
    vectors, ids, scores, Ks = _lane_batch()
    eps_v = np.full(ids.shape[0], eps, np.float32)
    want = ops.fused_round_batch(vectors, ids, scores, Ks, eps_v, k, "cos",
                                 impl="ref")
    got = ops.fused_round_batch(vectors, ids, scores, Ks, eps_v, k, "cos",
                                impl="interpret")
    _assert_rounds_equal(got, want)


@pytest.mark.parametrize("metric", ["ip", "l2"])
def test_fused_round_interpret_parity_metrics(metric):
    vectors, ids, scores, Ks = _lane_batch(seed=12)
    eps_v = np.asarray(RNG.uniform(0.2, 0.7, size=ids.shape[0]), np.float32)
    want = ops.fused_round_batch(vectors, ids, scores, Ks, eps_v, 5, metric,
                                 impl="ref")
    got = ops.fused_round_batch(vectors, ids, scores, Ks, eps_v, 5, metric,
                                impl="interpret")
    _assert_rounds_equal(got, want)


def test_fused_round_matches_per_stage_chain():
    """The fused op reproduces the per-stage dispatch chain it replaced in
    ``ProgressiveEngine._pgs_round`` bit-for-bit: _mask_prefix ->
    _batched_adjacency -> greedy_diversify_batch -> host extraction."""
    vectors, ids, scores, Ks = _lane_batch(seed=13)
    B, W = ids.shape
    k = 6
    eps_v = jnp.asarray(RNG.uniform(0.3, 0.8, size=B), jnp.float32)

    sel_ids, sel_sc, count, cert = ops.fused_round_batch(
        vectors, ids, scores, Ks, eps_v, k, "cos", impl="ref")

    ids_m, sc_m = _mask_prefix(jnp.asarray(ids), jnp.asarray(scores),
                               jnp.asarray(Ks, jnp.int32))
    adj = _batched_adjacency(vectors, ids_m, eps_v, "cos")
    sel, cnt = ops.greedy_diversify_batch(sc_m, adj, k, valid=ids_m >= 0,
                                          impl="ref")
    sel_np, ids_np, sc_np = (np.asarray(sel), np.asarray(ids_m),
                             np.asarray(sc_m))
    for b in range(B):
        s = sel_np[b]
        np.testing.assert_array_equal(
            np.asarray(sel_ids)[b],
            np.where(s >= 0, ids_np[b][np.maximum(s, 0)], -1))
        np.testing.assert_array_equal(
            np.asarray(sel_sc)[b],
            np.where(s >= 0, sc_np[b][np.maximum(s, 0)], 0.0))
    np.testing.assert_array_equal(np.asarray(count), np.asarray(cnt))
    # certificate inputs: total = selected-score sum, s_K = worst kept score
    np.testing.assert_array_equal(
        np.asarray(cert)[:, 0],
        np.asarray(jnp.sum(jnp.asarray(np.asarray(sel_sc)), axis=1)))
    valid = ids_np >= 0
    want_sK = np.where(valid.any(1),
                       np.min(np.where(valid, sc_np, np.inf), axis=1),
                       -np.inf)
    np.testing.assert_array_equal(np.asarray(cert)[:, 1], want_sK)


def test_fused_round_lane_oracle_consistency():
    """Batched ref path rows == the documented per-lane ``ref.fused_round``
    oracle applied lane by lane."""
    vectors, ids, scores, Ks = _lane_batch(B=4, seed=14)
    eps_v = np.asarray([0.4, 0.5, 0.6, 0.7], np.float32)
    got = ops.fused_round_batch(vectors, ids, scores, Ks, eps_v, 5, "cos",
                                impl="ref")
    for b in range(4):
        want = ref.fused_round(vectors, jnp.asarray(ids[b]),
                               jnp.asarray(scores[b]), int(Ks[b]),
                               float(eps_v[b]), 5, "cos")
        for name, g, w in zip(("sel_ids", "sel_scores", "count", "cert"),
                              got, want):
            np.testing.assert_array_equal(np.asarray(g)[b], np.asarray(w),
                                          err_msg=f"lane {b}: {name}")


def test_fused_round_empty_and_tiny_lanes():
    """All-sentinel lanes pick nothing; a one-candidate lane picks it."""
    vectors, ids, scores, Ks = _lane_batch(B=4, seed=15)
    ids[0], scores[0], Ks[0] = -1, -np.inf, 0           # empty lane
    ids[1, 1:], scores[1, 1:], Ks[1] = -1, -np.inf, 1   # single candidate
    eps_v = np.full(4, 0.5, np.float32)
    for impl in ("ref", "interpret"):
        sel_ids, sel_sc, count, cert = ops.fused_round_batch(
            vectors, ids, scores, Ks, eps_v, 5, "cos", impl=impl)
        assert int(np.asarray(count)[0]) == 0
        assert np.all(np.asarray(sel_ids)[0] == -1)
        assert np.asarray(cert)[0, 1] == -np.inf
        assert int(np.asarray(count)[1]) == 1
        assert int(np.asarray(sel_ids)[1, 0]) == int(ids[1, 0])


def test_engine_interpret_matches_ref_oracle():
    """Contract #12 pinning test: end-to-end engine results with the fused
    round on the interpret-mode Pallas kernel are bit-identical to the jnp
    oracle path."""
    rng = np.random.default_rng(21)
    x = _normalize(rng.normal(size=(400, 16)))
    graph = build_knn_graph(x, metric="cos", M=8)
    qs = _normalize(x[rng.integers(0, 400, 4)]
                    + 0.05 * rng.normal(size=(4, 16)).astype(np.float32))
    want = batch_pss(graph, qs, 5, 0.5, ef=10)
    got = batch_pss(graph, qs, 5, 0.5, ef=10, kernel_impl="interpret")
    np.testing.assert_array_equal(np.asarray(want.ids), np.asarray(got.ids))
    np.testing.assert_array_equal(np.asarray(want.scores),
                                  np.asarray(got.scores))
    np.testing.assert_array_equal(np.asarray(want.totals),
                                  np.asarray(got.totals))
    np.testing.assert_array_equal(np.asarray(want.stats.certified),
                                  np.asarray(got.stats.certified))
