"""Theorem-2 audit/recheck edge cases (core/theorems.py) against a
brute-force per-query oracle: empty frontiers, k=1 (infinite slack),
exact-tie similarities at eps (strict adjacency), and rechecking a
frontier under a *different* query than the one that built it — the
semantic result cache's revalidation primitive (contract 14)."""
import itertools
import math

import numpy as np

from repro.core import theorems


def _adj(vecs: np.ndarray, eps: float) -> np.ndarray:
    """Oracle G^eps adjacency for metric 'ip': strictly > eps, no diag."""
    sims = vecs @ vecs.T
    adj = sims > eps
    np.fill_diagonal(adj, False)
    return adj


def _brute_best(scores: np.ndarray, adj: np.ndarray, k: int):
    """Exhaustive optimal independent sets of sizes 1..k: (totals, sets)."""
    K = len(scores)
    totals = [-np.inf] * k
    sets: list = [None] * k
    for size in range(1, k + 1):
        for comb in itertools.combinations(range(K), size):
            if any(not np.isfinite(scores[c]) for c in comb):
                continue
            if any(adj[a, b] for a, b in itertools.combinations(comb, 2)):
                continue
            tot = float(sum(scores[c] for c in comb))
            if tot > totals[size - 1]:
                totals[size - 1], sets[size - 1] = tot, comb
    return totals, sets


def _brute_certified(scores: np.ndarray, adj: np.ndarray, k: int) -> bool:
    """Theorem 2 by hand: min_{0<i<k} (S_k - S_i)/(k-i) > s_K."""
    totals, _ = _brute_best(scores, adj, k)
    if not np.isfinite(totals[k - 1]):
        return False
    s_K = float(scores[-1])
    if k == 1:
        return True                     # minValue is +inf
    gaps = [(totals[k - 1] - totals[i - 1]) / (k - i)
            for i in range(1, k) if np.isfinite(totals[i - 1])]
    return min(gaps, default=math.inf) > s_K


def test_recheck_empty_frontier_never_certifies():
    X = np.eye(4, dtype=np.float32)
    cert, sel = theorems.theorem2_recheck(
        X, "ip", np.array([], np.int32), np.array([], np.float32), 0.5, 3)
    assert not cert and sel.shape == (3,) and (sel == -1).all()
    # all-padding is the same case: there is no s_K to bound
    cert, sel = theorems.theorem2_recheck(
        X, "ip", np.full(5, -1, np.int32), np.zeros(5, np.float32), 0.5, 3)
    assert not cert and (sel == -1).all()


def test_audit_k1_infinite_slack():
    """k=1 has no gap terms: minValue is +inf, the certificate always holds
    over a nonempty frontier, and the slack-derived threshold is infinite
    (the cache caps it with max_drift)."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(8, 4)).astype(np.float32)
    q = rng.normal(size=4).astype(np.float32)
    sc = (X @ q).astype(np.float32)
    order = np.argsort(-sc, kind="stable")[:5]
    cert, sel, min_value, s_K = theorems.theorem2_audit(
        X, "ip", order.astype(np.int32), sc[order], 0.0, 1)
    assert cert and math.isinf(min_value)
    assert sel[0] == order[0]           # the global argmax
    assert theorems.theorem2_slack_threshold(min_value - s_K, 1) == math.inf


def test_exact_tie_at_eps_is_not_an_edge():
    """Definition 2 is strict: sim(u, v) == eps leaves u-v *absent* from
    G^eps, so an exact-tie pair is a feasible diverse set."""
    eps = 0.5
    u = np.array([1.0, 0.0], np.float32)
    v = np.array([eps, math.sqrt(1 - eps * eps)], np.float32)
    w = np.array([0.99, 0.14106912], np.float32)     # <u,w> > eps: an edge
    X = np.stack([u, v, w])
    assert abs(float(u @ v) - eps) < 1e-7
    # frontier sorted by score for the query u: u, w, v
    q = u
    sc = (X @ q).astype(np.float32)
    order = np.argsort(-sc, kind="stable").astype(np.int32)
    cert, sel, min_value, s_K = theorems.theorem2_audit(
        X, "ip", order, sc[order], eps, 2)
    # {u, v} is independent (tie is NOT an edge) and outscores any set
    # containing w's neighbors-constrained alternatives
    assert set(map(int, sel)) == {0, 1}
    totals, sets = _brute_best(sc[order].astype(np.float64),
                               _adj(X, eps)[order][:, order], 2)
    assert set(order[list(sets[1])]) == {0, 1}
    assert cert == _brute_certified(sc[order], _adj(X, eps)[order][:, order],
                                    2)


def test_recheck_matches_brute_oracle_random():
    """Random small frontiers: audit's certificate flag and selection must
    match the exhaustive oracle evaluated per query."""
    rng = np.random.default_rng(7)
    for trial in range(20):
        K, k = int(rng.integers(3, 8)), int(rng.integers(2, 4))
        X = rng.normal(size=(K + 4, 3)).astype(np.float32)
        q = rng.normal(size=3).astype(np.float32)
        eps = float(rng.uniform(-0.5, 1.5))
        sc = (X @ q).astype(np.float32)
        order = np.argsort(-sc, kind="stable")[:K].astype(np.int32)
        cert, sel, min_value, s_K = theorems.theorem2_audit(
            X, "ip", order, sc[order], eps, k)
        adj = _adj(X, eps)[order][:, order]
        assert cert == _brute_certified(sc[order], adj, k), (trial, eps)
        totals, sets = _brute_best(sc[order].astype(np.float64), adj, k)
        if sets[k - 1] is not None:
            assert math.isclose(
                float(sc[sel[sel >= 0]].sum()), totals[k - 1],
                rel_tol=1e-5), trial


def test_recheck_under_different_query():
    """The cache's revalidation shape: a frontier recorded under query qa,
    rescored and rechecked under qb — the recheck must behave exactly like
    a per-query oracle on (frontier, qb scores), for drifts inside AND
    outside the slack threshold."""
    rng = np.random.default_rng(3)
    X = rng.normal(size=(32, 6)).astype(np.float32)
    X /= np.linalg.norm(X, axis=1, keepdims=True)
    qa = X[0] + rng.normal(size=6).astype(np.float32) * 0.05
    eps, k, K = 0.9, 3, 12
    sca = (X @ qa).astype(np.float32)
    order = np.argsort(-sca, kind="stable")[:K].astype(np.int32)
    cert_a, sel_a, mv, sK = theorems.theorem2_audit(
        X, "ip", order, sca[order], eps, k)
    assert cert_a, "fixture must produce a certified frontier"
    slack = mv - sK
    L = float(np.linalg.norm(X, axis=1).max())
    thr = theorems.theorem2_slack_threshold(slack, k, L)
    assert 0.0 < thr < math.inf
    for scale, must_hold in ((0.5, True), (50.0, None)):
        delta = rng.normal(size=6)
        delta = (delta / np.linalg.norm(delta) * thr * scale).astype(
            np.float32)
        qb = qa + delta
        scb = (X[order] @ qb).astype(np.float32)
        ob = np.argsort(-scb, kind="stable")
        ids_b, sc_b = order[ob], scb[ob]
        cert_b, sel_b = theorems.theorem2_recheck(
            X, "ip", ids_b, sc_b, eps, k)
        adj = _adj(X, eps)[ids_b][:, ids_b]
        assert cert_b == _brute_certified(sc_b, adj, k)
        if must_hold:    # inside the proven drift bound: must re-certify
            assert cert_b
            totals, sets = _brute_best(sc_b.astype(np.float64), adj, k)
            assert set(map(int, sel_b)) == set(map(int, ids_b[list(
                sets[k - 1])]))
