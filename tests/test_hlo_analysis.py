"""HLO analyzer: trip-count-aware flop counting and collective parsing."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import HloModule, analyze, shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("(s32[], f32[2,3])") == 4 + 24
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("pred[7]") == 7


def test_scan_trip_count_flops():
    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    res = analyze(comp.as_text())
    want = 6 * 2 * 64 * 128 * 128
    assert abs(res["flops"] - want) / want < 0.02


def test_comment_stripping_in_tuples():
    txt = """
ENTRY %main (p: f32[4,4]) -> f32[4,4] {
  %p = f32[4,4]{1,0} parameter(0)
  %t = (f32[4,4]{1,0}, /*index=1*/f32[4,4]{1,0}) tuple(%p, %p)
  ROOT %dot = f32[4,4]{1,0} dot(%p, %p), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    mod = HloModule(txt)
    assert mod.entry == "main"
    assert mod.entry_cost().flops == 2 * 4 * 4 * 4


def test_nested_while_multiplication():
    def f(x):
        def outer(h, _):
            def inner(g, _):
                return jnp.tanh(g @ g), None
            g, _ = jax.lax.scan(inner, h, None, length=3)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    comp = jax.jit(f).lower(x).compile()
    res = analyze(comp.as_text())
    want = 5 * 3 * 2 * 32 * 32 * 32
    assert abs(res["flops"] - want) / want < 0.05
