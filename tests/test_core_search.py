"""Beam search, progressive search, queue invariants, theorems."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import beam_search as bs
from repro.core import queue as qmod
from repro.core.theorems import theorem1_K, theorem2_min_value, theorem3_recall_bound
from repro.index.flat import exact_topk


# ------------------------------------------------------------ queue ----
@given(st.lists(st.tuples(st.integers(0, 50), st.floats(-5, 5)),
                min_size=0, max_size=30))
@settings(max_examples=40, deadline=None)
def test_queue_insert_invariants(entries):
    q = qmod.make_queue(16)
    ids = jnp.asarray([e[0] for e in entries] or [0], jnp.int32)
    scores = jnp.asarray([e[1] for e in entries] or [0.0], jnp.float32)
    mask = jnp.ones(ids.shape, bool) if entries else jnp.zeros((1,), bool)
    q = qmod.insert(q, ids, scores, mask)
    got_ids = np.asarray(q.ids)
    got_scores = np.asarray(q.scores)
    valid = got_ids >= 0
    # sorted descending
    vs = got_scores[valid]
    assert np.all(np.diff(vs) <= 1e-6)
    # no duplicate ids
    assert len(set(got_ids[valid].tolist())) == valid.sum()
    # padding at the back
    if valid.any():
        assert valid[: valid.sum()].all()


def test_queue_insert_dedup():
    q = qmod.make_queue(8)
    q = qmod.insert(q, jnp.asarray([3, 4], jnp.int32),
                    jnp.asarray([1.0, 2.0], jnp.float32),
                    jnp.ones(2, bool))
    q = qmod.insert(q, jnp.asarray([3, 5], jnp.int32),
                    jnp.asarray([9.0, 0.5], jnp.float32),
                    jnp.ones(2, bool))
    ids = np.asarray(q.ids)
    assert (ids == 3).sum() == 1  # not re-inserted


# --------------------------------------------------------- beam search ----
def test_beam_search_exact_on_full_graph(clustered_data, small_graph):
    rng = np.random.default_rng(1)
    recalls = []
    for _ in range(10):
        q = clustered_data[rng.integers(len(clustered_data))] \
            + rng.normal(size=clustered_data.shape[1]).astype(np.float32) * 0.05
        ids, _ = bs.beam_search(small_graph, jnp.asarray(q), k=10, L=80)
        gt, _ = exact_topk(q[None], clustered_data, 10, "l2")
        recalls.append(
            len(set(np.asarray(ids).tolist()) & set(gt[0].tolist())) / 10)
    assert np.mean(recalls) >= 0.9


def test_progressive_resume_matches_oneshot(clustered_data, small_graph):
    q = jnp.asarray(clustered_data[7] + 0.02)
    # one shot to 120 stable
    s1 = bs.init_state(small_graph, q, 256)
    s1 = bs.run_search(small_graph, q, s1, stable_limit=120)
    # two-phase: 40 then resume to 120 (queue reuse)
    s2 = bs.init_state(small_graph, q, 256)
    s2 = bs.run_search(small_graph, q, s2, stable_limit=40)
    s2 = bs.run_search(small_graph, q, s2, stable_limit=120)
    n = 120
    np.testing.assert_array_equal(np.asarray(s1.queue.ids[:n]),
                                  np.asarray(s2.queue.ids[:n]))


def test_rebuild_for_growth_exact(clustered_data, small_graph):
    q = jnp.asarray(clustered_data[3] + 0.01)
    s = bs.init_state(small_graph, q, 64)
    s = bs.run_search(small_graph, q, s, stable_limit=48)
    grown = bs.rebuild_for_growth(small_graph, q, s, 256)
    # all previously stable entries survive with same order
    k = int(qmod.stable_count(s.queue))
    np.testing.assert_array_equal(np.asarray(s.queue.ids[:k]),
                                  np.asarray(grown.queue.ids[:k]))
    # continuing from grown matches a fresh larger-capacity run
    s_big = bs.init_state(small_graph, q, 256)
    s_big = bs.run_search(small_graph, q, s_big, stable_limit=150)
    g2 = bs.run_search(small_graph, q, grown, stable_limit=150)
    np.testing.assert_array_equal(np.asarray(s_big.queue.ids[:150]),
                                  np.asarray(g2.queue.ids[:150]))


# ------------------------------------------------------------ theorems ----
def _diversity_graph(rng, n, dens):
    scores = np.sort(rng.normal(size=n) * 2)[::-1]
    adj = np.triu(rng.random((n, n)) < dens, 1)
    return scores, adj | adj.T


@given(st.integers(0, 10_000), st.integers(2, 4), st.floats(0.05, 0.5))
@settings(max_examples=30, deadline=None)
def test_theorem1_sufficiency(seed, k, dens):
    """If K >= Theorem-1 bound, top-K contains an optimal diverse set of the
    full graph."""
    from repro.core.div_astar_ref import div_astar_ref

    rng = np.random.default_rng(seed)
    n = 24
    scores, adj = _diversity_graph(rng, n, dens)
    deg = adj.sum(1)
    K = int(theorem1_K(jnp.asarray(deg), k))
    K = min(K, n)
    # optimal within top-K candidates
    sets_k, sc_k, _ = div_astar_ref(scores[:K], adj[:K, :K], k)
    # global optimal
    sets_n, sc_n, _ = div_astar_ref(scores, adj, k)
    if np.isfinite(sc_n[k - 1]):
        # theorem computed from FULL degree info: the top-K prefix suffices
        assert sc_k[k - 1] >= sc_n[k - 1] - 1e-9
@given(st.integers(0, 10_000), st.integers(2, 5))
@settings(max_examples=30, deadline=None)
def test_theorem2_certificate(seed, k):
    """If minValue > s_K then the top-K optimum is the global optimum."""
    from repro.core.div_astar_ref import div_astar_ref

    rng = np.random.default_rng(seed)
    n = 26
    scores, adj = _diversity_graph(rng, n, 0.3)
    for K in range(k, n):
        sets_k, sc_k, _ = div_astar_ref(scores[:K], adj[:K, :K], k)
        if not np.isfinite(sc_k[k - 1]):
            continue
        mv = float(theorem2_min_value(jnp.asarray(sc_k, jnp.float32), k))
        if mv > scores[K - 1]:
            _, sc_n, _ = div_astar_ref(scores, adj, k)
            assert abs(sc_k[k - 1] - sc_n[k - 1]) < 1e-6
            break


def test_theorem3_monotone():
    assert theorem3_recall_bound(100, 5, 0.0) == 1.0
    assert theorem3_recall_bound(100, 5, 0.01) > \
        theorem3_recall_bound(100, 5, 0.05)
    assert 0.0 <= theorem3_recall_bound(50, 10, 0.1) <= 1.0
