"""Cost-aware admission policies (serve/policies.py + the scheduler's
policy layer): cost-model math and calibration, FIFO bit-compat, drr /
slo_cost determinism (incl. cross-backend), fairness, and shed/defer
semantics. The determinism tests inject a fake clock, making every policy
decision a pure function of the request trace — the contract
``docs/ARCHITECTURE.md`` states for the policy layer."""
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core.pss import pss
from repro.index.flat import build_knn_graph
from repro.serve.policies import (DrrPolicy, ExpansionCostModel, FifoPolicy,
                                  SloCostPolicy, make_policy, theorem1_prior)
from repro.serve.scheduler import (LaneScheduler, RequestDeferred,
                                   RequestShed)
from repro.sharded_search import ShardedEngine, build_sharded_index


class FakeClock:
    """Strictly-increasing deterministic clock: with it, timestamps (and so
    EDF deadlines, learned seconds-per-expansion, and stats) depend only on
    the call sequence, never on wall time."""

    def __init__(self, dt: float = 1e-3):
        self.t, self.dt = 0.0, dt

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


@pytest.fixture(scope="module")
def graph_and_queries():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(12, 24)) * 2.0
    x = (centers[rng.integers(0, 12, 600)]
         + rng.normal(size=(600, 24)) * 0.3).astype(np.float32)
    graph = build_knn_graph(x, metric="l2", M=8)
    qs = (x[rng.integers(0, 600, 12)]
          + rng.normal(size=(12, 24)).astype(np.float32) * 0.05)
    return graph, qs.astype(np.float32)


@pytest.fixture(scope="module")
def sharded_world():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(256, 12)).astype(np.float32)
    index = build_sharded_index(x, 1, "ip", M=8)
    mesh = make_mesh((1,), ("data",))
    qs = rng.normal(size=(12, 12)).astype(np.float32)
    return x, index, mesh, qs


def admission_order(sched) -> list[int]:
    """Request ids in the order lanes admitted them (FakeClock timestamps
    are strictly increasing, so t_admit is a total order)."""
    done = [r for r in sched.completed if r.t_admit is not None]
    return [r.rid for r in sorted(done, key=lambda r: r.t_admit)]


# ------------------------------------------------------------ cost model ----

def test_theorem1_prior_k_monotone():
    prev = 0.0
    for k in (1, 2, 4, 8, 16):
        epr, rounds = theorem1_prior(k)
        assert epr > 0 and rounds >= 1
        assert epr * rounds >= prev
        prev = epr * rounds


def test_cost_model_bucketing():
    m = ExpansionCostModel()
    assert m.bucket(5, 0.8, "pss") == m.bucket(8, 0.8, "pss")   # pow2 k
    assert m.bucket(5, 0.8, "pss") != m.bucket(5, 0.8, "pds")
    assert m.bucket(5, 0.8, "pss") != m.bucket(5, 0.5, "pss")
    banded = ExpansionCostModel(eps_bands=(0.4, 0.7))
    assert banded.bucket(5, 0.1, "pss") == banded.bucket(5, 0.39, "pss")
    assert banded.bucket(5, 0.5, "pss") == banded.bucket(5, 0.69, "pss")
    assert banded.bucket(5, 0.1, "pss") != banded.bucket(5, 0.9, "pss")


def test_cost_model_prior_then_learns():
    m = ExpansionCostModel()
    cold = m.predict_expansions(4, 0.8, "pss")
    assert cold > 0     # Theorem-1 prior: estimates exist before traffic
    assert m.predict_service(4, 0.8, "pss") == 0.0   # no timing prior
    for _ in range(30):
        m.observe(4, 0.8, "pss", expansions=1000, rounds=2, service=0.5)
    assert m.predict_expansions(4, 0.8, "pss") == pytest.approx(1000, rel=.01)
    assert m.predict_rounds(4, 0.8, "pss") == pytest.approx(2, rel=.01)
    assert m.predict_service(4, 0.8, "pss") == pytest.approx(0.5, rel=.02)
    # constant workload -> calibration error collapses toward zero
    assert m.calibration_error() < 0.05
    # other buckets still answer from the prior
    assert m.predict_expansions(16, 0.1, "pds") > 0


def test_cost_model_freeze():
    m = ExpansionCostModel()
    m.observe(4, 0.8, "pss", expansions=100, rounds=1, service=0.1)
    before = m.predict_expansions(4, 0.8, "pss")
    m.freeze()
    m.observe(4, 0.8, "pss", expansions=9000, rounds=9, service=9.0)
    assert m.predict_expansions(4, 0.8, "pss") == before
    assert m.stats()["frozen"]


def test_make_policy_and_bind_guard(graph_and_queries):
    graph, _ = graph_and_queries
    assert isinstance(make_policy("fifo"), FifoPolicy)
    assert isinstance(make_policy("drr"), DrrPolicy)
    assert isinstance(make_policy("slo_cost"), SloCostPolicy)
    pol = DrrPolicy()
    assert make_policy(pol) is pol
    with pytest.raises(ValueError):
        make_policy("edf")
    with pytest.raises(ValueError):
        DrrPolicy(quantum=0)
    s1 = LaneScheduler(graph, num_lanes=2, max_k=8, default_ef=10,
                       prewarm=False, policy=pol)
    assert s1.policy is pol
    with pytest.raises(RuntimeError):   # policies hold per-scheduler state
        LaneScheduler(graph, num_lanes=2, max_k=8, default_ef=10,
                      prewarm=False, policy=pol)


# ------------------------------------------------------- fifo bit-compat ----

MIX_KS = [5, 3, 5, 3, 5, 3, 5, 3, 5, 3, 5, 3]
MIX_EPS = [0.0, -0.5, 0.0, -0.5, 0.0, -0.5, 0.0, -0.5, 0.0, -0.5, 0.0, -0.5]


def test_fifo_admission_order_is_submission_order(graph_and_queries):
    """policy="fifo" (the default) is the pre-policy scheduler bit-exactly:
    the queue drains in submission order (results parity is pinned by
    tests/test_scheduler.py — admission order is the only new surface)."""
    graph, qs = graph_and_queries
    sched = LaneScheduler(graph, num_lanes=3, max_k=8, default_ef=10,
                          prewarm=False, max_pending=len(qs),
                          clock=FakeClock())
    sched.run(qs, MIX_KS, MIX_EPS)
    assert sched.latency_stats()["policy"] == "fifo"
    order = admission_order(sched)
    assert order == sorted(order)   # == rids in submission order


# -------------------------------------------------- drr: fairness + order ----

def _run_trace(sched, qs, ks, epss, tenants):
    for i in range(len(qs)):
        sched.submit(qs[i], int(ks[i]), float(epss[i]),
                     tenant=str(tenants[i]))
    sched.drain()
    return admission_order(sched)


def test_drr_deterministic_same_trace_same_order(graph_and_queries):
    """Same trace in -> same admission order out, with the cost model
    learning live (the EWMA updates are part of the replayed state)."""
    graph, qs = graph_and_queries
    tenants = ["light"] * 8 + ["heavy"] * 4
    orders = []
    for _ in range(2):
        sched = LaneScheduler(graph, num_lanes=2, max_k=8, default_ef=10,
                              prewarm=False, policy="drr",
                              max_pending=len(qs), clock=FakeClock())
        orders.append(_run_trace(sched, qs, MIX_KS, MIX_EPS, tenants))
    assert orders[0] == orders[1]
    assert sorted(orders[0]) == list(range(len(qs)))


def test_drr_protects_sparse_tenant_from_flood(graph_and_queries):
    """A tenant flooding cheap requests cannot starve a sparse tenant's
    expensive one: under DRR the heavy request is admitted once its deficit
    covers the predicted cost — far earlier than its FIFO position at the
    back of the flood (and later than position 0: it *is* charged more)."""
    graph, qs = graph_and_queries
    n_light = 10
    queries = np.repeat(qs[:5], 4, axis=0)[:n_light + 1]
    ks = [4] * n_light + [16]            # k=16: ~7x the predicted cost
    epss = [0.0] * (n_light + 1)
    tenants = ["light"] * n_light + ["heavy"]
    sched = LaneScheduler(graph, num_lanes=1, default_ef=10,
                          prewarm=False, policy="drr",
                          cost_model=ExpansionCostModel().freeze(),
                          max_pending=n_light + 1, clock=FakeClock())
    order = _run_trace(sched, queries, ks, epss, tenants)
    heavy_pos = order.index(n_light)
    assert 0 < heavy_pos < n_light       # interleaved, not starved to last
    st = sched.latency_stats()
    assert set(st["tenants"]) == {"heavy", "light"}
    assert st["tenants"]["heavy"]["completed"] == 1
    assert st["tenants"]["light"]["completed"] == n_light


def test_drr_results_match_solo_driver(graph_and_queries):
    """Admission *order* changes under drr; per-request *results* cannot
    (lane separability) — every result equals a fresh per-query PSS run."""
    graph, qs = graph_and_queries
    tenants = ["a", "b"] * 6
    sched = LaneScheduler(graph, num_lanes=3, max_k=8, default_ef=10,
                          prewarm=False, policy="drr", max_pending=len(qs))
    reqs = [sched.submit(qs[i], MIX_KS[i], MIX_EPS[i], tenant=tenants[i])
            for i in range(len(qs))]
    sched.drain()
    for i, req in enumerate(reqs):
        solo = pss(graph, qs[i], MIX_KS[i], MIX_EPS[i], ef=10)
        np.testing.assert_array_equal(np.asarray(solo.ids), req.result.ids)
        np.testing.assert_array_equal(np.asarray(solo.scores),
                                      req.result.scores)
        assert solo.stats.certified == req.result.stats.certified


# ------------------------------------------------------ slo_cost semantics ----

def _timed_model(sec_per_exp=1e-3, expansions=1000):
    """A model that predicts `expansions` per k=4 request at a known time
    rate — frozen, so tests control every prediction."""
    m = ExpansionCostModel()
    m.observe(4, 0.0, "pss", expansions=expansions, rounds=1,
              service=sec_per_exp * expansions)
    return m.freeze()


def test_slo_cost_sheds_hopeless_requests(graph_and_queries):
    """Predicted service alone over budget -> shed at submit, never
    enqueued, counted per tenant."""
    graph, qs = graph_and_queries
    sched = LaneScheduler(graph, num_lanes=2, max_k=8, default_ef=10,
                          prewarm=False, cost_model=_timed_model(),
                          policy=SloCostPolicy(budget=0.5),  # svc pred = 1.0s
                          clock=FakeClock())
    with pytest.raises(RequestShed):
        sched.submit(qs[0], 4, 0.0, tenant="t0")
    assert sched.try_submit(qs[1], 4, 0.0, tenant="t0") is None
    assert sched.total_shed == 2 and not sched.pending
    assert sched.latency_stats()["tenants"]["t0"]["shed"] == 2
    # a best-effort tenant (no budget) is never shed
    pol = SloCostPolicy(budget=0.5, budgets={"free": None})
    s2 = LaneScheduler(graph, num_lanes=2, max_k=8, default_ef=10,
                       prewarm=False, cost_model=_timed_model(),
                       policy=pol, clock=FakeClock())
    assert s2.try_submit(qs[0], 4, 0.0, tenant="free") is not None


def test_slo_cost_defers_backlogged_then_serves(graph_and_queries):
    """Backlog over budget -> defer (retry later succeeds); service within
    budget -> never shed. run() retries deferred submissions and completes
    the whole batch."""
    graph, qs = graph_and_queries
    make = lambda: LaneScheduler(
        graph, num_lanes=1, max_k=8, default_ef=10, prewarm=False,
        cost_model=_timed_model(), policy=SloCostPolicy(budget=2.5),
        max_pending=8, clock=FakeClock())
    sched = make()
    # predicted: svc 1.0s each, wait = backlog/lanes * 1.0s
    assert sched.try_submit(qs[0], 4, 0.0) is not None   # wait 0
    assert sched.try_submit(qs[1], 4, 0.0) is not None   # wait 1.0
    with pytest.raises(RequestDeferred):
        sched.submit(qs[2], 4, 0.0)                      # wait 2.0 + 1 > 2.5
    assert sched.total_deferred == 1
    sched.drain()
    assert sched.try_submit(qs[2], 4, 0.0) is not None   # backlog drained
    sched.drain()
    # run() self-retries deferrals: all requests come back served
    s2 = make()
    results = s2.run(qs[:6], 4, 0.0)
    assert all(r is not None for r in results)
    assert s2.total_deferred > 0          # the defer path actually fired
    assert s2.total_completed == 6


def test_slo_cost_orders_queue_by_deadline(graph_and_queries):
    """Tight-budget tenants jump the queue (EDF), lax ones drain after —
    submission order only breaks ties."""
    graph, qs = graph_and_queries
    pol = SloCostPolicy(budgets={"tight": 1.5, "lax": 60.0})
    sched = LaneScheduler(graph, num_lanes=1, max_k=8, default_ef=10,
                          prewarm=False, policy=pol,
                          cost_model=_timed_model(sec_per_exp=1e-9),
                          max_pending=8, clock=FakeClock())
    tenants = ["lax"] * 4 + ["tight"] * 2
    order = _run_trace(sched, qs[:6], [4] * 6, [0.0] * 6, tenants)
    assert order[:2] == [4, 5]            # tight deadlines first
    assert order[2:] == [0, 1, 2, 3]      # then lax, in submission order


def test_slo_cost_deterministic(graph_and_queries):
    graph, qs = graph_and_queries
    orders = []
    for _ in range(2):
        sched = LaneScheduler(
            graph, num_lanes=2, max_k=8, default_ef=10, prewarm=False,
            policy=SloCostPolicy(budgets={"tight": 1.0, "lax": 60.0}),
            cost_model=_timed_model(sec_per_exp=1e-9),
            max_pending=len(qs), clock=FakeClock())
        orders.append(_run_trace(sched, qs, [4] * len(qs), [0.0] * len(qs),
                                 ["lax", "tight"] * 6))
    assert orders[0] == orders[1]


# ------------------------------------------- backend-neutral policy layer ----

@pytest.mark.parametrize("policy_name", ["drr", "slo_cost"])
def test_policy_order_identical_across_backends(graph_and_queries,
                                                sharded_world, policy_name):
    """Admission order is scheduler-level state: with a frozen cost model
    the same trace yields the *identical* order over the single-host
    ProgressiveEngine and a 1-shard ShardedEngine — policies never peek at
    the backend (per-request results are covered by each backend's own
    parity contract)."""
    graph, gqs = graph_and_queries
    x, index, mesh, sqs = sharded_world
    tenants = ["light", "light", "heavy"] * 4
    ks = [4, 4, 8] * 4

    def make_policy_inst():
        if policy_name == "drr":
            return DrrPolicy()
        return SloCostPolicy(budgets={"heavy": 1.0, "light": 30.0})

    single = LaneScheduler(graph, num_lanes=2, max_k=8, default_ef=10,
                           prewarm=False, policy=make_policy_inst(),
                           cost_model=ExpansionCostModel().freeze(),
                           max_pending=12, clock=FakeClock())
    order_single = _run_trace(single, gqs, ks, [0.0] * 12, tenants)

    eng = ShardedEngine(index, x, mesh, num_lanes=2, K0=16, max_k=8)
    sharded = LaneScheduler(backend=eng, prewarm=False,
                            policy=make_policy_inst(),
                            cost_model=ExpansionCostModel().freeze(),
                            max_pending=12, clock=FakeClock())
    order_sharded = _run_trace(sharded, sqs, ks, [4.0] * 12, tenants)

    assert order_single == order_sharded
    assert single.total_completed == sharded.total_completed == 12


# ------------------------------------------------------- per-tenant stats ----

def test_per_tenant_stats_and_fairness(graph_and_queries):
    graph, qs = graph_and_queries
    sched = LaneScheduler(graph, num_lanes=3, max_k=8, default_ef=10,
                          prewarm=False, policy="drr",
                          max_pending=len(qs), clock=FakeClock())
    sched.run(qs, 5, 0.0, tenants=["a"] * 6 + ["b"] * 6)
    st = sched.latency_stats()
    assert set(st["tenants"]) == {"a", "b"}
    for t in st["tenants"].values():
        assert t["completed"] == 6 and t["shed"] == 0 and t["deferred"] == 0
        assert t["p99_latency"] >= t["p50_latency"] >= 0
        assert 0 < t["fairness"] <= 1
    assert 0 < st["tenant_fairness"] <= 1
    assert st["completed"] == 12
    assert st["cost_calibration_error"] >= 0


# ------------------------------------------------------ calibration (slow) ----

#: documented tolerance for the 10k-graph calibration test: after ~48 mixed
#: requests the EWMA relative expansion-prediction error must be below this
#: (measured ~0.1-0.25 on the fixture; generous headroom for EWMA noise)
CALIBRATION_TOL = 0.5


@pytest.mark.slow
def test_cost_model_calibration_converges_10k():
    """Predicted vs actual expansions converge on real traffic: serve a
    mixed-(k, eps) stream on the 10k graph and require the model's running
    calibration error under CALIBRATION_TOL — the bound docs/ARCHITECTURE.md
    cites for cost-driven scheduling being meaningful at all."""
    rng = np.random.default_rng(5)
    n, d = 10_000, 32
    centers = rng.normal(size=(64, d)) * 0.25
    x = centers[rng.integers(0, 64, n)] + rng.normal(size=(n, d))
    x = (x / np.linalg.norm(x, axis=1, keepdims=True)).astype(np.float32)
    graph = build_knn_graph(x, metric="cos", M=8)
    B = 48
    qs = x[rng.integers(0, n, B)] \
        + rng.normal(size=(B, d)).astype(np.float32) * 0.05
    ks = np.where(np.arange(B) % 2 == 0, 5, 10)
    epss = np.where(rng.random(B) < 0.25, 0.8, 0.5)
    sched = LaneScheduler(graph, num_lanes=8, default_ef=10, prewarm=False,
                          max_pending=B)
    sched.run(qs.astype(np.float32), ks, epss)
    err = sched.cost_model.calibration_error()
    stats = sched.cost_model.stats()
    assert stats["observations"] == B
    assert err < CALIBRATION_TOL, (
        f"calibration error {err:.3f} >= {CALIBRATION_TOL} "
        f"(model stats: {stats})")


# ------------------------------------------------- weighted DRR (quanta) ----

def test_drr_quanta_validation():
    with pytest.raises(ValueError, match="positive"):
        DrrPolicy(quanta={"gold": 0.0})
    with pytest.raises(ValueError, match="positive"):
        DrrPolicy(quanta={"gold": -5.0})


def test_drr_weighted_quanta_2to1_shares(graph_and_queries):
    """quanta={tenant: q} buys weighted shares: a tenant with twice the
    quantum is admitted 2:1 against an equal-cost competitor while both
    have backlog (and the tail drains the rest — conservation holds)."""
    graph, qs = graph_and_queries
    n_each = 12
    queries = np.repeat(qs, 2, axis=0)[: 2 * n_each]
    m = ExpansionCostModel()
    m.observe(4, 0.0, "pss", expansions=100, rounds=1, service=0.1)
    pol = DrrPolicy(quantum=100.0, quanta={"gold": 200.0})
    sched = LaneScheduler(graph, num_lanes=1, default_ef=10, prewarm=False,
                          policy=pol, cost_model=m.freeze(),
                          max_pending=2 * n_each, clock=FakeClock())
    tenants = ["gold", "bronze"] * n_each
    order = _run_trace(sched, queries, [4] * 2 * n_each,
                       [0.0] * 2 * n_each, tenants)
    by_tenant = [tenants[i] for i in order]
    # while both tenants have backlog, every DRR cycle admits 2 gold + 1
    # bronze (equal per-request cost, 2:1 quanta)
    for n in (3, 6, 9, 12):
        assert by_tenant[:n].count("gold") == 2 * n // 3, by_tenant
    st = sched.latency_stats()
    assert st["tenants"]["gold"]["completed"] == n_each
    assert st["tenants"]["bronze"]["completed"] == n_each


# ------------------------------------------- cost-model JSON persistence ----

def test_cost_model_save_load_round_trip(tmp_path):
    """save() -> load() reconstructs the model bit-exactly: identical
    predictions (admitted and offered), calibration, and stats — the
    launch/serve.py --cost-model-path warm-start contract."""
    m = ExpansionCostModel(K0=16, alpha=0.5, eps_bands=(0.25, 0.75))
    rng = np.random.default_rng(4)
    for i in range(20):
        k = int(rng.integers(2, 12))
        eps = float(rng.uniform(0.0, 1.0))
        m.observe(k, eps, "pss", expansions=float(rng.integers(50, 500)),
                  rounds=int(rng.integers(1, 5)),
                  service=float(rng.uniform(0.01, 0.2)))
        m.observe_cache(k, eps, "pss", hit=bool(rng.random() < 0.5))
    path = tmp_path / "model.json"
    m.save(path)
    m2 = ExpansionCostModel.load(path)
    assert m2.stats() == m.stats()
    for k in (2, 5, 11):
        for eps in (0.1, 0.5, 0.9):
            assert m2.predict_expansions(k, eps, "pss") \
                == m.predict_expansions(k, eps, "pss")
            assert m2.predict_expansions(k, eps, "pss", offered=True) \
                == m.predict_expansions(k, eps, "pss", offered=True)
            assert m2.predict_service(k, eps, "pss") \
                == m.predict_service(k, eps, "pss")
            assert m2.predict_hit_rate(k, eps, "pss") \
                == m.predict_hit_rate(k, eps, "pss")
    # the loaded model keeps learning from where the original stopped
    m.observe(3, 0.5, "pss", expansions=77.0, rounds=2, service=0.05)
    m2.observe(3, 0.5, "pss", expansions=77.0, rounds=2, service=0.05)
    assert m2.predict_expansions(3, 0.5, "pss") \
        == m.predict_expansions(3, 0.5, "pss")


def test_cost_model_load_rejects_unknown_version(tmp_path):
    m = ExpansionCostModel()
    path = tmp_path / "model.json"
    m.save(path)
    import json
    doc = json.loads(path.read_text())
    doc["version"] = 99
    path.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="version"):
        ExpansionCostModel.load(path)


# ----------------------------------------------- offered-vs-admitted price ----

def test_offered_price_discounts_by_hit_rate():
    """With no cache observations the offered and admitted prices agree
    exactly (pre-cache pricing is reproduced bit-for-bit); once hits are
    observed, only the *offered* price is discounted — an in-hand admitted
    request already missed the cache and pays full freight."""
    m = ExpansionCostModel()
    m.observe(5, 0.0, "pss", expansions=200.0, rounds=2, service=0.1)
    full = m.predict_expansions(5, 0.0, "pss")
    assert m.predict_expansions(5, 0.0, "pss", offered=True) == full
    for _ in range(8):
        m.observe_cache(5, 0.0, "pss", hit=True)
    rate = m.predict_hit_rate(5, 0.0, "pss")
    assert 0.0 < rate <= 1.0
    assert m.predict_expansions(5, 0.0, "pss") == full      # unchanged
    assert m.predict_expansions(5, 0.0, "pss", offered=True) \
        == pytest.approx(full * (1.0 - rate))
    # frozen models ignore further cache observations too
    m.freeze()
    before = m.predict_hit_rate(5, 0.0, "pss")
    m.observe_cache(5, 0.0, "pss", hit=False)
    assert m.predict_hit_rate(5, 0.0, "pss") == before
