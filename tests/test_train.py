"""Training substrate: loop convergence, checkpoint/resume, fault restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.train import checkpoint as ckpt
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.optimizer import AdamW, cosine_schedule

pytestmark = pytest.mark.slow  # compile-heavy; CI runs these in the slow job


def _mesh():
    from repro.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))


def test_synthetic_data_deterministic_and_disjoint():
    d0 = SyntheticLM(97, 16, 8, seed=1, num_hosts=2, host_id=0)
    d1 = SyntheticLM(97, 16, 8, seed=1, num_hosts=2, host_id=1)
    b0a, b0b = d0.batch_at(3), d0.batch_at(3)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])
    assert not np.array_equal(d0.batch_at(3)["tokens"],
                              d1.batch_at(3)["tokens"])
    assert b0a["tokens"].shape == (4, 16)


def test_prefetcher_orders_steps():
    src = SyntheticLM(17, 4, 2, seed=0)
    pf = Prefetcher(src, start_step=5)
    s, b = pf.next()
    s2, _ = pf.next()
    pf.close()
    assert (s, s2) == (5, 6)


def test_adamw_reduces_loss_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = dict(w=jnp.asarray([3.0, -2.0]))
    state = opt.init(params)
    def loss(p):
        return jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2 * l0


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=0.1)


def test_checkpoint_roundtrip(tmp_path):
    tree = dict(a=jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                b=dict(c=jnp.ones(4, jnp.bfloat16)))
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    out = ckpt.restore(str(tmp_path), 7, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_digest_mismatch_rejected(tmp_path):
    tree = dict(a=jnp.ones(3))
    ckpt.save(str(tmp_path), 1, tree)
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, dict(a=jnp.ones(4)))


def test_incomplete_checkpoint_ignored(tmp_path):
    tree = dict(a=jnp.ones(3))
    ckpt.save(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_00000002.tmp")  # simulated crash mid-write
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    tree = dict(a=jnp.ones(5))
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    saver.save(3, tree)
    saver.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_train_loop_loss_decreases(tmp_path):
    from repro.train.loop import train

    cfg = get_config("qwen2-1.5b").reduced()
    rep = train(cfg, _mesh(), steps=25, global_batch=8, seq_len=16,
                ckpt_dir=str(tmp_path), ckpt_every=10, log_every=0,
                optimizer=AdamW(lr=3e-3))
    head = np.mean(rep.losses[:5])
    tail = np.mean(rep.losses[-5:])
    assert tail < head  # induction pattern is learnable


def test_train_loop_fault_restart(tmp_path):
    from repro.train.loop import train

    cfg = get_config("qwen2-1.5b").reduced()
    crashed = {"done": False}

    def fault(step):
        if step == 12 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    rep = train(cfg, _mesh(), steps=18, global_batch=8, seq_len=16,
                ckpt_dir=str(tmp_path), ckpt_every=5, log_every=0,
                fault_hook=fault)
    assert rep.restarts == 1
    assert ckpt.latest_step(str(tmp_path)) is not None
    assert np.isfinite(rep.final_loss)


def test_train_loop_resumes_from_checkpoint(tmp_path):
    from repro.train.loop import train

    cfg = get_config("mamba2-370m").reduced()
    train(cfg, _mesh(), steps=6, global_batch=4, seq_len=8,
          ckpt_dir=str(tmp_path), ckpt_every=5, log_every=0)
    rep2 = train(cfg, _mesh(), steps=8, global_batch=4, seq_len=8,
                 ckpt_dir=str(tmp_path), ckpt_every=5, log_every=0)
    assert rep2.steps_run == 3  # resumed at 5, ran to 8
