"""Similarity-space properties (paper Eqs. 5-7) via hypothesis."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.similarity import pairwise_sim, query_sim, sim_one

vecs = st.lists(st.floats(-5, 5, allow_nan=False), min_size=4, max_size=4)


@given(vecs, vecs)
@settings(max_examples=50, deadline=None)
def test_symmetry(u, v):
    u = jnp.asarray(u, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    for metric in ("l2", "ip", "cos"):
        a = float(sim_one(u, v, metric))
        b = float(sim_one(v, u, metric))
        assert abs(a - b) < 1e-4


@given(vecs)
@settings(max_examples=30, deadline=None)
def test_self_similarity_is_max(u):
    u = jnp.asarray(u, jnp.float32)
    if float(jnp.linalg.norm(u)) < 1e-3:
        return
    # fp cancellation in ||u||^2+||v||^2-2<u,v> bounds accuracy at
    # ~sqrt(eps)*|u|; allow that
    assert float(sim_one(u, u, "l2")) >= 1.0 - 5e-3
    assert abs(float(sim_one(u, u, "cos")) - 1.0) < 1e-5


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_pairwise_matches_query(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(7, 5)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)
    for metric in ("l2", "ip", "cos"):
        m = pairwise_sim(x, y, metric)
        for i in range(7):
            row = query_sim(x[i], y, metric)
            np.testing.assert_allclose(np.asarray(m[i]), np.asarray(row),
                                       rtol=1e-5, atol=1e-5)
