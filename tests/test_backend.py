"""LaneBackend protocol: bucketing helpers, protocol conformance of both
engines, and the scheduler driving a (1-shard) ShardedEngine — the mesh
backend's full lifecycle without forced host devices (the 4-device variant
lives in tests/dist_scripts/sharded_scheduler_check.py)."""
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core.backend import LaneBackend, LaneRequest
from repro.core.bucketing import (next_pow2, pow2_group_sizes,
                                  pow2_padded_indices)
from repro.serve.scheduler import LaneScheduler, RequestShed
from repro.sharded_search import (ShardedEngine, build_sharded_index,
                                  sharded_diverse_search,
                                  sharded_progressive_diverse)


# ----------------------------------------------------------- bucketing ----

def test_next_pow2():
    assert [next_pow2(x) for x in (0, 1, 2, 3, 4, 5, 63, 64, 65)] == \
        [1, 1, 2, 4, 4, 8, 64, 64, 128]


def test_pow2_padded_indices():
    np.testing.assert_array_equal(pow2_padded_indices([3, 7, 1]),
                                  [3, 7, 1, 3])
    np.testing.assert_array_equal(pow2_padded_indices([5]), [5])
    np.testing.assert_array_equal(pow2_padded_indices([2, 4]), [2, 4])
    with pytest.raises(ValueError):
        pow2_padded_indices([])


def test_pow2_group_sizes():
    assert pow2_group_sizes(1) == [1]
    assert pow2_group_sizes(6) == [1, 2, 4, 8]
    assert pow2_group_sizes(8) == [1, 2, 4, 8]


# ----------------------------------------------- protocol conformance ----

@pytest.fixture(scope="module")
def tiny_world():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(256, 12)).astype(np.float32)
    index = build_sharded_index(x, 1, "ip", M=8)
    mesh = make_mesh((1,), ("data",))
    qs = rng.normal(size=(6, 12)).astype(np.float32)
    return x, index, mesh, qs


def test_both_engines_satisfy_protocol(tiny_world):
    from repro.core.batch_progressive import ProgressiveEngine
    from repro.index.flat import build_knn_graph

    x, index, mesh, _ = tiny_world
    graph = build_knn_graph(x, metric="ip", M=8)
    single = ProgressiveEngine(graph, num_lanes=2)
    sharded = ShardedEngine(index, x, mesh, num_lanes=2)
    for eng in (single, sharded):
        assert isinstance(eng, LaneBackend)
        assert eng.num_lanes == 2
        assert len(eng.free_lanes()) == 2 and eng.active_count() == 0
        assert eng.methods[0] in ("pss", "sharded")
        assert len(eng.signature_log) >= 0


def test_sharded_engine_lifecycle(tiny_world):
    """admit -> step -> harvest -> recycle on the mesh backend, plus the
    occupancy guards."""
    x, index, mesh, qs = tiny_world
    eng = ShardedEngine(index, x, mesh, num_lanes=2, K0=16, max_k=8)
    req = LaneRequest(q=qs[0], k=4, eps=4.0, method="sharded")
    eng.admit(0, req)
    assert eng.active_count() == 1 and list(eng.free_lanes()) == [1]
    with pytest.raises(RuntimeError):
        eng.admit(0, req)                  # occupied
    with pytest.raises(ValueError):
        eng.admit(1, LaneRequest(q=qs[0], k=99, eps=4.0, method="sharded"))
    while eng.active_count():
        eng.step()
    harvested = eng.harvest()
    assert [lane for lane, _ in harvested] == [0]
    lane, res = harvested[0]
    assert res.ids.shape == (4,) and res.stats.K_final >= 16
    with pytest.raises(RuntimeError):
        eng.recycle(1)                     # never ran
    eng.recycle(0)
    assert sorted(eng.free_lanes().tolist()) == [0, 1]


def test_scheduler_over_sharded_backend_parity(tiny_world):
    """The unmodified LaneScheduler serving queued requests over recycled
    mesh lanes: every result must equal sharded_diverse_search for that
    query at the lane's final K-budget — the mesh parity contract, which
    resume="scratch" guarantees for multi-round lanes too (the default
    resume="beam" narrows it to single-round lanes; tests/
    test_sharded_resume.py covers that contract)."""
    import jax.numpy as jnp

    x, index, mesh, qs = tiny_world
    eng = ShardedEngine(index, x, mesh, num_lanes=2, K0=16, max_k=8,
                        resume="scratch")
    sched = LaneScheduler(backend=eng, prewarm=False, max_pending=8)
    reqs = [sched.submit(qs[i], 4, 4.0) for i in range(6)]   # 6 reqs, 2 lanes
    sched.drain()
    assert all(r.result is not None for r in reqs)
    for r in reqs:
        assert r.method == "sharded"      # backend-native default
        Kf = r.result.stats.K_final
        ids, sc, cert = sharded_diverse_search(
            index, jnp.asarray(x), jnp.asarray(r.q[None]), 4, 4.0, int(Kf),
            mesh)
        np.testing.assert_array_equal(np.asarray(ids)[0], r.result.ids)
        np.testing.assert_array_equal(np.asarray(sc)[0], r.result.scores)
        assert bool(np.asarray(cert)[0]) == r.result.stats.certified
    st = sched.latency_stats()
    assert st["completed"] == 6 and st["signatures"] > 0


def test_sharded_wrapper_matches_engine(tiny_world):
    """sharded_progressive_diverse is a thin wrapper over ShardedEngine:
    same results as driving the engine by hand in lockstep."""
    x, index, mesh, qs = tiny_world
    ids, sc, cert, K_final = sharded_progressive_diverse(
        index, np.asarray(x), qs, k=4, eps=4.0, mesh=mesh, K0=16)
    assert ids.shape == (6, 4) and K_final.min() >= 16
    eng = ShardedEngine(index, x, mesh, num_lanes=6, K0=16, max_k=4)
    for lane in range(6):
        eng.admit(lane, LaneRequest(q=qs[lane], k=4, eps=4.0,
                                    method="sharded"))
    while eng.active_count():
        eng.step()
    for lane, res in eng.harvest():
        np.testing.assert_array_equal(res.ids, ids[lane])
        np.testing.assert_array_equal(res.scores, sc[lane])
        assert res.stats.certified == bool(cert[lane])
        assert res.stats.K_final == int(K_final[lane])


def test_scheduler_rejects_foreign_method(tiny_world):
    x, index, mesh, qs = tiny_world
    eng = ShardedEngine(index, x, mesh, num_lanes=2, max_k=8)
    sched = LaneScheduler(backend=eng, prewarm=False)
    with pytest.raises(ValueError):
        sched.submit(qs[0], 4, 4.0, method="pds")   # single-host-only method


def test_scheduler_graph_xor_backend(tiny_world):
    from repro.index.flat import build_knn_graph

    x, index, mesh, _ = tiny_world
    graph = build_knn_graph(x, metric="ip", M=8)
    eng = ShardedEngine(index, x, mesh, num_lanes=2)
    with pytest.raises(ValueError):
        LaneScheduler(graph, backend=eng)
    with pytest.raises(ValueError):
        LaneScheduler()


def test_shed_callback(tiny_world):
    """The SLO-shed hook drops requests at submit and counts them."""
    x, index, mesh, qs = tiny_world
    eng = ShardedEngine(index, x, mesh, num_lanes=2, max_k=8)
    sched = LaneScheduler(backend=eng, prewarm=False,
                          shed=lambda req, s: req.eps > 5.0)
    ok = sched.submit(qs[0], 4, 4.0)
    with pytest.raises(RequestShed):
        sched.submit(qs[1], 4, 9.0)
    assert sched.try_submit(qs[2], 4, 9.0) is None
    assert sched.total_shed == 2
    sched.drain()
    assert ok.result is not None
    assert sched.latency_stats()["shed"] == 2


def test_run_with_deterministic_shed_terminates(tiny_world):
    """run() must not retry a shed request (a deterministic policy would
    shed it again forever): shed slots come back as None."""
    x, index, mesh, qs = tiny_world
    eng = ShardedEngine(index, x, mesh, num_lanes=2, max_k=8)
    sched = LaneScheduler(backend=eng, prewarm=False,
                          shed=lambda req, s: req.eps > 5.0)
    results = sched.run(qs[:4], 4, [4.0, 9.0, 4.0, 9.0])
    assert [r is None for r in results] == [False, True, False, True]
    assert results[0].ids.shape == (4,)
    assert sched.total_shed == 2
