import numpy as np
import pytest

try:                                    # real hypothesis when installed (CI)
    import hypothesis  # noqa: F401
except ModuleNotFoundError:             # hermetic containers: smoke fallback
    from repro.testing import hypothesis_fallback
    hypothesis_fallback.install()


@pytest.fixture(scope="session")
def clustered_data():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(12, 24)) * 2.0
    x = (centers[rng.integers(0, 12, 600)]
         + rng.normal(size=(600, 24)) * 0.3).astype(np.float32)
    return x


@pytest.fixture(scope="session")
def small_graph(clustered_data):
    from repro.index.flat import build_knn_graph

    return build_knn_graph(clustered_data, metric="l2", M=8)


@pytest.fixture(scope="session")
def small_graph_cos(clustered_data):
    from repro.index.flat import build_knn_graph

    return build_knn_graph(clustered_data, metric="cos", M=8)
