"""div-A* exactness: python oracle vs brute force vs JAX implementation."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.div_astar import div_astar
from repro.core.div_astar_ref import brute_force_diverse, div_astar_ref


@st.composite
def instances(draw):
    n = draw(st.integers(4, 12))
    k = draw(st.integers(2, min(5, n)))
    seed = draw(st.integers(0, 2**31 - 1))
    dens = draw(st.floats(0.05, 0.7))
    rng = np.random.default_rng(seed)
    scores = rng.normal(size=n) * 3
    adj = np.triu(rng.random((n, n)) < dens, 1)
    adj = adj | adj.T
    return scores, adj, k


@given(instances())
@settings(max_examples=60, deadline=None)
def test_ref_matches_brute_force_all_sizes(inst):
    scores, adj, k = inst
    sets, sc, complete = div_astar_ref(scores, adj, k)
    assert complete
    for m in range(1, k + 1):
        bset, bsc = brute_force_diverse(scores, adj, m)
        if bset is None:
            assert sets[m - 1] is None
        else:
            assert abs(sc[m - 1] - bsc) < 1e-9
            # returned set is valid + achieves the score
            s = sets[m - 1]
            assert len(s) == m
            for a in s:
                for b in s:
                    if a != b:
                        assert not adj[a, b]


@given(instances())
@settings(max_examples=40, deadline=None)
def test_jax_matches_ref(inst):
    scores, adj, k = inst
    _, sc, _ = div_astar_ref(scores, adj, k)
    res = div_astar(jnp.asarray(scores, jnp.float32), jnp.asarray(adj), k)
    assert bool(res.complete)
    for m in range(1, k + 1):
        got = float(res.best_scores[m - 1])
        want = sc[m - 1]
        if np.isfinite(want):
            assert abs(got - want) < 1e-3
        else:
            assert not np.isfinite(got)


def test_padding_with_neg_inf():
    scores = np.array([5.0, 4.0, 3.0, -np.inf, -np.inf])
    adj = np.zeros((5, 5), bool)
    adj[0, 1] = adj[1, 0] = True
    res = div_astar(jnp.asarray(scores, jnp.float32), jnp.asarray(adj), 2)
    assert abs(float(res.best_scores[1]) - 8.0) < 1e-5  # {0, 2}
    sel = sorted(np.asarray(res.best_sets[1]).tolist())
    assert sel == [0, 2]


def test_budget_reports_incomplete():
    rng = np.random.default_rng(0)
    n = 40
    scores = rng.normal(size=n)
    adj = np.triu(rng.random((n, n)) < 0.4, 1)
    adj = adj | adj.T
    res = div_astar(jnp.asarray(scores, jnp.float32), jnp.asarray(adj), 8,
                    max_expansions=5)
    assert not bool(res.complete)
