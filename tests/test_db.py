"""DiverseVectorDB facade + frozen Query: read-path parity with the solo
drivers, the write path through the scheduler, cache invalidation on
writes, and bit-exactness of the deprecated wiring shims."""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core.pss import pss
from repro.db import DiverseVectorDB, Query
from repro.serve.scheduler import LaneScheduler


@pytest.fixture(scope="module")
def db(small_graph):
    return DiverseVectorDB(index=small_graph, num_lanes=3, max_k=8,
                           default_ef=10, prewarm=False)


def test_search_matches_solo_pss(db, clustered_data, small_graph):
    """With no writes the facade is a pass-through: results equal a fresh
    per-query PSS driver bit-for-bit (the old entry points' contract)."""
    rng = np.random.default_rng(0)
    qs = (clustered_data[rng.integers(0, 600, 6)]
          + 0.05 * rng.normal(size=(6, 24))).astype(np.float32)
    for i, (k, eps) in enumerate([(5, 0.0), (3, -0.5)] * 3):
        r = db.search(qs[i], k=k, eps=eps, ef=10)
        solo = pss(small_graph, qs[i], k, eps, ef=10)
        np.testing.assert_array_equal(r.ids, solo.ids)
        np.testing.assert_array_equal(r.scores, solo.scores)
        assert r.stats.certified == solo.stats.certified


def test_search_batch_broadcast_and_queries(db, clustered_data):
    qs = clustered_data[:4] + np.float32(0.01)
    by_arr = db.search_batch(qs, k=3, eps=0.0, ef=10)
    by_query = db.search_batch([Query(q, k=3, eps=0.0, ef=10) for q in qs])
    for a, b in zip(by_arr, by_query):
        np.testing.assert_array_equal(a.ids, b.ids)


def test_query_is_frozen_and_validated(db):
    q = Query(np.zeros(24, np.float32), k=3, eps=0.0)
    with pytest.raises(dataclasses.FrozenInstanceError):
        q.k = 5
    with pytest.raises(ValueError):
        db.search(q, k=5)            # overrides belong on the Query
    with pytest.raises(TypeError):
        db.search(np.zeros(24, np.float32))   # raw embedding needs k/eps
    with pytest.raises(TypeError):
        Query("what is diversity?", k=3, eps=0.0).embedding()  # no embed=


def test_text_queries_via_embed(clustered_data):
    emb = {"a": clustered_data[3], "b": clustered_data[9]}
    db = DiverseVectorDB(clustered_data, "l2", M=8, num_lanes=2, max_k=8,
                         default_ef=10, prewarm=False,
                         embed=lambda t: emb[t])
    r = db.search("a", k=3, eps=0.0, ef=10)
    assert 3 in r.ids.tolist()


def test_scheduler_submit_accepts_query(small_graph, clustered_data):
    sched = LaneScheduler(small_graph, num_lanes=2, max_k=8, default_ef=10,
                          prewarm=False)
    q = clustered_data[5] + np.float32(0.01)
    req = sched.submit(Query(q, k=3, eps=0.0, ef=10))
    sched.drain()
    solo = pss(small_graph, q, 3, 0.0, ef=10)
    np.testing.assert_array_equal(req.result.ids, solo.ids)
    with pytest.raises(ValueError):
        sched.submit(Query(q, k=3, eps=0.0), k=5)  # no overrides on Query
    with pytest.raises(TypeError):
        sched.submit(q)                            # raw embedding needs k=


def test_upsert_served_delete_filtered(clustered_data):
    db = DiverseVectorDB(clustered_data, "l2", M=8, num_lanes=2, max_k=8,
                         default_ef=10, prewarm=False)
    rng = np.random.default_rng(4)
    q = (clustered_data[11]
         + 0.05 * rng.normal(size=24)).astype(np.float32)
    ids = db.upsert(q[None])     # the query itself: top score, must win
    assert int(ids[0]) == len(clustered_data)
    r = db.search(q, k=3, eps=0.0, ef=10)
    assert int(ids[0]) in r.ids.tolist()
    assert db.delete(ids) == 1
    r = db.search(q, k=3, eps=0.0, ef=10)
    assert int(ids[0]) not in r.ids.tolist()
    st = db.stats()
    assert st["writes"] == 2 and st["writes_applied"] == 2
    assert st["index"]["deleted"] == 1


def test_write_admission_validates(db, small_graph):
    with pytest.raises(ValueError):
        db.scheduler.submit_write("replace", [0])
    plain = LaneScheduler(small_graph, num_lanes=2, max_k=8,
                          default_ef=10, prewarm=False)
    with pytest.raises(TypeError):
        plain.submit_write("upsert", np.zeros((1, 24), np.float32))


def test_cache_invalidated_on_delete(clustered_data):
    """A cached entry whose stored frontier holds a deleted id is evicted
    at write time — the next repeat query misses and re-searches, so a
    deleted id is never served from cache (no stale hits)."""
    db = DiverseVectorDB(clustered_data, "l2", M=8, num_lanes=2, max_k=8,
                         default_ef=10, cache_size=8, prewarm=False)
    q = clustered_data[21].astype(np.float32)
    first = db.search(q, k=3, eps=0.0, ef=10)
    hit = db.search(q, k=3, eps=0.0, ef=10)
    st = db.stats()
    victim = int(first.ids[0])
    if st["cache_hits"]:      # only certified results are admitted
        np.testing.assert_array_equal(hit.ids, first.ids)
    db.delete([victim])
    st = db.stats()
    assert st["cache_invalidations"] == st["cache"]["invalidated"]
    after = db.search(q, k=3, eps=0.0, ef=10)
    assert victim not in after.ids.tolist()
    if st["cache_hits"]:
        assert st["cache_invalidations"] >= 1


def test_rebuild_and_epoch_swap(clustered_data):
    db = DiverseVectorDB(clustered_data, "l2", M=8, num_lanes=2, max_k=8,
                         default_ef=10, delta_capacity=64,
                         background_rebuild=False, prewarm=False)
    rng = np.random.default_rng(7)
    db.upsert(rng.normal(size=(5, 24)).astype(np.float32))
    assert db.rebuild(wait=True)
    st = db.stats()
    assert st["index"]["epoch"] == 1 and st["epoch_swaps"] == 1
    assert st["index"]["delta"] == 0
    q = clustered_data[2].astype(np.float32)
    r = db.search(q, k=3, eps=0.0, ef=10)     # post-swap service is live
    assert 2 in r.ids.tolist()


def test_rag_graph_shim_bit_exact_and_deprecated(small_graph,
                                                 clustered_data):
    """RagPipeline(graph=...) still works, warns, and retrieves the same
    ids as the db= wiring (the shim's bit-exactness promise)."""
    from repro.configs import get_config
    from repro.models import model as M
    import jax
    from repro.serve.rag import RagPipeline

    cfg = get_config("qwen2-1.5b").reduced()
    params = M.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    qs = (clustered_data[rng.integers(0, 600, 3)]
          + 0.05 * rng.normal(size=(3, 24))).astype(np.float32)
    old = RagPipeline(cfg, params, small_graph, k=3, eps=0.0, ef=10,
                      num_lanes=2)
    with pytest.warns(DeprecationWarning, match="DiverseVectorDB"):
        ids_old, cert_old = old.retrieve(qs)
    db = DiverseVectorDB(index=small_graph, num_lanes=2, max_k=16,
                         default_ef=10, prewarm=False)
    new = RagPipeline(cfg, params, k=3, eps=0.0, ef=10, db=db)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        ids_new, cert_new = new.retrieve(qs)
    assert not [w for w in caught if "shim" in str(w.message)]
    np.testing.assert_array_equal(ids_old, ids_new)
    np.testing.assert_array_equal(cert_old, cert_new)
    # the Query-native batch path returns the same ids again
    ids_q, cert_q = new.retrieve([Query(q, k=3, eps=0.0, ef=10)
                                  for q in qs])
    np.testing.assert_array_equal(ids_new, ids_q)
    with pytest.raises(ValueError):
        new.retrieve([Query(qs[0], k=3, eps=0.0)], ks=[5])
