"""Compressed-corpus search: quantization, kernel parity, rerank soundness.

The contract under test (ARCHITECTURE.md contract 13 — "quantization is a
memory knob, never a certificate knob"):

* int8 reconstruction error is bounded by one quantization step per
  row-block (the error-bound property behind the recall floor);
* ``quantized_similarity_many`` is bitwise identical across its impl
  ladder (ref / interpret) for both schemes and all three metrics — the
  kernels only ever compute exact integer dots / exact LUT gathers, so
  there is no tolerance to tune;
* the per-round block scorer (``quant.score_rows``) matches the batched
  op to float32 round-off (~1 ulp: same exact integers, different XLA
  fusion contexts);
* a quantized engine's certificates re-verify via ``theorem2_recheck``
  against *exact float* scores — the rerank stage, not the codes, feeds
  Theorem 2;
* the memory accounting is honest: int8 codes are exactly 4x smaller than
  f32, the total int8 payload (codes + scale sidecar) is >= 3.9x smaller
  at the default ``scale_rows=8``, and PQ is strictly smaller than int8
  once the codebook amortizes;
* (slow) on the 10k clustered fixture both schemes stay within 1% mean
  recall of the float path against the exact diverse oracle.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.compat import make_mesh
from repro.core.backend import LaneRequest
from repro.core.theorems import theorem2_recheck
from repro.kernels import ops as kops
from repro.sharded_search import ShardedEngine, build_sharded_index

METRICS = ("ip", "cos", "l2")


@pytest.fixture(scope="module")
def corpus_f32():
    rng = np.random.default_rng(11)
    centers = rng.normal(size=(16, 24)) * 0.5
    x = centers[rng.integers(0, 16, 512)] + rng.normal(size=(512, 24))
    return x.astype(np.float32)


@pytest.fixture(scope="module")
def queries(corpus_f32):
    rng = np.random.default_rng(12)
    return (corpus_f32[rng.integers(0, corpus_f32.shape[0], 7)]
            + 0.1 * rng.normal(size=(7, corpus_f32.shape[1]))
            ).astype(np.float32)


# ----------------------------------------------------- error bound ----------

def test_int8_reconstruction_within_one_step(corpus_f32):
    """Symmetric int8: |x - dequant(x)| <= scale/2 everywhere, with the
    scale shared per ``scale_rows`` row block (one step = scale; rounding
    keeps the error within half a step)."""
    for scale_rows in (1, 8, 64):
        c = quant.quantize_int8(corpus_f32, scale_rows=scale_rows)
        err = np.abs(np.asarray(c.dequantize()) - corpus_f32)
        step = np.asarray(c.row_scales())[:, None]
        assert np.all(err <= 0.5 * step + 1e-7), (
            f"scale_rows={scale_rows}: max err {err.max()} vs "
            f"step {step.max()}")


def test_int8_codes_are_saturating_and_symmetric(corpus_f32):
    c = quant.quantize_int8(corpus_f32, scale_rows=8)
    codes = np.asarray(c.codes)
    assert codes.dtype == np.int8
    assert codes.min() >= -127 and codes.max() <= 127  # -128 never emitted


# ------------------------------------------------- impl-ladder parity -------

@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("scheme", quant.QUANT_SCHEMES)
def test_quantized_ladder_bitwise_parity(corpus_f32, queries, scheme,
                                         metric):
    """ref and interpret (compiled-Pallas semantics) are bitwise equal:
    the kernel computes the same exact int32 dot / exact LUT gather-sum
    and shares the one float postprocess with the oracle."""
    corpus = quant.quantize_corpus(corpus_f32, scheme, pq_iters=4)
    qs = jnp.asarray(queries)
    ref = np.asarray(kops.quantized_similarity_many(qs, corpus, metric,
                                                    impl="ref"))
    itp = np.asarray(kops.quantized_similarity_many(qs, corpus, metric,
                                                    impl="interpret"))
    assert ref.shape == (queries.shape[0], corpus_f32.shape[0])
    assert np.array_equal(ref, itp), (
        f"{scheme}/{metric}: ladder not bitwise "
        f"(max |d|={np.abs(ref - itp).max()})")


@pytest.mark.parametrize("scheme", quant.QUANT_SCHEMES)
def test_block_scorer_matches_batched_op(corpus_f32, queries, scheme):
    """The beam-round block scorer re-scores the rows the batched op
    scored, to float32 round-off (same exact integer intermediates, XLA
    may fuse the float postprocess differently across the two jit
    contexts)."""
    metric = "cos"
    corpus = quant.quantize_corpus(corpus_f32, scheme, pq_iters=4)
    full = np.asarray(kops.quantized_similarity_many(
        jnp.asarray(queries), corpus, metric, impl="ref"))
    rng = np.random.default_rng(13)
    idx = rng.integers(0, corpus_f32.shape[0], 37)
    for r in range(queries.shape[0]):
        prep = quant.prepare_query(corpus, jnp.asarray(queries[r]), metric)
        got = np.asarray(quant.score_rows(prep, corpus,
                                          jnp.asarray(idx, jnp.int32),
                                          metric))
        np.testing.assert_allclose(got, full[r, idx], rtol=1e-6, atol=1e-6)


# ------------------------------------------------- rerank soundness ---------

@pytest.mark.parametrize("scheme", quant.QUANT_SCHEMES)
def test_quantized_certificates_reverify_on_float_scores(corpus_f32, scheme):
    """Certificates from the quantized path must survive an independent
    Theorem-2 re-check against exact float scores: the engine's recorded
    frontier is the post-rerank one, so ``theorem2_recheck`` (which
    re-runs div-A* host-side on the float corpus) must certify every lane
    the engine certified, with identical selected ids. Zero violations —
    the acceptance bar, not a ratio."""
    x = corpus_f32
    index = build_sharded_index(x, 1, "cos", M=8, quantized=scheme,
                                pq_iters=4)
    mesh = make_mesh((1,), ("data",))
    rng = np.random.default_rng(14)
    qs = (x[rng.integers(0, x.shape[0], 6)]
          + 0.05 * rng.normal(size=(6, x.shape[1]))).astype(np.float32)
    eng = ShardedEngine(index, x, mesh, num_lanes=6, K0=16, max_k=8,
                        resume="beam", record_candidates=True)
    for lane in range(6):
        eng.admit(lane, LaneRequest(q=qs[lane], k=4, eps=0.3,
                                    method="sharded"))
    out = {}
    while eng.active_count():
        eng.step()
        for lane, res in eng.harvest():
            out[lane] = res
            eng.recycle(lane)
    certified = [lane for lane, r in out.items() if r.stats.certified]
    assert certified, "fixture produced no certified lane"
    violations = []
    for lane in certified:
        cand_ids, cand_sc = eng.last_candidates[lane]
        ok, sel_ids = theorem2_recheck(x, "cos", cand_ids, cand_sc, 0.3, 4)
        if not ok or not np.array_equal(sel_ids, out[lane].ids):
            violations.append(lane)
    assert not violations, (
        f"{scheme}: lanes {violations} certified on scores that do not "
        "re-verify against the float corpus")


# --------------------------------------------------- memory accounting ------

def test_bytes_per_vector_accounting(corpus_f32):
    d = corpus_f32.shape[1]
    c8 = quant.quantize_int8(corpus_f32, scale_rows=8)
    assert c8.code_bytes_per_vector() == pytest.approx(4.0 * d / 4.0)
    assert 4.0 * d / c8.bytes_per_vector() >= 3.9  # codes + scale sidecar
    rng = np.random.default_rng(15)
    x = rng.normal(size=(2048, 32)).astype(np.float32)
    cpq = quant.quantize_corpus(x, "pq", pq_iters=2)
    c8b = quant.quantize_int8(x, scale_rows=8)
    assert cpq.bytes_per_vector() < c8b.bytes_per_vector()
    assert quant.corpus_bytes_per_vector(x) == 4.0 * 32


# ------------------------------------------------------ 10k recall ----------

@pytest.mark.slow
def test_quantized_recall_floors_10k_slow():
    """The documented recall floors on the 10k clustered fixture, recall
    measured against the exact diverse oracle:

    * int8 — within 1% (absolute) of the float path's mean recall, at a
      ~4x smaller on-device corpus;
    * pq (default ``default_pq_m`` subspaces, width 2 here) — within 20%
      of the float path (measured ~0.83 vs 1.00): approximate ADC scores
      steer the *graph traversal*, so the exact rerank cannot recover
      candidates the quantized beam never visits — that is the price of a
      corpus strictly smaller than int8's (asserted below).
    """
    from repro.core.baselines import div_astar_oracle

    rng = np.random.default_rng(5)
    n, d = 10_000, 32
    centers = rng.normal(size=(64, d)) * 0.25
    x = centers[rng.integers(0, 64, n)] + rng.normal(size=(n, d))
    x = (x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True),
                        1e-9)).astype(np.float32)
    mesh = make_mesh((1,), ("data",))
    qs = x[rng.integers(0, n, 6)] + 0.05 * rng.normal(size=(6, d))
    qs = (qs / np.maximum(np.linalg.norm(qs, axis=1, keepdims=True),
                          1e-9)).astype(np.float32)
    k, eps = 5, 0.35
    truth = [set(int(i) for i in
                 div_astar_oracle(x, "cos", qs[r], k, eps, X=512).ids
                 if i >= 0) for r in range(6)]

    def mean_recall(index):
        eng = ShardedEngine(index, x, mesh, num_lanes=6, K0=16, max_k=8,
                            resume="beam", max_rounds=4)
        for lane in range(6):
            eng.admit(lane, LaneRequest(q=qs[lane], k=k, eps=eps,
                                        method="sharded"))
        out = {}
        while eng.active_count():
            eng.step()
            for lane, res in eng.harvest():
                out[lane] = res
                eng.recycle(lane)
        recs = [len(set(int(i) for i in out[r].ids if i >= 0) & truth[r])
                / max(len(truth[r]), 1) for r in range(6)]
        return float(np.mean(recs))

    base = mean_recall(build_sharded_index(x, 1, "cos", M=8))
    floors = {"int8": 0.01, "pq": 0.20}
    bpv = {}
    for scheme in quant.QUANT_SCHEMES:
        idx = build_sharded_index(x, 1, "cos", M=8, quantized=scheme)
        bpv[scheme] = float(idx.corpus_bytes_per_vector())
        rec = mean_recall(idx)
        assert rec >= base - floors[scheme], (
            f"{scheme}: recall {rec:.4f} more than {floors[scheme]:.0%} "
            f"below float {base:.4f}")
    assert 4.0 * d / bpv["int8"] >= 3.9       # ~4x smaller than f32
    assert bpv["pq"] < bpv["int8"]            # PQ strictly smaller still
