"""Table III: PDS/PSS vs exact div-A* on the l2 dataset, k in {5, 20}."""
from __future__ import annotations

import numpy as np

from benchmarks import datasets as D
from benchmarks.common import emit, evaluate_method, oracle_for, timed


def run(num_queries: int = 10, n: int = D.N_DEFAULT, ef: int = 15):
    graph, x, metric = D.load_graph("deep-like", n=n)
    queries = D.queries_for(x, num_queries)
    for k in (5, 20):
        for level in ("low", "medium"):
            eps = D.calibrate_eps(x, metric, D.PHI_TARGETS[level])
            cache: dict = {}
            o_lat = []
            for q in queries:
                _, dt = timed(oracle_for, x, metric, q, k, eps, cache,
                              warmup=0)
                o_lat.append(dt)
            emit(f"table3/k{k}/{level}/div-astar",
                 float(np.mean(o_lat)) * 1e6, "recall=1.00")
            for method in ("pds", "pss"):
                kw = dict(max_K=1024) if method == "pds" else {}
                lat, score, rec, extra = evaluate_method(
                    graph, x, metric, queries, k, eps, method, ef, cache,
                    **kw)
                speed = float(np.mean(o_lat)) / max(lat, 1e-9)
                emit(f"table3/k{k}/{level}/{method}", lat * 1e6,
                     f"score={score:.4f};recall={rec:.3f};"
                     f"speedup_vs_oracle={speed:.1f}x")


if __name__ == "__main__":
    run()
