"""Benchmark datasets: the paper's three metric spaces, synthetic stand-ins.

LAION-art / Deep1M / Txt2img are license/size-gated (DESIGN.md §7); we
substitute deterministic synthetic datasets with the same metric spaces and
density character, scaled to what 1 CPU core can index:

  deep-like    l2   uniform-ish Gaussian mixture, mild clustering
  laion-like   cos  heavy clustering (partially dense regions — the paper
                    calls out LAION's density as the hard case)
  txt2img-like ip   anisotropic heavy-tail mixture

Diversification levels follow the paper's phi(eps) calibration: phi(eps) =
expected diversity-graph degree = (N-1) * P(sim > eps); eps is chosen from a
random-pair similarity sample to hit the low/medium/high phi targets
(paper: 10/100/500 at N=1M; proportionally scaled here).

Graphs are HNSW (the paper's index) and cached on disk keyed by config.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.graph import FlatGraph, make_flat_graph

CACHE = os.path.join(os.path.dirname(__file__), "..", "results", "cache")

N_DEFAULT = 20_000
PHI_TARGETS = dict(low=5.0, medium=50.0, high=200.0)


def make_dataset(name: str, n: int = N_DEFAULT, d: int = 48,
                 seed: int = 0) -> tuple[np.ndarray, str]:
    rng = np.random.default_rng(seed)
    if name == "deep-like":
        centers = rng.normal(size=(64, d)) * 1.0
        x = centers[rng.integers(0, 64, n)] + rng.normal(size=(n, d)) * 0.7
        return x.astype(np.float32), "l2"
    if name == "laion-like":
        centers = rng.normal(size=(24, d)) * 2.0
        x = centers[rng.integers(0, 24, n)] + rng.normal(size=(n, d)) * 0.35
        x /= np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-9)
        return x.astype(np.float32), "cos"
    if name == "txt2img-like":
        scales = np.exp(rng.normal(size=(1, d)) * 0.8)
        centers = rng.normal(size=(32, d)) * scales
        x = centers[rng.integers(0, 32, n)] \
            + rng.normal(size=(n, d)) * 0.5 * scales
        return (x / np.sqrt(d)).astype(np.float32), "ip"
    raise KeyError(name)


DATASETS = ("deep-like", "laion-like", "txt2img-like")


def calibrate_eps(x: np.ndarray, metric: str, phi: float,
                  sample: int = 400_000, seed: int = 1) -> float:
    """eps such that E[deg(G^eps)] ~= phi over the dataset."""
    from repro.core.similarity import pairwise_sim
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    n = x.shape[0]
    m = int(np.sqrt(sample))
    a = x[rng.integers(0, n, m)]
    b = x[rng.integers(0, n, m)]
    sims = np.asarray(pairwise_sim(jnp.asarray(a), jnp.asarray(b),
                                   metric)).ravel()
    q = 1.0 - phi / (n - 1)
    return float(np.quantile(sims, q))


def queries_for(x: np.ndarray, num: int, seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    base = x[rng.integers(0, x.shape[0], num)]
    return (base + rng.normal(size=base.shape).astype(np.float32)
            * 0.05 * np.abs(base).mean()).astype(np.float32)


def load_graph(name: str, n: int = N_DEFAULT, M: int = 12,
               ef_construction: int = 80, builder: str = "hnsw",
               seed: int = 0) -> tuple[FlatGraph, np.ndarray, str]:
    os.makedirs(CACHE, exist_ok=True)
    x, metric = make_dataset(name, n=n, seed=seed)
    key = f"{name}_{n}_{M}_{ef_construction}_{builder}_{seed}"
    path = os.path.join(CACHE, key + ".npz")
    if os.path.exists(path):
        z = np.load(path)
        g = make_flat_graph(x, z["neighbors"],
                            z["upper"] if z["upper"].size else None,
                            int(z["entry"]), metric)
        return g, x, metric
    if builder == "hnsw":
        from repro.index.hnsw import build_hnsw
        g = build_hnsw(x, metric=metric, M=M,
                       ef_construction=ef_construction, seed=seed)
    else:
        from repro.index.flat import build_knn_graph
        g = build_knn_graph(x, metric=metric, M=M)
    np.savez(path, neighbors=np.asarray(g.neighbors),
             upper=np.asarray(g.upper), entry=int(g.entry))
    return g, x, metric
