"""Table II reproduction: latency / score / recall across datasets and
diversification settings for greedy / PGS / PDS / PSS (+ the div-A* oracle).

Settings mirror the paper's five columns: (k=10, phi low/med/high),
(k=5, phi high), (k=15, phi high). Ground truth = certified div-A* oracle.
"""
from __future__ import annotations

import numpy as np

from benchmarks import datasets as D
from benchmarks.common import emit, evaluate_method, oracle_for, timed

SETTINGS = [
    (10, "low"), (10, "medium"), (10, "high"), (5, "high"), (15, "high"),
]
METHODS = ("greedy", "pgs", "pds", "pss")


def run(num_queries: int = 12, n: int = D.N_DEFAULT, ef: int = 15,
        datasets=D.DATASETS):
    rows = []
    for ds in datasets:
        graph, x, metric = D.load_graph(ds, n=n)
        queries = D.queries_for(x, num_queries)
        for k, level in SETTINGS:
            eps = D.calibrate_eps(x, metric, D.PHI_TARGETS[level])
            oracle_cache: dict = {}
            # oracle row (scores only — it defines recall=1)
            o_lat, o_scores = [], []
            for q in queries:
                o, dt = timed(oracle_for, x, metric, q, k, eps, oracle_cache,
                              warmup=0)
                o_lat.append(dt)
                o_scores.append(o.total)
            emit(f"table2/{ds}/k{k}/{level}/oracle",
                 float(np.mean(o_lat)) * 1e6,
                 f"score={np.mean(o_scores):.4f};recall=1.00;eps={eps:.4f}")
            for method in METHODS:
                kw = {}
                if method == "pds":
                    kw["max_K"] = 1024  # paper marks exploding-K cells N/A
                lat, score, rec, extra = evaluate_method(
                    graph, x, metric, queries, k, eps, method, ef,
                    oracle_cache, **kw)
                emit(f"table2/{ds}/k{k}/{level}/{method}", lat * 1e6,
                     f"score={score:.4f};recall={rec:.3f};"
                     f"Kavg={extra['K_avg']:.0f};Kmax={extra['K_max']}")
                rows.append((ds, k, level, method, lat, score, rec))
    return rows


if __name__ == "__main__":
    run()
