"""Figs. 12-14: latency-recall frontier as ef sweeps (PGS/PDS/PSS)."""
from __future__ import annotations


from benchmarks import datasets as D
from benchmarks.common import emit, evaluate_method


def run(num_queries: int = 8, n: int = D.N_DEFAULT,
        efs=(5, 10, 20), datasets=("deep-like", "txt2img-like")):
    settings = [(10, "medium"), (10, "low"), (15, "medium")]
    for ds in datasets:
        graph, x, metric = D.load_graph(ds, n=n)
        queries = D.queries_for(x, num_queries)
        for k, level in settings:
            eps = D.calibrate_eps(x, metric, D.PHI_TARGETS[level])
            cache: dict = {}
            for method in ("pgs", "pds", "pss"):
                for ef in efs:
                    kw = dict(max_K=2048) if method == "pds" else {}
                    lat, score, rec, _ = evaluate_method(
                        graph, x, metric, queries, k, eps, method, ef,
                        cache, **kw)
                    emit(f"latrec/{ds}/k{k}/{level}/{method}/ef{ef}",
                         lat * 1e6, f"recall={rec:.3f};score={score:.4f}")


if __name__ == "__main__":
    run()
