"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table2,...]

Prints ``name,us_per_call,derived`` CSV (benchmarks/common.emit). The first
run builds + caches the HNSW indexes (a few minutes at N=20k on one core).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller N / fewer queries")
    ap.add_argument("--only", default="",
                    help="comma list: table2,table3,table4,fig8,latrec,"
                         "kernels,batch")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (datasets, fig8_ipgreedy, kernel_bench,
                            latency_recall, table2, table3, table4)

    n = 6000 if args.quick else datasets.N_DEFAULT
    nq = 3 if args.quick else 4

    t0 = time.time()
    print("name,us_per_call,derived")
    if only is None or "kernels" in only:
        kernel_bench.run()
    if only is None or "table2" in only:
        table2.run(num_queries=nq, n=n)
    if only is None or "table3" in only:
        table3.run(num_queries=max(4, nq // 2), n=n)
    if only is None or "table4" in only:
        table4.run(num_queries=max(4, nq // 2), n=n)
    if only is None or "fig8" in only:
        fig8_ipgreedy.run(num_queries=max(4, nq // 2), n=n)
    if only is not None and "latrec" in only:
        latency_recall.run(num_queries=max(3, nq // 2), n=n)
    if only is None or "batch" in only:
        from benchmarks import batch_bench
        batch_bench.run(n=n)
    print(f"# total {time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
