"""Shared benchmark machinery: timing, recall-vs-oracle, CSV emission."""
from __future__ import annotations

import time

import numpy as np

from repro.core.api import diverse_search
from repro.core.baselines import div_astar_oracle


def timed(fn, *args, warmup: int = 1, reps: int = 1, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return out, dt


def recall(result_ids: np.ndarray, truth_ids: np.ndarray) -> float:
    a = set(int(i) for i in result_ids if i >= 0)
    b = set(int(i) for i in truth_ids if i >= 0)
    if not b:
        return 1.0
    return len(a & b) / len(b)


def oracle_for(x, metric, q, k, eps, cache: dict):
    key = (id(x), float(np.sum(q)), k, round(eps, 6))
    if key not in cache:
        cache[key] = div_astar_oracle(x, metric, q, k, eps, X=1024)
    return cache[key]


def evaluate_method(graph, x, metric, queries, k, eps, method, ef,
                    oracle_cache, **kw):
    """Returns (mean latency s, mean score, mean recall, extras)."""
    lats, scores, recs, Ks = [], [], [], []
    for qi, q in enumerate(queries):
        res, dt = timed(diverse_search, graph, q, k=k, eps=eps,
                        method=method, ef=ef, warmup=0, **kw)
        lats.append(dt)
        scores.append(res.total)
        o = oracle_for(x, metric, q, k, eps, oracle_cache)
        recs.append(recall(res.ids, o.ids))
        Ks.append(res.stats.K_final)
    return (float(np.mean(lats)), float(np.mean(scores)),
            float(np.mean(recs)), dict(K_avg=float(np.mean(Ks)),
                                       K_max=int(np.max(Ks))))


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
