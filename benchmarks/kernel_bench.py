"""Kernel microbenchmarks: jnp-oracle CPU timings + work derived metrics.

(The Pallas kernels target TPU; interpret mode is a correctness harness, not
a performance path — benchmarking it would measure the Python interpreter.)
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels import ops


def run():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(65536, 64)), jnp.float32)
    qs = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    for metric in ("l2", "ip", "cos"):
        out, dt = timed(
            lambda: ops.batch_similarity_many(qs, x, metric).block_until_ready(),
            warmup=2, reps=5)
        flops = 2 * qs.shape[0] * x.shape[0] * x.shape[1]
        emit(f"kernel/batch_similarity/{metric}", dt * 1e6,
             f"gflops={flops/dt/1e9:.1f}")
    cand = jnp.asarray(rng.normal(size=(1024, 64)), jnp.float32)
    out, dt = timed(
        lambda: ops.pairwise_adjacency(cand, 0.1, "cos").block_until_ready(),
        warmup=2, reps=5)
    emit("kernel/pairwise_adjacency/K1024", dt * 1e6,
         f"pairs_per_s={1024*1024/dt:.2e}")
    scores = jnp.asarray(np.sort(rng.normal(size=1024))[::-1], jnp.float32)
    adj = ops.pairwise_adjacency(cand, 0.1, "cos")
    out, dt = timed(
        lambda: ops.greedy_diversify(scores, adj, 20)[0].block_until_ready(),
        warmup=2, reps=5)
    emit("kernel/greedy_diversify/K1024_k20", dt * 1e6, "")
    ia = jnp.arange(256, dtype=jnp.int32)
    sa = jnp.asarray(np.sort(rng.normal(size=256))[::-1], jnp.float32)
    out, dt = timed(
        lambda: ops.topk_merge(ia, sa, ia + 999, sa)[0].block_until_ready(),
        warmup=2, reps=10)
    emit("kernel/topk_merge/L256", dt * 1e6, "")


if __name__ == "__main__":
    run()
