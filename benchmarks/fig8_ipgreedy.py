"""Fig. 8 reproduction: IP-greedy lambda sweep — the paper's finding that
lambda barely moves the realized diversity (max pairwise sim) while costing
total score, motivating direct eps control instead."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import datasets as D
from benchmarks.common import emit
from repro.core.baselines import ip_greedy
from repro.core.similarity import pairwise_sim


def run(num_queries: int = 8, n: int = D.N_DEFAULT):
    graph, x, metric = D.load_graph("txt2img-like", n=n)
    queries = D.queries_for(x, num_queries)
    for lam in (0.5, 0.6, 0.7, 0.8, 0.9, 1.0):
        scores, divs = [], []
        for q in queries:
            res = ip_greedy(graph, q, k=10, lam=lam, L=200)
            ids = res.ids[res.ids >= 0]
            scores.append(res.total)
            sims = np.asarray(pairwise_sim(jnp.asarray(x[ids]),
                                           jnp.asarray(x[ids]), metric))
            off = sims[~np.eye(len(ids), dtype=bool)]
            divs.append(float(off.max()) if off.size else 0.0)
        emit(f"fig8/ip_greedy/lam{lam}", 0.0,
             f"score={np.mean(scores):.4f};max_pair_sim={np.mean(divs):.4f}")


if __name__ == "__main__":
    run()
