"""Table IV: estimated candidate count K (AVG/MAX) — PDS vs PSS at ef=10.

Reproduces the paper's claim that Theorem-1 (degree) estimates explode at
high diversification while Theorem-2 (score) estimates stay tight.
"""
from __future__ import annotations

import numpy as np

from benchmarks import datasets as D
from benchmarks.common import emit
from repro.core.api import diverse_search


def run(num_queries: int = 10, n: int = D.N_DEFAULT, ef: int = 10):
    graph, x, metric = D.load_graph("deep-like", n=n)
    queries = D.queries_for(x, num_queries)
    for k in (5, 20):
        for level in ("low", "medium", "high"):
            eps = D.calibrate_eps(x, metric, D.PHI_TARGETS[level])
            for method in ("pds", "pss"):
                Ks = []
                na = 0
                for q in queries:
                    kw = dict(max_K=1024) if method == "pds" else {}
                    res = diverse_search(graph, q, k=k, eps=eps,
                                         method=method, ef=ef, **kw)
                    if res.stats.exhausted and method == "pds":
                        na += 1
                    else:
                        Ks.append(res.stats.K_final)
                if Ks:
                    emit(f"table4/k{k}/{level}/{method}",
                         float(np.mean(Ks)),
                         f"Kavg={np.mean(Ks):.0f};Kmax={np.max(Ks)};NA={na}")
                else:
                    emit(f"table4/k{k}/{level}/{method}", 0.0,
                         f"NA={na} (all queries exceeded max_K)")


if __name__ == "__main__":
    run()
