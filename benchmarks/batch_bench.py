"""Beyond-paper TPU-path benchmark: batched (vmapped) diverse search
throughput vs the per-query progressive driver — the optimization the paper
cannot express on CPU (DESIGN.md §2; EXPERIMENTS.md §Perf paper-technique
track)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import datasets as D
from benchmarks.common import emit, timed
from repro.core.api import diverse_search
from repro.core.batch import batch_greedy_diverse, batch_optimal_diverse


def run(n: int = D.N_DEFAULT, batch: int = 16, k: int = 10):
    graph, x, metric = D.load_graph("deep-like", n=n)
    queries = D.queries_for(x, batch)
    eps = D.calibrate_eps(x, metric, D.PHI_TARGETS["medium"])
    qs = jnp.asarray(queries)

    # per-query driver (paper-faithful)
    def loop_pss():
        return [diverse_search(graph, q, k=k, eps=eps, method="pss", ef=10)
                for q in queries]
    _, dt_loop = timed(loop_pss, warmup=1, reps=1)
    emit("batch/per_query_pss", dt_loop / batch * 1e6, "per-query us")

    # batched fixed-K div-A* (TPU path)
    def batched():
        out = batch_optimal_diverse(graph, qs, k, eps, K=128, ef=4)
        out[0].block_until_ready()
        return out
    out, dt_b = timed(batched, warmup=1, reps=2)
    cert = float(np.mean(np.asarray(out[3])))
    emit("batch/batched_divastar", dt_b / batch * 1e6,
         f"certified_frac={cert:.2f};speedup={dt_loop/dt_b:.1f}x")

    def batched_greedy():
        out = batch_greedy_diverse(graph, qs, k, eps, L=256)
        out[0].block_until_ready()
        return out
    _, dt_g = timed(batched_greedy, warmup=1, reps=2)
    emit("batch/batched_greedy", dt_g / batch * 1e6,
         f"speedup_vs_loop={dt_loop/dt_g:.1f}x")


if __name__ == "__main__":
    run()
