"""Serving-path benchmark: engine vs per-query loop, and continuous vs
lockstep admission on skewed workloads.

Two modes:

* ``--mode engine`` (default) — PR 1's headline comparison: at serving batch
  sizes the per-query pause/inspect/resume loop pays its host round-trips
  and device dispatches per *query*, while the batched engine pays them per
  *round* for the whole batch — same per-lane semantics (exact parity with
  ``pss``), ~B-fold fewer dispatches.

* ``--mode skewed`` — the continuous-batching comparison: a heavy-tailed
  request mix (mixed ``k`` in {5, 10}, mostly light-diversification queries
  with a heavy tail of dense-G^eps ones whose div-A* trip counts explode)
  served by the *same* lane scheduler under two admission policies.
  Lockstep admission refills lanes only when the whole wave finished (every
  wave waits for its straggler); continuous admission recycles each
  certified lane immediately. Both policies return bit-identical per-request
  results (verified against the per-query ``pss`` driver — a parity
  violation exits nonzero, which is what the CI smoke job checks); the
  difference is purely p50/p99 latency and throughput. ``--tiny`` shrinks
  everything for the CI smoke job.
"""
from __future__ import annotations

import argparse
import os
import sys

import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):   # `python benchmarks/batch_bench.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks import datasets as D
from benchmarks.common import emit, timed
from repro.core.api import diverse_search
from repro.core.batch import batch_greedy_diverse, batch_optimal_diverse
from repro.core.batch_progressive import batch_pss
from repro.serve.scheduler import LaneScheduler


def run(n: int = D.N_DEFAULT, batch: int = 64, k: int = 10, ef: int = 10,
        phis: tuple = ("low", "medium")):
    graph, x, metric = D.load_graph("deep-like", n=n)
    queries = D.queries_for(x, batch)
    qs = jnp.asarray(queries)
    speedups = {}
    for phi in phis:
        eps = D.calibrate_eps(x, metric, D.PHI_TARGETS[phi])

        # per-query progressive driver loop (paper-faithful baseline)
        def loop_pss():
            return [diverse_search(graph, q, k=k, eps=eps, method="pss",
                                   ef=ef) for q in queries]
        _, dt_loop = timed(loop_pss, warmup=1, reps=1)
        emit(f"batch/{phi}/per_query_pss", dt_loop / batch * 1e6,
             "per-query us")

        # batched progressive engine (exact same per-lane results);
        # streams=2 overlaps host orchestration with device work
        def engine():
            return batch_pss(graph, qs, k, eps, ef=ef, streams=2)
        res, dt_e = timed(engine, warmup=1, reps=2)
        speedups[phi] = dt_loop / dt_e
        emit(f"batch/{phi}/progressive_engine", dt_e / batch * 1e6,
             f"certified_frac={res.stats.certified.mean():.2f};"
             f"speedup={dt_loop / dt_e:.1f}x")

        # legacy fixed-K div-A* (approximation: static candidate budget)
        def batched():
            out = batch_optimal_diverse(graph, qs, k, eps, K=128, ef=4)
            out[0].block_until_ready()
            return out
        out, dt_b = timed(batched, warmup=1, reps=2)
        cert = float(np.mean(np.asarray(out[3])))
        emit(f"batch/{phi}/batched_divastar", dt_b / batch * 1e6,
             f"certified_frac={cert:.2f};speedup={dt_loop/dt_b:.1f}x")

        def batched_greedy():
            out = batch_greedy_diverse(graph, qs, k, eps, L=256)
            out[0].block_until_ready()
            return out
        _, dt_g = timed(batched_greedy, warmup=1, reps=2)
        emit(f"batch/{phi}/batched_greedy", dt_g / batch * 1e6,
             f"speedup_vs_loop={dt_loop/dt_g:.1f}x")
    return speedups


# ------------------------------------------------------------ skewed mode ----

def make_skewed_workload(x, metric, requests: int, seed: int = 7):
    """Mixed (k, eps) request stream with a heavy diversification tail:
    75% light (phi ~ low) queries, 25% dense-G^eps (phi ~ medium) ones,
    k alternating in {5, 10}, order shuffled."""
    rng = np.random.default_rng(seed)
    queries = D.queries_for(x, requests)
    eps_light = D.calibrate_eps(x, metric, D.PHI_TARGETS["low"])
    eps_heavy = D.calibrate_eps(x, metric, D.PHI_TARGETS["medium"])
    ks = np.where(np.arange(requests) % 2 == 0, 5, 10)
    heavy = rng.permutation(requests) < requests // 4
    epss = np.where(heavy, eps_heavy, eps_light)
    perm = rng.permutation(requests)
    return queries[perm], ks[perm], epss[perm], heavy[perm]


def _serve(graph, queries, ks, epss, ef, lanes, admission, prewarm):
    sched = LaneScheduler(graph, num_lanes=lanes, max_k=int(ks.max()),
                          default_ef=ef, admission=admission,
                          max_pending=len(queries), prewarm=prewarm)
    results = sched.run(queries, ks, epss, efs=ef)
    return sched, results


def run_skewed(n: int = D.N_DEFAULT, requests: int = 64, lanes: int = 16,
               ef: int = 10, parity: str = "sample", seed: int = 7) -> dict:
    graph, x, metric = D.load_graph("deep-like", n=n)
    queries, ks, epss, heavy = make_skewed_workload(x, metric, requests, seed)
    print(f"# skewed workload: {requests} requests, {lanes} lanes, n={n}, "
          f"heavy_frac={heavy.mean():.2f}, ks={sorted(set(ks.tolist()))}",
          flush=True)

    # warmup: compiles the capacity ladder + every diversify signature the
    # workload reaches (jit caches are module-global, so both timed passes
    # below run fully warm)
    _serve(graph, queries, ks, epss, ef, lanes, "continuous", prewarm=True)

    out = {}
    for admission in ("lockstep", "continuous"):
        sched, results = _serve(graph, queries, ks, epss, ef, lanes,
                                admission, prewarm=False)
        stats = sched.latency_stats()
        out[admission] = (stats, results)
        emit(f"skewed/{admission}/p50_latency", stats["p50_latency"] * 1e6,
             "per-request us")
        emit(f"skewed/{admission}/p99_latency", stats["p99_latency"] * 1e6,
             f"fairness={stats['fairness']:.3f}")
        emit(f"skewed/{admission}/throughput", stats["throughput"],
             f"req_per_s;certified_frac={stats['certified_frac']:.2f};"
             f"signatures={stats['signatures']}")

    ls, cs = out["lockstep"][0], out["continuous"][0]
    p99_win = cs["p99_latency"] < ls["p99_latency"]
    tput_win = cs["throughput"] > ls["throughput"]
    print(f"# continuous vs lockstep: p99 "
          f"{ls['p99_latency']:.3f}s -> {cs['p99_latency']:.3f}s "
          f"({'better' if p99_win else 'WORSE'}), throughput "
          f"{ls['throughput']:.2f} -> {cs['throughput']:.2f} req/s "
          f"({'better' if tput_win else 'WORSE'})", flush=True)

    # parity: scheduler results (either admission — they are identical by
    # construction, assert that too) vs the per-query PSS driver
    violations = 0
    lock_res, cont_res = out["lockstep"][1], out["continuous"][1]
    for i in range(requests):
        if not (np.array_equal(lock_res[i].ids, cont_res[i].ids)
                and np.array_equal(lock_res[i].scores, cont_res[i].scores)):
            print(f"# PARITY VIOLATION lockstep!=continuous at request {i}")
            violations += 1
    if parity != "off":
        sample = (range(requests) if parity == "full" else
                  np.random.default_rng(0).choice(requests,
                                                  min(8, requests),
                                                  replace=False))
        for i in sample:
            solo = diverse_search(graph, queries[i], k=int(ks[i]),
                                  eps=float(epss[i]), method="pss", ef=ef)
            r = cont_res[i]
            if not (np.array_equal(np.asarray(solo.ids), r.ids)
                    and np.array_equal(np.asarray(solo.scores), r.scores)
                    and solo.stats.certified == r.stats.certified):
                print(f"# PARITY VIOLATION scheduler!=solo pss at request {i}")
                violations += 1
    print(f"# parity check: {violations} violations", flush=True)
    return dict(lockstep=ls, continuous=cs, p99_win=p99_win,
                tput_win=tput_win, parity_violations=violations)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="engine", choices=["engine", "skewed"])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (small n, few requests)")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None,
                    help="request count (both modes)")
    ap.add_argument("--lanes", type=int, default=None)
    ap.add_argument("--ef", type=int, default=10)
    ap.add_argument("--parity", default=None,
                    choices=["full", "sample", "off"])
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)
    if args.mode == "engine":
        kwargs = {}
        if args.n:
            kwargs["n"] = args.n
        if args.batch:
            kwargs["batch"] = args.batch
        run(**kwargs)
        return 0
    n = args.n or (2000 if args.tiny else D.N_DEFAULT)
    requests = args.batch or (16 if args.tiny else 64)
    lanes = args.lanes or (4 if args.tiny else 16)
    parity = args.parity or ("full" if args.tiny else "sample")
    res = run_skewed(n=n, requests=requests, lanes=lanes, ef=args.ef,
                     parity=parity, seed=args.seed)
    if res["parity_violations"]:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
