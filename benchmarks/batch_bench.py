"""Serving-path benchmark: the batched progressive engine vs the per-query
progressive driver loop, plus the legacy fixed-K batched baselines.

The headline comparison (EXPERIMENTS.md §Perf): at serving batch sizes the
per-query pause/inspect/resume loop pays its host round-trips and device
dispatches per *query*, while ``core.batch_progressive`` pays them per
*round* for the whole batch — same per-lane semantics (exact parity with
``pss``), ~B-fold fewer dispatches."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import datasets as D
from benchmarks.common import emit, timed
from repro.core.api import diverse_search
from repro.core.batch import batch_greedy_diverse, batch_optimal_diverse
from repro.core.batch_progressive import batch_pss


def run(n: int = D.N_DEFAULT, batch: int = 64, k: int = 10, ef: int = 10,
        phis: tuple = ("low", "medium")):
    graph, x, metric = D.load_graph("deep-like", n=n)
    queries = D.queries_for(x, batch)
    qs = jnp.asarray(queries)
    speedups = {}
    for phi in phis:
        eps = D.calibrate_eps(x, metric, D.PHI_TARGETS[phi])

        # per-query progressive driver loop (paper-faithful baseline)
        def loop_pss():
            return [diverse_search(graph, q, k=k, eps=eps, method="pss",
                                   ef=ef) for q in queries]
        _, dt_loop = timed(loop_pss, warmup=1, reps=1)
        emit(f"batch/{phi}/per_query_pss", dt_loop / batch * 1e6,
             "per-query us")

        # batched progressive engine (exact same per-lane results);
        # streams=2 overlaps host orchestration with device work
        def engine():
            return batch_pss(graph, qs, k, eps, ef=ef, streams=2)
        res, dt_e = timed(engine, warmup=1, reps=2)
        speedups[phi] = dt_loop / dt_e
        emit(f"batch/{phi}/progressive_engine", dt_e / batch * 1e6,
             f"certified_frac={res.stats.certified.mean():.2f};"
             f"speedup={dt_loop / dt_e:.1f}x")

        # legacy fixed-K div-A* (approximation: static candidate budget)
        def batched():
            out = batch_optimal_diverse(graph, qs, k, eps, K=128, ef=4)
            out[0].block_until_ready()
            return out
        out, dt_b = timed(batched, warmup=1, reps=2)
        cert = float(np.mean(np.asarray(out[3])))
        emit(f"batch/{phi}/batched_divastar", dt_b / batch * 1e6,
             f"certified_frac={cert:.2f};speedup={dt_loop/dt_b:.1f}x")

        def batched_greedy():
            out = batch_greedy_diverse(graph, qs, k, eps, L=256)
            out[0].block_until_ready()
            return out
        _, dt_g = timed(batched_greedy, warmup=1, reps=2)
        emit(f"batch/{phi}/batched_greedy", dt_g / batch * 1e6,
             f"speedup_vs_loop={dt_loop/dt_g:.1f}x")
    return speedups


if __name__ == "__main__":
    import sys
    kwargs = {}
    if len(sys.argv) > 1:
        kwargs["n"] = int(sys.argv[1])
    if len(sys.argv) > 2:
        kwargs["batch"] = int(sys.argv[2])
    run(**kwargs)
